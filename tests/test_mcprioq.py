"""Behavioural tests for the core MCPrioQ structure vs a dict oracle."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core import slab as sl
from repro.core.hashtable import EMPTY


class DictOracle:
    """Exact Markov-chain counts (no capacity limits) for cross-checking."""

    def __init__(self):
        self.edges = {}   # src -> {dst: cnt}
        self.tot = {}     # src -> total

    def update(self, src, dst, w=1):
        self.edges.setdefault(src, {})
        self.edges[src][dst] = self.edges[src].get(dst, 0) + w
        self.tot[src] = self.tot.get(src, 0) + w

    def probs_desc(self, src):
        if src not in self.edges:
            return []
        t = self.tot[src]
        items = sorted(self.edges[src].items(), key=lambda kv: (-kv[1], kv[0]))
        return [(d, c / t) for d, c in items]

    def decay(self):
        for s in list(self.edges):
            new = {d: c // 2 for d, c in self.edges[s].items() if c // 2 > 0}
            self.edges[s] = new
            self.tot[s] = sum(new.values())


CFGS = [
    mc.MCConfig(num_rows=64, capacity=16, sort_passes=2),
    mc.MCConfig(num_rows=64, capacity=16, sort_passes=2, use_dst_hash=True),
]


@pytest.mark.parametrize("cfg", CFGS, ids=["scan", "dst_hash"])
def test_update_and_counts_match_oracle(cfg):
    rng = np.random.default_rng(0)
    state = mc.init(cfg)
    oracle = DictOracle()
    for _ in range(6):
        src = rng.integers(0, 20, size=64).astype(np.int32)
        dst = rng.integers(0, 12, size=64).astype(np.int32)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst), cfg=cfg)
        for s, d in zip(src.tolist(), dst.tolist()):
            oracle.update(s, d)
    inv = mc.check_invariants(state)
    assert inv["order_is_permutation"]
    assert inv["tot_matches_cnt_sum"]
    assert inv["free_slots_consistent"]
    # every oracle edge must be present with the exact count (capacity 16 > 12
    # distinct dsts, so no Space-Saving approximation in this test)
    rows, found = mc.lookup_rows(state, jnp.arange(20, dtype=jnp.int32), cfg=cfg)
    rows, found = np.asarray(rows), np.asarray(found)
    dstm, cntm = np.asarray(state.slabs.dst), np.asarray(state.slabs.cnt)
    for s in oracle.edges:
        assert found[s]
        r = rows[s]
        for d, c in oracle.edges[s].items():
            slots = np.nonzero(dstm[r] == d)[0]
            assert len(slots) == 1
            assert cntm[r, slots[0]] == c
        assert int(state.slabs.tot[r]) == oracle.tot[s]


@pytest.mark.parametrize("cfg", CFGS, ids=["scan", "dst_hash"])
def test_query_threshold_matches_oracle(cfg):
    rng = np.random.default_rng(1)
    state = mc.init(cfg)
    oracle = DictOracle()
    # Zipf-ish transitions from a handful of srcs
    for _ in range(30):
        src = rng.integers(0, 5, size=32).astype(np.int32)
        dst = (rng.zipf(1.8, size=32) % 10).astype(np.int32)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst), cfg=cfg)
        for s, d in zip(src.tolist(), dst.tolist()):
            oracle.update(s, d)
    # settle ordering fully so the comparison is exact
    slabs = state.slabs
    order = sl.full_sort(slabs.cnt, slabs.order)
    state = state._replace(slabs=sl.Slabs(slabs.dst, slabs.cnt, slabs.tot, order))

    t = 0.9
    srcs = jnp.arange(5, dtype=jnp.int32)
    dsts, probs, n_needed = mc.query_threshold(state, srcs, t, cfg=cfg, max_items=16)
    dsts, probs, n_needed = map(np.asarray, (dsts, probs, n_needed))
    for s in range(5):
        ref = oracle.probs_desc(s)
        cum, n_ref = 0.0, 0
        for _, p in ref:
            if cum >= t:
                break
            cum += p
            n_ref += 1
        assert n_needed[s] == n_ref
        # probabilities of the returned prefix match the oracle's sorted probs
        ref_p = np.array([p for _, p in ref[: min(n_ref, 16)]])
        got_p = probs[s][: len(ref_p)]
        np.testing.assert_allclose(got_p, ref_p, rtol=1e-6)
        # cumulative probability actually crosses the threshold
        assert ref_p.sum() >= t or len(ref) <= 16


def test_sort_convergence_and_approximate_order():
    """One odd-even pass fixes a single small increment (paper's normal case);
    C passes sort fully from any state."""
    cfg = mc.MCConfig(num_rows=4, capacity=8, sort_passes=0)
    state = mc.init(cfg)
    # build a sorted row: counts 8,7,6,...,1
    src = jnp.zeros((8,), jnp.int32)
    for i in range(8):
        w = jnp.full((1,), 8 - i, jnp.int32)
        state = mc.update_batch(state, src[:1], jnp.asarray([i], jnp.int32),
                                weights=w, cfg=cfg)
    slabs = state.slabs
    order = sl.full_sort(slabs.cnt, slabs.order)
    assert int(sl.inversions(slabs.cnt, order)[0]) == 0
    state = state._replace(slabs=sl.Slabs(slabs.dst, slabs.cnt, slabs.tot, order))

    # bump item ranked 5 by +2: creates exactly one adjacent inversion
    cfg1 = mc.MCConfig(num_rows=4, capacity=8, sort_passes=1)
    d5 = int(np.asarray(jnp.take_along_axis(slabs.dst, order, 1))[0, 5])
    state = mc.update_batch(state, src[:1], jnp.asarray([d5], jnp.int32),
                            weights=jnp.asarray([2], jnp.int32), cfg=cfg1)
    assert int(sl.inversions(state.slabs.cnt, state.slabs.order)[0]) == 0

    # now scramble hard (big weights to random dsts) and show k=C passes sort
    rng = np.random.default_rng(3)
    dd = jnp.asarray(rng.integers(0, 8, size=16), jnp.int32)
    ww = jnp.asarray(rng.integers(1, 100, size=16), jnp.int32)
    state = mc.update_batch(state, jnp.zeros((16,), jnp.int32), dd,
                            weights=ww, cfg=cfg)
    order = sl.oddeven_passes(state.slabs.cnt, state.slabs.order, passes=8)
    assert int(sl.inversions(state.slabs.cnt, order)[0]) == 0


def test_decay_preserves_distribution_and_evicts():
    cfg = mc.MCConfig(num_rows=8, capacity=8, sort_passes=2, use_dst_hash=True)
    state = mc.init(cfg)
    src = jnp.zeros((4,), jnp.int32)
    dst = jnp.asarray([10, 11, 12, 13], jnp.int32)
    w = jnp.asarray([8, 4, 2, 1], jnp.int32)
    state = mc.update_batch(state, src, dst, weights=w, cfg=cfg)
    state = mc.decay(state, cfg=cfg)
    inv = mc.check_invariants(state, cfg)
    assert inv["dst_hash_consistent"]  # repaired incrementally, not rebuilt
    assert all(v for k, v in inv.items() if isinstance(v, bool))
    # counts halved: 4,2,1 and the w=1 edge evicted
    dsts, probs = mc.query_topk(state, src[:1], cfg=cfg, k=8)
    live = np.asarray(dsts[0])
    assert set(live[live != EMPTY].tolist()) == {10, 11, 12}
    # ratios preserved: p(10) = 4/7
    np.testing.assert_allclose(float(probs[0, 0]), 4 / 7, rtol=1e-6)
    # dst-hash still consistent after rebuild
    rows, _ = mc.lookup_rows(state, src[:1], cfg=cfg)
    slots, found = mc._find_slots(state, rows, jnp.asarray([11], jnp.int32), cfg)
    assert bool(found[0])
    assert int(state.slabs.dst[rows[0], slots[0]]) == 11


def test_space_saving_replacement_when_full():
    cfg = mc.MCConfig(num_rows=4, capacity=4, sort_passes=4)
    state = mc.init(cfg)
    src = jnp.zeros((4,), jnp.int32)
    state = mc.update_batch(state, src, jnp.asarray([0, 1, 2, 3], jnp.int32),
                            weights=jnp.asarray([10, 8, 6, 1], jnp.int32), cfg=cfg)
    # new dst 99 must replace the tail (dst 3, cnt 1) and inherit its count
    state = mc.update_batch(state, src[:1], jnp.asarray([99], jnp.int32), cfg=cfg)
    d = np.asarray(state.slabs.dst[0])
    c = np.asarray(state.slabs.cnt[0])
    assert 99 in d.tolist() and 3 not in d.tolist()
    assert c[d.tolist().index(99)] == 2  # inherited 1 + weight 1
    assert int(state.evictions) == 1
    # tot unchanged except +1
    assert int(state.slabs.tot[0]) == 26


def test_unknown_src_queries_are_empty():
    cfg = mc.MCConfig(num_rows=4, capacity=4)
    state = mc.init(cfg)
    dsts, probs, n = mc.query_threshold(
        state, jnp.asarray([7], jnp.int32), 0.9, cfg=cfg, max_items=4)
    assert int(n[0]) == 0
    assert np.all(np.asarray(dsts) == EMPTY)


def test_maybe_decay_threshold():
    cfg = mc.MCConfig(num_rows=4, capacity=4)
    state = mc.init(cfg)
    src = jnp.zeros((2,), jnp.int32)
    state = mc.update_batch(state, src, jnp.asarray([1, 2], jnp.int32),
                            weights=jnp.asarray([40, 20], jnp.int32), cfg=cfg)
    out = mc.maybe_decay(state, cfg=cfg, total_threshold=50)
    assert int(out.slabs.tot[0]) == 30  # decayed
    out2 = mc.maybe_decay(out, cfg=cfg, total_threshold=50)
    assert int(out2.slabs.tot[0]) == 30  # below threshold now, unchanged
