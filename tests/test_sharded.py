"""Sharded MCPrioQ: routing correctness on a multi-device (fake) mesh.

Runs the real shard_map path in a subprocess with 8 host devices so the rest
of the suite keeps seeing a single device (see dryrun.py note in the brief).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.core.epoch import EpochStore

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.core import mcprioq as mc, sharded as sh

    mesh = compat.make_mesh((8,), ("shard",))
    # sort_passes=4: the comparison below is exact, so per-batch passes must
    # fully settle the order (2 passes leave residual inversions on this load)
    scfg = sh.ShardedConfig(
        base=mc.MCConfig(num_rows=256, capacity=32, sort_passes=4),
        num_shards=8, axis="shard", bucket_factor=4.0)
    state = sh.init_sharded(scfg, mesh)
    upd = sh.make_update_fn(scfg, mesh)
    qry = sh.make_query_fn(scfg, mesh, threshold=0.9, max_items=8)

    rng = np.random.default_rng(0)
    oracle = {}
    for _ in range(4):
        src = rng.integers(0, 40, size=256).astype(np.int32)
        dst = rng.integers(0, 10, size=256).astype(np.int32)
        w = np.ones(256, np.int32)
        state = upd(state, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))
        for s, d in zip(src.tolist(), dst.tolist()):
            oracle.setdefault(s, {})
            oracle[s][d] = oracle[s].get(d, 0) + 1

    # no drops allowed at this bucket factor
    assert int(jnp.sum(state.route_dropped)) == 0, "router dropped items"
    assert int(jnp.sum(state.dropped_probes)) == 0
    assert int(jnp.sum(state.dropped_rows)) == 0

    # query every src node once; batch padded to a multiple of 8
    srcs = np.arange(40, dtype=np.int32)
    srcs = np.concatenate([srcs, np.full(8 - len(srcs) % 8, -1, np.int32)])
    d, p, n, qdrop = qry(state, jnp.asarray(srcs))
    assert int(jnp.sum(qdrop)) == 0, "query routing dropped items"
    d, p, n = map(np.asarray, (d, p, n))
    for s in range(40):
        tot = sum(oracle[s].values())
        ref = sorted(oracle[s].items(), key=lambda kv: (-kv[1], kv[0]))
        cum, n_ref = 0.0, 0
        for _, c in ref:
            if cum >= 0.9:
                break
            cum += c / tot
            n_ref += 1
        assert n[s] == n_ref, (s, n[s], n_ref)
        got = p[s][p[s] > 0]
        want = np.array([c / tot for _, c in ref[: len(got)]])
        np.testing.assert_allclose(np.sort(got)[::-1], want, rtol=1e-5)
    print("SHARDED-OK")
    """
)


def test_sharded_update_query_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-OK" in out.stdout


def test_owner_assignment_balanced():
    owners = sh.owner_of(jnp.arange(4096, dtype=jnp.int32), 16)
    counts = np.bincount(np.asarray(owners), minlength=16)
    assert counts.min() > 0.6 * 4096 / 16
    assert counts.max() < 1.4 * 4096 / 16


def test_single_shard_matches_local():
    """num_shards=1 sharded path == plain local update/query."""
    mesh = compat.make_mesh((1,), ("shard",))
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=2)
    scfg = sh.ShardedConfig(base=base, num_shards=1, axis="shard",
                            bucket_factor=1.0)
    state = sh.init_sharded(scfg, mesh)
    upd = sh.make_update_fn(scfg, mesh)
    rng = np.random.default_rng(1)
    src = jnp.asarray(rng.integers(0, 8, 64).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, 8, 64).astype(np.int32))
    w = jnp.ones((64,), jnp.int32)
    state = upd(state, src, dst, w)

    local = mc.init(base)
    local = mc.update_batch(local, src, dst, cfg=base)
    # same multiset of (dst, cnt) per row for every src
    for s in range(8):
        r_sh, f_sh = mc.lookup_rows(
            jax.tree_util.tree_map(lambda x: x[0], state),
            jnp.asarray([s], jnp.int32), cfg=base)
        r_lo, f_lo = mc.lookup_rows(local, jnp.asarray([s], jnp.int32), cfg=base)
        assert bool(f_sh[0]) == bool(f_lo[0])
        if not bool(f_lo[0]):
            continue
        def row_multiset(st, r):
            d = np.asarray(st.slabs.dst[int(r)])
            c = np.asarray(st.slabs.cnt[int(r)])
            return sorted((int(a), int(b)) for a, b in zip(d, c) if b > 0)
        st0 = jax.tree_util.tree_map(lambda x: x[0], state)
        assert row_multiset(st0, r_sh[0]) == row_multiset(local, r_lo[0])


def test_epoch_store_rcu_semantics():
    store = EpochStore({"v": 0})
    s0 = store.acquire()
    store.publish({"v": 1})
    s1 = store.acquire()
    assert s0.state["v"] == 0 and s1.state["v"] == 1  # old reader unaffected
    store.release(s0)
    store.release(s1)
    store.synchronize()
    assert 0 in store.retired_versions  # grace period elapsed -> reclaimed
    assert store.version == 1
