"""MCPrioQ as the MoE expert-popularity monitor (DESIGN §Arch-applicability)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import expert_monitor as em


def test_monitor_flags_imbalance():
    cfg = em.MonitorConfig(num_layers=4, num_experts=16)
    state = em.init(cfg)
    rng = np.random.default_rng(0)
    for step in range(20):
        # layer 0: collapsed routing (expert 3 gets ~85%); layer 1: uniform
        c0 = rng.multinomial(512, [0.85 / 1] + [0.01] * 15)
        c0 = np.roll(c0, 3)
        c1 = rng.multinomial(512, [1 / 16] * 16)
        state = em.observe(state, 0, jnp.asarray(c0), cfg)
        state = em.observe(state, 1, jnp.asarray(c1), cfg)
    report = em.balance_report(state, cfg, t=0.8)
    assert report[0] <= 2, report       # collapsed: 1-2 experts carry 80%
    assert report[1] >= 12, report      # uniform: ~13 experts needed
    ids, load, n = em.hot_experts(state, 0, 0.5, cfg)
    assert int(ids[0]) == 3             # hottest expert identified
    assert float(load[0]) > 0.7


def test_monitor_decay_tracks_drift():
    cfg = em.MonitorConfig(num_layers=1, num_experts=8,
                           decay_threshold=4096)
    state = em.init(cfg)
    hot_a = jnp.asarray([900, 10, 10, 10, 10, 10, 10, 10], jnp.int32)
    hot_b = jnp.asarray([10, 10, 10, 10, 10, 10, 10, 900], jnp.int32)
    for _ in range(8):
        state = em.observe(state, 0, hot_a, cfg)
    for _ in range(16):  # routing drifts; decay forgets the old regime
        state = em.observe(state, 0, hot_b, cfg)
    ids, load, _ = em.hot_experts(state, 0, 0.5, cfg)
    assert int(ids[0]) == 7, np.asarray(ids)
