"""Durability & elasticity subsystem (DESIGN.md §10): snapshots, WAL,
crash recovery, N -> M reshard-on-restore, and the two-level ownership map.

Single-shard engines run in-process (the persist machinery is fully
exercised at num_shards=1); the N=4 -> M={2,8} elastic matrix needs 8 fake
host devices and runs in a subprocess (device count is fixed at first jax
init — same pattern as test_sharded_engine.py).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.persist import reshard as rs
from repro.persist import snapshot as snap_io
from repro.persist.wal import WriteAheadLog
from repro.serve.engine import ShardedEngine, ShardedServeConfig
from repro.sharding.ownership import Ownership


def _distinct_count_batch(n_src=12, n_dst=5, seed=0):
    srcs, dsts = [], []
    for s in range(n_src):
        for d in range(n_dst):
            srcs += [s] * (d + 1)
            dsts += [d] * (d + 1)
    src = np.array(srcs, np.int32)
    dst = np.array(dsts, np.int32)
    perm = np.random.default_rng(seed).permutation(src.size)
    return src[perm], dst[perm]


def _assert_states_equal(a: mc.MCState, b: mc.MCState):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# ckpt dtype regression (satellite): integer counters must survive npz
# ---------------------------------------------------------------------------


def test_ckpt_roundtrips_integer_counters(tmp_path):
    cfg = mc.MCConfig(num_rows=8, capacity=4)
    state = mc.init(cfg)._replace(
        decay_cursor=jnp.int32(3), route_dropped=jnp.int32(7),
        deferred_new=jnp.int32(11))
    ckpt.save(state, str(tmp_path), 0)
    restored, _ = ckpt.restore(mc.init(cfg), str(tmp_path))
    for field in ("decay_cursor", "route_dropped", "deferred_new"):
        leaf = getattr(restored, field)
        assert leaf.dtype == jnp.int32, field
        assert int(leaf) == int(getattr(state, field)), field
    _assert_states_equal(state, restored)


def test_ckpt_rejects_kind_changing_cast(tmp_path):
    """A float checkpoint restoring into an integer leaf is a template
    mismatch; the old silent ``astype`` truncated values instead of
    failing."""
    cfg = mc.MCConfig(num_rows=8, capacity=4)
    float_state = jax.tree_util.tree_map(
        lambda x: jnp.asarray(x, jnp.float32) + 0.5, mc.init(cfg))
    ckpt.save(float_state, str(tmp_path), 0)
    with pytest.raises(ValueError, match="kind"):
        ckpt.restore(mc.init(cfg), str(tmp_path))


# ---------------------------------------------------------------------------
# snapshot completeness (crash-during-snapshot recovery)
# ---------------------------------------------------------------------------


def _save_two_steps(tmp_path, cfg):
    state0 = mc.init(cfg)
    src, dst = _distinct_count_batch()
    state1 = mc.update_batch(state0, jnp.asarray(src), jnp.asarray(dst),
                             cfg=cfg)
    snap_io.save_snapshot(state0, str(tmp_path), 0, {"wal_seq": -1})
    snap_io.save_snapshot(state1, str(tmp_path), 1, {"wal_seq": 0})
    return state0, state1


def test_latest_complete_step_skips_missing_npz(tmp_path):
    cfg = mc.MCConfig(num_rows=32, capacity=8)
    state0, _ = _save_two_steps(tmp_path, cfg)
    os.unlink(tmp_path / "step_00000001" / "arrays.npz")
    assert snap_io.latest_complete_step(str(tmp_path)) == 0
    restored, meta, step = snap_io.restore_snapshot(
        mc.init(cfg), str(tmp_path))
    assert step == 0 and meta["wal_seq"] == -1
    _assert_states_equal(state0, restored)


def test_latest_complete_step_skips_truncated_npz(tmp_path):
    cfg = mc.MCConfig(num_rows=32, capacity=8)
    _save_two_steps(tmp_path, cfg)
    npz = tmp_path / "step_00000001" / "arrays.npz"
    data = npz.read_bytes()
    npz.write_bytes(data[: len(data) // 2])   # torn mid-write
    assert snap_io.latest_complete_step(str(tmp_path)) == 0


def test_latest_complete_step_skips_torn_manifest(tmp_path):
    cfg = mc.MCConfig(num_rows=32, capacity=8)
    _save_two_steps(tmp_path, cfg)
    man = tmp_path / "step_00000001" / "manifest.json"
    man.write_text(man.read_text()[:20])      # torn json
    assert snap_io.latest_complete_step(str(tmp_path)) == 0


def test_latest_complete_step_requires_sidecar(tmp_path):
    cfg = mc.MCConfig(num_rows=32, capacity=8)
    _save_two_steps(tmp_path, cfg)
    os.unlink(tmp_path / "step_00000001" / "chain.json")
    assert snap_io.latest_complete_step(str(tmp_path)) == 0
    with pytest.raises(FileNotFoundError):
        snap_io.restore_snapshot(mc.init(cfg), str(tmp_path), step=1)


def test_no_complete_snapshot_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        snap_io.restore_snapshot(mc.init(mc.MCConfig(num_rows=8, capacity=4)),
                                 str(tmp_path))


# ---------------------------------------------------------------------------
# WAL: framing, rotation, torn tails, truncation
# ---------------------------------------------------------------------------


def test_wal_roundtrip_and_rotation(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_records=2, fsync="always")
    batches = []
    rng = np.random.default_rng(0)
    for i in range(5):
        src = rng.integers(0, 100, 16).astype(np.int32)
        dst = rng.integers(0, 100, 16).astype(np.int32)
        w = rng.integers(1, 5, 16).astype(np.int32)
        assert wal.append(src, dst, w) == i
        batches.append((src, dst, w))
    wal.close()
    assert len([n for n in os.listdir(tmp_path) if n.endswith(".seg")]) == 3
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.next_seq == 5          # resumes after what is on disk
    records = list(wal2.replay())
    assert [seq for seq, *_ in records] == list(range(5))
    for (seq, src, dst, w), (s0, d0, w0) in zip(records, batches):
        np.testing.assert_array_equal(src, s0)
        np.testing.assert_array_equal(dst, d0)
        np.testing.assert_array_equal(w, w0)
    assert len(list(wal2.replay(after_seq=2))) == 2


def test_wal_torn_tail_stops_replay(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_records=100)
    for i in range(3):
        wal.append(np.full(8, i, np.int32), np.full(8, i, np.int32))
    wal.close()
    seg = os.path.join(str(tmp_path), sorted(os.listdir(tmp_path))[0])
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:       # crash mid-append: half a record
        f.truncate(size - 10)
    records = list(WriteAheadLog(str(tmp_path)).replay())
    assert [seq for seq, *_ in records] == [0, 1]


def test_wal_corrupt_record_stops_replay(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_records=100)
    for i in range(3):
        wal.append(np.full(8, i, np.int32), np.full(8, i, np.int32))
    wal.close()
    seg = os.path.join(str(tmp_path), sorted(os.listdir(tmp_path))[0])
    with open(seg, "r+b") as f:       # flip payload bytes of record 1
        f.seek(-40, os.SEEK_END)
        f.write(b"\xff\xff\xff\xff")
    records = list(WriteAheadLog(str(tmp_path)).replay())
    assert [seq for seq, *_ in records] == [0, 1]  # CRC kills record 2


def test_wal_append_after_torn_tail_keeps_later_records(tmp_path):
    """Crash-restart pattern: a torn tail in segment A must not hide the
    durable records a post-crash writer appends to segment B — the writer
    resumes at the torn seq, so the sequence stays contiguous through the
    tear (regression: replay used to stop at the first tear globally)."""
    wal = WriteAheadLog(str(tmp_path), segment_records=100, fsync="always")
    for i in range(3):
        wal.append(np.full(8, i, np.int32), np.full(8, i, np.int32))
    wal.close()
    seg = os.path.join(str(tmp_path), sorted(os.listdir(tmp_path))[0])
    with open(seg, "r+b") as f:       # crash mid-append tears record 2
        f.truncate(os.path.getsize(seg) - 10)
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.next_seq == 2         # the torn record never happened
    wal2.append(np.full(8, 7, np.int32), np.full(8, 7, np.int32))
    wal2.append(np.full(8, 9, np.int32), np.full(8, 9, np.int32))
    wal2.close()
    records = list(WriteAheadLog(str(tmp_path)).replay())
    assert [seq for seq, *_ in records] == [0, 1, 2, 3]
    np.testing.assert_array_equal(records[2][1], np.full(8, 7, np.int32))
    np.testing.assert_array_equal(records[3][1], np.full(8, 9, np.int32))


def test_wal_gap_between_segments_stops_replay(tmp_path):
    """A genuine mid-log gap (whole segment lost, valid data after) breaks
    seq contiguity; nothing past it may be resurrected."""
    wal = WriteAheadLog(str(tmp_path), segment_records=2)
    for i in range(6):
        wal.append(np.full(4, i, np.int32), np.full(4, i, np.int32))
    wal.close()
    os.unlink(os.path.join(str(tmp_path), sorted(os.listdir(tmp_path))[1]))
    records = list(WriteAheadLog(str(tmp_path)).replay())
    assert [seq for seq, *_ in records] == [0, 1]


def test_wal_truncate_through_drops_closed_segments(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_records=2)
    for i in range(6):
        wal.append(np.full(4, i, np.int32), np.full(4, i, np.int32))
    wal.close()
    removed = wal.truncate_through(3)   # segments [0,1] and [2,3]
    assert removed == 2
    assert [seq for seq, *_ in WriteAheadLog(str(tmp_path)).replay()] == [4, 5]


# ---------------------------------------------------------------------------
# crash recovery, unsharded path: snapshot + replay is bit-exact
# ---------------------------------------------------------------------------


def test_unsharded_crash_recovery_bit_exact(tmp_path):
    """Restore(latest snapshot) + deterministic WAL replay through the same
    update/maintain pipeline reproduces the pre-crash state — arrays and
    counter_stats — bit-exactly (the recovery contract)."""
    cfg = mc.MCConfig(num_rows=64, capacity=8, sort_passes=1,
                      max_new_per_batch=16, decay_block_rows=16)
    snap_dir, wal_dir = tmp_path / "snap", tmp_path / "wal"
    wal = WriteAheadLog(str(wal_dir), segment_records=3, fsync="always")

    def cycle(state, src, dst):
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)
        return mc.maybe_decay(state, cfg=cfg, total_threshold=4)

    rng = np.random.default_rng(1)
    state = mc.init(cfg)
    for seq in range(10):
        src = rng.integers(0, 80, 64).astype(np.int32)   # overflows rows,
        dst = rng.integers(0, 40, 64).astype(np.int32)   # decays, defers
        wal.append(src, dst)
        state = cycle(state, src, dst)
        if seq == 4:
            snap_io.save_snapshot(state, str(snap_dir), seq + 1,
                                  {"wal_seq": seq})
    wal.close()
    expect_stats = mc.counter_stats(state)
    assert expect_stats["deferred_new"] > 0      # the messy path is live
    assert mc.maintenance_stats(state)["decay_steps"] > 0

    # crash: all host/device state is gone; recover from disk only
    step = snap_io.latest_complete_step(str(snap_dir))
    recovered, meta, _ = snap_io.restore_snapshot(mc.init(cfg),
                                                  str(snap_dir), step)
    replayed = 0
    for seq, src, dst, _w in WriteAheadLog(str(wal_dir)).replay(
            after_seq=meta["wal_seq"]):
        recovered = cycle(recovered, src, dst)
        replayed += 1
    assert replayed == 5
    _assert_states_equal(state, recovered)
    assert mc.counter_stats(recovered) == expect_stats


# ---------------------------------------------------------------------------
# ownership map
# ---------------------------------------------------------------------------


def test_ownership_default_matches_legacy_hash():
    """The seed routing formula, inlined as the oracle (sh.owner_of now
    delegates to Ownership, so comparing against it would be circular)."""
    from repro.core.hashtable import hash_u32
    src = jnp.arange(4096, dtype=jnp.int32)
    for s in (1, 2, 4, 8, 16):
        legacy = ((hash_u32(src) >> jnp.uint32(8))
                  % jnp.uint32(s)).astype(jnp.int32)
        own = Ownership(num_shards=s).owner_of(src)
        np.testing.assert_array_equal(np.asarray(legacy), np.asarray(own))
        np.testing.assert_array_equal(np.asarray(legacy),
                                      np.asarray(sh.owner_of(src, s)))


def test_ownership_total_and_reassign_moves_bucket():
    own = Ownership(num_shards=4, num_buckets=64)
    src = jnp.arange(10000, dtype=jnp.int32)
    owners = np.asarray(own.owner_of(src))
    assert owners.min() >= 0 and owners.max() < 4          # total
    buckets = np.asarray(own.bucket_of(src))
    b = int(buckets[0])
    moved = own.reassign(b, 3)
    new_owners = np.asarray(moved.owner_of(src))
    in_bucket = buckets == b
    assert np.all(new_owners[in_bucket] == 3)              # bucket moved
    np.testing.assert_array_equal(owners[~in_bucket],
                                  new_owners[~in_bucket])  # others pinned


def test_ownership_validation():
    with pytest.raises(ValueError):
        Ownership(num_shards=2, num_buckets=3)       # not a power of two
    with pytest.raises(ValueError):
        Ownership(num_shards=2, num_buckets=4, assignment=(0, 1, 2, 0))
    with pytest.raises(ValueError):
        Ownership(num_shards=2, num_buckets=4, assignment=(0, 1))
    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=8, capacity=4),
                            num_shards=2,
                            ownership=Ownership(num_shards=4))
    with pytest.raises(ValueError):
        scfg.resolved_ownership()


# ---------------------------------------------------------------------------
# reshard planning + edge extraction
# ---------------------------------------------------------------------------


def test_extract_edges_roundtrips_counts():
    cfg = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    src, dst = _distinct_count_batch()
    state = mc.update_batch(mc.init(cfg), jnp.asarray(src), jnp.asarray(dst),
                            cfg=cfg)
    es, ed, ec = rs.extract_edges(state)
    assert es.size == 12 * 5
    got = {(int(s), int(d)): int(c) for s, d, c in zip(es, ed, ec)}
    for s in range(12):
        for d in range(5):
            assert got[(s, d)] == d + 1


def test_plan_batches_respects_slice_and_bucket_caps():
    rng = np.random.default_rng(2)
    n = 1000
    # unique (src, dst) pairs so edges are identifiable across batches
    src = (np.arange(n) // 40).astype(np.int32)
    dst = (np.arange(n) % 40).astype(np.int32)
    w = rng.integers(1, 9, n).astype(np.int32)
    num_shards, slice_len, cap = 4, 32, 8
    owner = rng.integers(0, num_shards, n).astype(np.int32)
    owner[:600] = 0                                          # heavy skew
    seen = np.zeros(n, bool)
    key = {(int(s), int(d)): i for i, (s, d) in enumerate(zip(src, dst))}
    for bsrc, bdst, bw in rs.plan_batches(src, dst, w, owner, num_shards,
                                          slice_len, cap):
        assert bsrc.size == num_shards * slice_len
        s2, d2 = (bsrc.reshape(num_shards, slice_len),
                  bdst.reshape(num_shards, slice_len))
        for s in range(num_shards):
            live = s2[s] >= 0
            # per (source slice, destination shard) count within capacity
            d_of = owner[[key[(int(x), int(y))]
                          for x, y in zip(s2[s][live], d2[s][live])]]
            for dshard in range(num_shards):
                assert np.sum(np.asarray(d_of) == dshard) <= cap
        for x, y, z in zip(bsrc, bdst, bw):
            if x >= 0:
                i = key[(int(x), int(y))]
                assert not seen[i] and z == w[i]
                seen[i] = True
    assert seen.all()                  # every edge exactly once


# ---------------------------------------------------------------------------
# ShardedEngine durability (single-shard mesh, in-process)
# ---------------------------------------------------------------------------


def _engine(tmp_path, *, wal=True, snapshot_every=0, num_shards=1,
            deadline_s=60.0):
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=num_shards,
                            bucket_factor=4.0)
    return ShardedEngine(ShardedServeConfig(
        sharded=scfg, decay_threshold=1 << 20,
        snapshot_dir=str(tmp_path / "snap"),
        snapshot_every=snapshot_every,
        wal_dir=str(tmp_path / "wal") if wal else None,
        wal_fsync="always", observe_deadline_s=deadline_s))


def test_engine_checkpoint_restore_exact_with_wal_replay(tmp_path):
    eng = _engine(tmp_path)
    src, dst = _distinct_count_batch()
    eng.observe(src, dst)
    eng.checkpoint()
    src2, dst2 = _distinct_count_batch(seed=1)
    eng.observe(src2, dst2)            # after the snapshot: WAL-only
    ref_q = eng.query(np.arange(12, dtype=np.int32))
    ref_stats = dict(eng.stats)

    eng2 = _engine(tmp_path)           # fresh process stand-in
    info = eng2.restore()
    assert info["mode"] == "exact" and info["replayed"] == 1
    got_q = eng2.query(np.arange(12, dtype=np.int32))
    for a, b in zip(ref_q, got_q):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("n_rows", "evictions", "deferred_new", "route_dropped",
              "decay_steps"):
        assert eng2.stats[k] == ref_stats[k], k
    snap_a = eng.store.acquire()
    snap_b = eng2.store.acquire()
    try:
        _assert_states_equal(snap_a.state, snap_b.state)
    finally:
        eng.store.release(snap_a)
        eng2.store.release(snap_b)


def test_engine_cadence_snapshots_in_background(tmp_path):
    eng = _engine(tmp_path, snapshot_every=2)
    src, dst = _distinct_count_batch(n_src=4)
    for _ in range(4):
        eng.observe(src, dst)
    eng.close()                        # joins the async snapshot writers
    assert eng.stats["snapshots"] == 2
    assert snap_io.latest_complete_step(str(tmp_path / "snap")) == 4


def test_engine_watchdog_escalation_checkpoints(tmp_path):
    eng = _engine(tmp_path, deadline_s=0.0)   # every observe is "slow"
    eng.watchdog.cfg = dataclasses.replace(
        eng.watchdog.cfg, max_consecutive_slow=2)
    src, dst = _distinct_count_batch(n_src=4)
    eng.observe(src, dst)
    assert eng.stats["snapshots"] == 0
    eng.observe(src, dst)                     # 2nd slow step escalates
    assert eng.stats["snapshots"] == 1
    assert snap_io.latest_complete_step(str(tmp_path / "snap")) is not None


def test_snapshot_truncates_redundant_wal_segments(tmp_path):
    """WAL GC rides the snapshot cadence: after a snapshot at wal_seq
    commits, every closed segment holding only records with seq <= wal_seq
    is unlinked — and recovery from what remains is still exact."""
    eng = _engine(tmp_path)
    eng.wal.segment_records = 1        # one batch per segment -> all closed
    src, dst = _distinct_count_batch(n_src=4)
    for _ in range(3):
        eng.observe(src, dst)
    wal_dir = tmp_path / "wal"
    assert len(list(wal_dir.glob("wal_*.seg"))) == 3
    eng.checkpoint()                   # sync: GC runs before return
    assert len(list(wal_dir.glob("wal_*.seg"))) == 0
    src2, dst2 = _distinct_count_batch(n_src=4, seed=1)
    eng.observe(src2, dst2)            # post-snapshot: survives GC
    assert len(list(wal_dir.glob("wal_*.seg"))) == 1

    eng2 = _engine(tmp_path)
    info = eng2.restore()
    assert info["mode"] == "exact" and info["replayed"] == 1
    snap_a, snap_b = eng.store.acquire(), eng2.store.acquire()
    try:
        _assert_states_equal(snap_a.state, snap_b.state)
    finally:
        eng.store.release(snap_a)
        eng2.store.release(snap_b)


def test_async_snapshot_gc_waits_for_commit_and_close_drains(tmp_path):
    """Async-cadence snapshots truncate the WAL only once the manifest
    commits (worker completion callback), and ``close()`` joins the
    non-daemon writers so shutdown never abandons a half-written step."""
    with _engine(tmp_path, snapshot_every=2) as eng:
        eng.wal.segment_records = 1
        src, dst = _distinct_count_batch(n_src=4)
        for _ in range(4):
            eng.observe(src, dst)
    # context exit ran close(): workers joined, callbacks (GC) done
    assert eng._io_threads == []
    assert snap_io.latest_complete_step(str(tmp_path / "snap")) == 4
    # snapshots landed at wal_seq=1 and wal_seq=3 -> all 4 segments GC'd
    assert len(list((tmp_path / "wal").glob("wal_*.seg"))) == 0
    eng.close()                        # idempotent

    eng2 = _engine(tmp_path)
    info = eng2.restore()
    assert info["mode"] == "exact" and info["replayed"] == 0
    snap_a, snap_b = eng.store.acquire(), eng2.store.acquire()
    try:
        _assert_states_equal(snap_a.state, snap_b.state)
    finally:
        eng.store.release(snap_a)
        eng2.store.release(snap_b)


def test_engine_restore_skips_torn_snapshot(tmp_path):
    eng = _engine(tmp_path)
    src, dst = _distinct_count_batch()
    eng.observe(src, dst)
    eng.checkpoint()
    eng.observe(src, dst)
    eng.checkpoint()
    # crash mid-snapshot: newest step's arrays are truncated
    snap_dir = tmp_path / "snap"
    steps = sorted(os.listdir(snap_dir))
    npz = snap_dir / steps[-1] / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:100])
    eng2 = _engine(tmp_path)
    info = eng2.restore()
    assert f"step_{info['step']:08d}" == steps[0]
    # WAL replay from the older snapshot still reaches the final state
    q = np.arange(12, dtype=np.int32)
    ref, got = eng.query(q), eng2.query(q)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_reassign_preserves_answers(tmp_path):
    eng = _engine(tmp_path, wal=False)
    src, dst = _distinct_count_batch()
    eng.observe(src, dst)
    ref = eng.query(np.arange(12, dtype=np.int32))
    own = Ownership(num_shards=1, num_buckets=32)
    eng.reassign(own)
    assert eng.cfg.sharded.resolved_ownership() == own
    got = eng.query(np.arange(12, dtype=np.int32))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        eng.reassign(Ownership(num_shards=3))


# ---------------------------------------------------------------------------
# elastic N -> M matrix on 8 fake devices (subprocess)
# ---------------------------------------------------------------------------

SCRIPT_ELASTIC = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import mcprioq as mc, sharded as sh
    from repro.serve.engine import ShardedEngine, ShardedServeConfig

    srcs, dsts = [], []
    for s in range(40):
        for d in range(6):
            srcs += [s] * (d + 1)
            dsts += [d] * (d + 1)
    src = np.array(srcs, np.int32)
    dst = np.array(dsts, np.int32)
    perm = np.random.default_rng(0).permutation(src.size)
    src, dst = src[perm], dst[perm]

    snap_dir = tempfile.mkdtemp()
    wal_dir = tempfile.mkdtemp()
    base = mc.MCConfig(num_rows=256, capacity=32, sort_passes=4)

    def engine_at(n):
        scfg = sh.ShardedConfig(base=base, num_shards=n, bucket_factor=4.0)
        return ShardedEngine(ShardedServeConfig(
            sharded=scfg, decay_threshold=1 << 20, snapshot_dir=snap_dir,
            wal_dir=wal_dir, wal_fsync="always"))

    e4 = engine_at(4)
    e4.observe(src, dst)
    e4.checkpoint()
    # one more batch AFTER the snapshot: elastic restore must replay it too
    src2 = np.arange(40, dtype=np.int32)
    dst2 = np.full(40, 17, np.int32)
    e4.observe(src2, dst2)

    oracle = mc.update_batch(mc.init(base), jnp.asarray(src),
                             jnp.asarray(dst), cfg=base)
    oracle = mc.update_batch(oracle, jnp.asarray(src2), jnp.asarray(dst2),
                             cfg=base)
    q = np.arange(40, dtype=np.int32)
    d0, p0, n0 = mc.query_threshold(oracle, jnp.asarray(q), 0.9, cfg=base,
                                    max_items=16)
    s4, d4, p4 = e4.topn(16)

    for m in (2, 8):
        em = engine_at(m)
        info = em.restore()
        assert info["mode"] == "reshard", info
        assert info["replayed"] == 1, info
        assert em.stats["route_dropped"] == 0, em.stats
        assert em.stats["deferred_new"] == 0, em.stats
        d, p, n = em.query(q)
        assert np.array_equal(np.asarray(d), np.asarray(d0)), m
        assert np.array_equal(np.asarray(p), np.asarray(p0)), m
        assert np.array_equal(np.asarray(n), np.asarray(n0)), m
        ms, md, mp = em.topn(16)
        assert np.array_equal(np.asarray(mp), np.asarray(p4)), m
        assert np.array_equal(np.asarray(md), np.asarray(d4)), m

    # same shard count takes the exact path (bit-identical arrays)
    e4b = engine_at(4)
    info = e4b.restore()
    assert info["mode"] == "exact", info
    a = e4.store.acquire().state
    b = e4b.store.acquire().state
    import jax
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    print("ELASTIC-PERSIST-OK")
    """
)


def test_elastic_reshard_restore_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT_ELASTIC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC-PERSIST-OK" in out.stdout
