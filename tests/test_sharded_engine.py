"""Sharded serving (DESIGN.md §9): kernel-routed shard bodies behind the
ShardedEngine, bucket-overflow semantics, and the cross-shard top-n merge.

Single-shard meshes run in-process (the routing machinery is fully exercised
with num_shards=1 — identity all_to_all, real buckets and counters); the
multi-shard path needs 8 fake host devices and runs in a subprocess because
the device count is fixed at first jax init (same pattern as test_sharded.py).
"""

import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.core.hashtable import EMPTY
from repro.kernels import ops
from repro.serve.engine import ShardedEngine, ShardedServeConfig


def _distinct_count_batch(n_src=12, n_dst=5, seed=0):
    """(src, dst) batch where src s carries dst d exactly (d+1) times — every
    per-row count is distinct, so priority order (and therefore query output)
    is unique and bit-exact comparisons are well-defined."""
    srcs, dsts = [], []
    for s in range(n_src):
        for d in range(n_dst):
            srcs += [s] * (d + 1)
            dsts += [d] * (d + 1)
    src = np.array(srcs, np.int32)
    dst = np.array(dsts, np.int32)
    perm = np.random.default_rng(seed).permutation(src.size)
    return src[perm], dst[perm]


# ---------------------------------------------------------------------------
# k-way merge (kernel layer)
# ---------------------------------------------------------------------------


def test_topn_merge_matches_flat_topk():
    rng = np.random.default_rng(3)
    s, m, n = 4, 6, 8
    probs = np.sort(rng.random((s, m)).astype(np.float32), axis=1)[:, ::-1]
    dsts = rng.integers(0, 100, (s, m)).astype(np.int32)
    srcs = rng.integers(0, 100, (s, m)).astype(np.int32)
    ms, md, mp = ops.topn_merge(jnp.asarray(probs.copy()), jnp.asarray(dsts),
                                jnp.asarray(srcs), n=n)
    mp = np.asarray(mp)
    assert np.all(np.diff(mp) <= 0)
    flat = np.sort(probs.reshape(-1))[::-1][:n]
    np.testing.assert_array_equal(mp, flat)
    # emitted ids belong to the emitted probability (same flat position)
    for i in range(n):
        hits = np.argwhere(probs == mp[i])
        assert any(dsts[a, b] == int(np.asarray(md)[i])
                   and srcs[a, b] == int(np.asarray(ms)[i])
                   for a, b in hits)


def test_topn_merge_dead_tail_is_empty():
    probs = jnp.asarray(np.array([[0.5, 0.0], [0.25, 0.0]], np.float32))
    dsts = jnp.asarray(np.array([[7, -1], [9, -1]], np.int32))
    srcs = jnp.asarray(np.array([[1, -1], [2, -1]], np.int32))
    ms, md, mp = ops.topn_merge(probs, dsts, srcs, n=4)
    np.testing.assert_array_equal(np.asarray(mp),
                                  np.array([0.5, 0.25, 0.0, 0.0], np.float32))
    np.testing.assert_array_equal(np.asarray(md), np.array([7, 9, EMPTY, EMPTY]))
    np.testing.assert_array_equal(np.asarray(ms), np.array([1, 2, EMPTY, EMPTY]))


# ---------------------------------------------------------------------------
# bucket-overflow semantics (fixed-capacity drop model)
# ---------------------------------------------------------------------------


def test_roomy_buckets_bit_identical_to_local_oracle():
    """With bucket_factor large enough the sharded path IS the local kernel
    path: zero drops, query outputs bit-identical to the unsharded oracle."""
    mesh = compat.make_mesh((1,), ("shard",))
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=1, bucket_factor=4.0)
    state = sh.init_sharded(scfg, mesh)
    upd = sh.make_update_fn(scfg, mesh)
    qry = sh.make_query_fn(scfg, mesh, threshold=0.9, max_items=8)
    src, dst = _distinct_count_batch()
    w = jnp.ones((src.size,), jnp.int32)
    state = upd(state, jnp.asarray(src), jnp.asarray(dst), w)
    assert int(jnp.sum(state.route_dropped)) == 0

    local = mc.update_batch(mc.init(base), jnp.asarray(src),
                            jnp.asarray(dst), cfg=base)
    q = jnp.arange(12, dtype=jnp.int32)
    d, p, n, qdrop = qry(state, q)
    d0, p0, n0 = mc.query_threshold(local, q, 0.9, cfg=base, max_items=8)
    assert int(jnp.sum(qdrop)) == 0
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n0))


def test_tiny_buckets_count_drops_and_stay_sorted():
    """A deliberately under-provisioned bucket factor drops items — counted,
    never corrupting: surviving answers stay sorted descending."""
    mesh = compat.make_mesh((1,), ("shard",))
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=1, bucket_factor=0.25)
    state = sh.init_sharded(scfg, mesh)
    upd = sh.make_update_fn(scfg, mesh)
    qry = sh.make_query_fn(scfg, mesh, threshold=0.9, max_items=8)
    src, dst = _distinct_count_batch()
    b = src.size
    cap = scfg.bucket_capacity(b)
    w = jnp.ones((b,), jnp.int32)
    state = upd(state, jnp.asarray(src), jnp.asarray(dst), w)
    # single shard: every item targets one bucket of exactly `cap` slots
    assert int(jnp.sum(state.route_dropped)) == b - cap

    q = jnp.arange(12, dtype=jnp.int32)
    d, p, n, qdrop = qry(state, q)
    q_cap = scfg.bucket_capacity(12)
    assert int(jnp.sum(qdrop)) == 12 - q_cap
    p = np.asarray(p)
    assert np.all(np.diff(p, axis=1) <= 1e-9), p   # descending per row
    # dropped queries answer EMPTY/0, never garbage
    dropped_rows = np.asarray(d)[q_cap:]
    assert np.all(dropped_rows == EMPTY)
    assert np.all(p[q_cap:] == 0.0)


def test_padding_consumes_no_bucket_capacity():
    """Inactive (-1) padding items must not displace real items or count as
    drops (they route to a nonexistent shard)."""
    mesh = compat.make_mesh((1,), ("shard",))
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=2)
    scfg = sh.ShardedConfig(base=base, num_shards=1, bucket_factor=1.0)
    state = sh.init_sharded(scfg, mesh)
    upd = sh.make_update_fn(scfg, mesh)
    # 8 real + 8 pad items with factor 1.0: bucket cap 16 holds all 8 real
    src = jnp.asarray(np.array([0] * 8 + [-1] * 8, np.int32))
    dst = jnp.asarray(np.array(list(range(8)) + [0] * 8, np.int32))
    state = upd(state, src, dst, jnp.ones((16,), jnp.int32))
    assert int(jnp.sum(state.route_dropped)) == 0
    assert int(jnp.sum(state.slabs.tot)) == 8


# ---------------------------------------------------------------------------
# ShardedEngine (serving boundary)
# ---------------------------------------------------------------------------


def _engine(bucket_factor=4.0, **cfg_kw):
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=1,
                            bucket_factor=bucket_factor)
    return ShardedEngine(ShardedServeConfig(sharded=scfg, **cfg_kw))


def test_engine_observe_query_topn_cycle():
    eng = _engine(decay_threshold=1 << 20)
    src, dst = _distinct_count_batch()
    eng.observe(src, dst)
    assert eng.store.version == 1          # publish happened
    assert eng.stats["updates"] == 1
    assert eng.stats["route_dropped"] == 0
    assert eng.stats["n_rows"] == 12

    d, p, n = eng.query(np.arange(12, dtype=np.int32))
    base = eng.cfg.sharded.base
    local = mc.update_batch(mc.init(base), jnp.asarray(src),
                            jnp.asarray(dst), cfg=base)
    d0, p0, n0 = mc.query_threshold(local, jnp.arange(12, dtype=jnp.int32),
                                    eng.cfg.threshold, cfg=base,
                                    max_items=eng.cfg.max_items)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n0))

    srcs, dsts, probs = eng.topn(6)
    probs = np.asarray(probs)
    assert np.all(np.diff(probs) <= 0)
    # oracle: global best prob is 5/15 for every row's heaviest dst
    np.testing.assert_allclose(probs[0], 5.0 / 15.0, rtol=1e-6)
    assert eng.stats["topn_dropped"] == 12 * 5 - 6


def test_engine_query_pads_ragged_batches():
    eng = _engine()
    src, dst = _distinct_count_batch(n_src=3)
    eng.observe(src, dst)
    d, p, n = eng.query(np.array([0, 1, 2], np.int32))  # not padded by caller
    assert d.shape[0] == 3
    assert eng.stats["query_dropped"] == 0


def test_engine_decay_runs_behind_writer_lock():
    eng = _engine(decay_threshold=4)
    src, dst = _distinct_count_batch()
    eng.observe(src, dst)                  # row totals 15 > 4 -> decay fires
    assert eng.stats["decay_steps"] >= 1


def test_engine_concurrent_observes_lose_no_updates():
    """Two overlapping observe() calls must serialise behind the writer lock
    — without it both publish from the same base and one batch vanishes."""
    eng = _engine()
    a = (np.repeat(np.arange(0, 6, dtype=np.int32), 4),
         np.tile(np.arange(4, dtype=np.int32), 6))
    b = (np.repeat(np.arange(6, 12, dtype=np.int32), 4),
         np.tile(np.arange(4, dtype=np.int32), 6))
    ts = [threading.Thread(target=eng.observe, args=batch)
          for batch in (a, b)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert eng.store.version == 2
    assert eng.stats["updates"] == 2
    d, p, n = eng.query(np.arange(12, dtype=np.int32), threshold=0.99)
    assert int(np.asarray(n).min()) == 4   # every src from both batches live


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (CI multidevice job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_engine_multi_shard_inprocess():
    """Real multi-shard routing in-process — only runs where the session
    already has multiple devices (the CI multidevice job), since the device
    count is fixed at first jax init."""
    shards = min(4, jax.device_count())
    base = mc.MCConfig(num_rows=128, capacity=16, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=shards, bucket_factor=4.0)
    eng = ShardedEngine(ShardedServeConfig(sharded=scfg,
                                           decay_threshold=1 << 20))
    src, dst = _distinct_count_batch(n_src=20)
    eng.observe(src, dst)
    assert eng.stats["route_dropped"] == 0
    d, p, n = eng.query(np.arange(20, dtype=np.int32))
    local = mc.update_batch(mc.init(base), jnp.asarray(src),
                            jnp.asarray(dst), cfg=base)
    d0, p0, n0 = mc.query_threshold(local, jnp.arange(20, dtype=jnp.int32),
                                    eng.cfg.threshold, cfg=base,
                                    max_items=eng.cfg.max_items)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d0))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(n0))
    _, _, probs = eng.topn(8)
    assert np.all(np.diff(np.asarray(probs)) <= 0)


# ---------------------------------------------------------------------------
# multi-shard engine on 8 fake devices (subprocess)
# ---------------------------------------------------------------------------

SCRIPT_8DEV = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import mcprioq as mc, sharded as sh
    from repro.serve.engine import ShardedEngine, ShardedServeConfig

    srcs, dsts = [], []
    for s in range(40):
        for d in range(6):
            srcs += [s] * (d + 1)
            dsts += [d] * (d + 1)
    src = np.array(srcs, np.int32)
    dst = np.array(dsts, np.int32)
    perm = np.random.default_rng(0).permutation(src.size)
    src, dst = src[perm], dst[perm]

    base = mc.MCConfig(num_rows=256, capacity=32, sort_passes=4)
    scfg = sh.ShardedConfig(base=base, num_shards=8, bucket_factor=4.0)
    eng = ShardedEngine(ShardedServeConfig(sharded=scfg,
                                           decay_threshold=1 << 20))
    eng.observe(src, dst)      # ragged batch: engine pads to a multiple of 8
    assert eng.stats["route_dropped"] == 0, eng.stats
    assert eng.stats["n_rows"] == 40

    local = mc.update_batch(mc.init(base), jnp.asarray(src),
                            jnp.asarray(dst), cfg=base)
    q = np.arange(40, dtype=np.int32)
    d, p, n = eng.query(q)
    d0, p0, n0 = mc.query_threshold(local, jnp.asarray(q), 0.9, cfg=base,
                                    max_items=16)
    assert np.array_equal(np.asarray(d), np.asarray(d0))
    assert np.array_equal(np.asarray(p), np.asarray(p0))
    assert np.array_equal(np.asarray(n), np.asarray(n0))
    assert eng.stats["query_dropped"] == 0

    ms, md, mp = eng.topn(16)
    mp = np.asarray(mp)
    assert np.all(np.diff(mp) <= 0), mp
    tot = np.int32(sum(d + 1 for d in range(6)))
    flat = np.sort(np.array(
        [np.float32(np.int32(d + 1)) / np.float32(tot)
         for s in range(40) for d in range(6)], np.float32))[::-1][:16]
    assert np.array_equal(mp, flat), (mp, flat)

    # under-provisioned buckets: drops counted, reads stay sorted
    tiny = ShardedEngine(ShardedServeConfig(
        sharded=sh.ShardedConfig(base=base, num_shards=8,
                                 bucket_factor=0.25),
        decay_threshold=1 << 20))
    tiny.observe(src, dst)
    assert tiny.stats["route_dropped"] > 0
    d, p, n = tiny.query(q)
    assert np.all(np.diff(np.asarray(p), axis=1) <= 1e-9)
    print("SHARDED-ENGINE-OK")
    """
)


def test_sharded_engine_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT_8DEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-ENGINE-OK" in out.stdout
