"""End-to-end inference-path equivalence (DESIGN.md §8).

The acceptance matrix of the fused read side: fused/unfused gather x
impl x chunks must be bit-identical on both queries for both
``use_dst_hash`` settings, and the one-shot draft-walk kernel must match
the k-dispatch scan oracle token-for-token.  (The hypothesis-driven
version of these properties lives in test_properties.py; this file keeps
deterministic coverage that runs without hypothesis installed.)
"""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core import speculative as spec


def _learned_state(cfg, seed=0, rounds=6, srcs=24, dsts=16, batch=96):
    state = mc.init(cfg)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        s = jnp.asarray(rng.integers(0, srcs, batch).astype(np.int32))
        d = jnp.asarray((rng.zipf(1.6, batch) % dsts).astype(np.int32))
        state = mc.update_batch(state, s, d, cfg=cfg)
    return state


@pytest.mark.parametrize("use_dst_hash", [False, True])
def test_fused_unfused_impl_chunks_bit_identical(use_dst_hash):
    """The full acceptance matrix on threshold + top-k queries."""
    base = mc.MCConfig(num_rows=64, capacity=16, sort_passes=2,
                       use_dst_hash=use_dst_hash)
    state = _learned_state(base)
    srcs = jnp.asarray(np.r_[np.arange(24), [999]].astype(np.int32))
    ref_out = ref_top = None
    for fused in (False, True):
        for impl in ("ref", "pallas"):
            for chunks in (1, 2, 4):
                cfg = dataclasses.replace(base, fused_query=fused, impl=impl,
                                          query_chunks=chunks)
                out = mc.query_threshold(state, srcs, 0.9, cfg=cfg,
                                         max_items=8)
                top = mc.query_topk(state, srcs, cfg=cfg, k=8)
                if ref_out is None:
                    ref_out, ref_top = out, top
                    continue
                tag = f"fused={fused},impl={impl},chunks={chunks}"
                for a, b in zip(ref_out, out):
                    assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), tag
                for a, b in zip(ref_top, top):
                    assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), tag


def test_fused_matches_inline_unfused_computation():
    """The fused path reproduces _ordered_rows + cdf_query exactly (the
    acceptance criterion, spelled out against the baseline pipeline)."""
    from repro.kernels import ops

    cfg = mc.MCConfig(num_rows=64, capacity=16, sort_passes=4)
    state = _learned_state(cfg, seed=3)
    srcs = jnp.arange(32, dtype=jnp.int32)
    c, d, tot, _ = mc._ordered_rows(state, srcs, cfg)
    want = ops.cdf_query(c, d, tot, 0.9, max_items=8, impl=cfg.impl)
    got = mc.query_threshold(state, srcs, 0.9, cfg=cfg, max_items=8)
    for a, b in zip(want, got):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_topk_has_no_sentinel_threshold():
    """threshold=None is the top-k contract: identical to keeping every
    live item, with no dependence on any unreachable float."""
    from repro.kernels import ops

    cfg = mc.MCConfig(num_rows=32, capacity=8, sort_passes=8)
    state = _learned_state(cfg, seed=5, srcs=12, dsts=6)
    srcs = jnp.arange(12, dtype=jnp.int32)
    dk, pk = mc.query_topk(state, srcs, cfg=cfg, k=8)
    c, d, tot, _ = mc._ordered_rows(state, srcs, cfg)
    want_d, want_p, want_n = ops.cdf_query(c, d, tot, None, max_items=8,
                                           impl=cfg.impl)
    assert np.asarray(dk).tobytes() == np.asarray(want_d).tobytes()
    assert np.asarray(pk).tobytes() == np.asarray(want_p).tobytes()
    # n reports every live item (nothing thresholded away)
    np.testing.assert_array_equal(np.asarray(want_n),
                                  np.asarray((c > 0).sum(axis=1)))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("k", [1, 4])
def test_draft_walk_matches_scan_oracle_end_to_end(impl, k):
    """spec.draft (one walk dispatch) == spec.draft_reference (k dispatches
    through query_topk), token-for-token, ok-for-ok."""
    ncfg = spec.NGramConfig(
        order=2, mc=mc.MCConfig(num_rows=512, capacity=16, sort_passes=2,
                                impl=impl))
    st = spec.init(ncfg)
    rng = np.random.default_rng(7)
    succ = rng.integers(0, 64, (64,)).astype(np.int32)
    toks = np.empty((4, 256), np.int32)
    toks[:, 0] = rng.integers(0, 64, 4)
    for i in range(1, 256):
        follow = succ[toks[:, i - 1]]
        noise = rng.integers(0, 64, 4)
        toks[:, i] = np.where(rng.random(4) < 0.85, follow, noise)
    st = spec.observe(st, jnp.asarray(toks), cfg=ncfg)
    ctx = jnp.asarray(np.concatenate(
        [toks[:, 40:42], np.full((2, 2), 31337, np.int32)]).astype(np.int32))
    got_t, got_o = spec.draft(st, ctx, cfg=ncfg, k=k)
    want_t, want_o = spec.draft_reference(st, ctx, cfg=ncfg, k=k)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))
    # unknown-context lanes are dead from step 0: no tokens, no oks
    assert not np.asarray(got_o)[-2:].any()
    assert not np.asarray(got_t)[-2:].any()


def test_draft_dead_lane_emits_zeros_after_failure():
    """Once ok goes False the lane stops: later tokens are 0, oks False
    (the walk does no work there — the early-stop satellite)."""
    ncfg = spec.NGramConfig(
        order=2, mc=mc.MCConfig(num_rows=64, capacity=8, sort_passes=2))
    st = spec.init(ncfg)
    # learn exactly one bigram chain 1->2->3, then a dead end
    seq = jnp.asarray([[1, 2, 3]], jnp.int32)
    st = spec.observe(st, seq, cfg=ncfg)
    draft, ok = spec.draft(st, jnp.asarray([[1, 2]], jnp.int32), cfg=ncfg, k=4)
    draft, ok = np.asarray(draft), np.asarray(ok)
    assert draft[0, 0] == 3 and ok[0, 0]
    assert not ok[0, 1:].any() and not draft[0, 1:].any()


def test_max_items_beyond_capacity_same_shape_both_impls():
    """max_items > C must yield (B, max_items) on every backend, padded
    with EMPTY/0 past C (a row holds at most C items)."""
    cfg = mc.MCConfig(num_rows=16, capacity=8, sort_passes=8)
    state = _learned_state(cfg, seed=9, srcs=8, dsts=6, batch=32)
    srcs = jnp.arange(8, dtype=jnp.int32)
    outs = {}
    for fused in (False, True):
        for impl in ("ref", "pallas"):
            c2 = dataclasses.replace(cfg, fused_query=fused, impl=impl)
            d, p, n = mc.query_threshold(state, srcs, 0.9, cfg=c2,
                                         max_items=16)
            assert d.shape == (8, 16) and p.shape == (8, 16), (fused, impl)
            outs[(fused, impl)] = (np.asarray(d), np.asarray(p),
                                   np.asarray(n))
    base = outs[(False, "ref")]
    for key, v in outs.items():
        for a, b in zip(base, v):
            assert a.tobytes() == b.tobytes(), key
    assert (base[0][:, 8:] == -1).all() and (base[1][:, 8:] == 0).all()


def test_bad_query_chunks_rejected_on_every_backend():
    """A chunk count that does not divide C fails identically on ref and
    pallas (validated once in auto_chunks, not at TPU trace time)."""
    cfg = mc.MCConfig(num_rows=16, capacity=8, sort_passes=1)
    state = _learned_state(cfg, seed=9, srcs=8, dsts=6, batch=32)
    srcs = jnp.arange(8, dtype=jnp.int32)
    for fused in (False, True):
        for impl in ("ref", "pallas"):
            c2 = dataclasses.replace(cfg, fused_query=fused, impl=impl,
                                     query_chunks=3)
            with pytest.raises(ValueError, match="query_chunks"):
                mc.query_threshold(state, srcs, 0.9, cfg=c2, max_items=4)
