"""Unit tests: optimizer, schedule, compression, checkpoint, fault tolerance,
data pipeline, sharding specs."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint import ckpt
from repro.data.pipeline import shard_batch
from repro.data.synthetic import MarkovGraphSampler, token_stream
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault_tolerance import (FailurePolicy, StepWatchdog,
                                           WatchdogConfig,
                                           plan_elastic_remesh)
from repro.sharding.specs import concretize, partition_specs
from repro.train import compression
from repro.train.train_step import TrainConfig, init_state, make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_weight_decay_and_clip():
    params = {"w": jnp.ones((4,)), "norm": {"scale": jnp.ones((4,))}}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=1e-9)
    state = adamw.init(params)
    g = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_params, _, m = adamw.update(g, state, params, cfg)
    # gradient clipped to ~0 -> only decay acts; 'scale' is exempt
    assert float(new_params["w"][0]) < 1.0
    assert float(new_params["norm"]["scale"][0]) == 1.0


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup_steps=10, total_steps=100)) == 0.0
    assert float(warmup_cosine(
        10, warmup_steps=10, total_steps=100)) == pytest.approx(1.0, abs=0.01)
    end = float(warmup_cosine(100, warmup_steps=10, total_steps=100))
    assert end == pytest.approx(0.1, abs=0.01)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_error_feedback_preserves_sum():
    rng = np.random.default_rng(0)
    grads = {"w": jnp.asarray(rng.normal(size=(1000,)) * 1e-3, jnp.float32)}
    state = compression.init(grads)
    # accumulated compressed grads + residual == accumulated true grads
    acc_true = np.zeros(1000)
    acc_comp = np.zeros(1000)
    for i in range(20):
        g = {"w": jnp.asarray(rng.normal(size=(1000,)) * 1e-3, jnp.float32)}
        cg, state, _ = compression.compress(g, state)
        acc_true += np.asarray(g["w"])
        acc_comp += np.asarray(cg["w"])
    drift = acc_true - acc_comp - (-np.asarray(state.residual["w"]))
    np.testing.assert_allclose(acc_comp + np.asarray(state.residual["w"]),
                               acc_true, rtol=1e-4, atol=1e-6)


def test_compression_quantisation_error_bounded():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(512,)), jnp.float32)}
    cg, state, _ = compression.compress(g, compression.init(g))
    err = np.abs(np.asarray(cg["w"]) - np.asarray(g["w"]))
    amax = np.abs(np.asarray(g["w"])).max()
    assert err.max() <= amax / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# checkpoint / restore / elastic
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    ckpt.save(tree, str(tmp_path), 7)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    got, step = ckpt.restore(like, str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_async_and_latest(tmp_path):
    tree = {"x": jnp.ones((8,))}
    t = ckpt.save_async(tree, str(tmp_path), 1)
    t.join()
    ckpt.save(tree, str(tmp_path), 5)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_restore_onto_new_sharding(tmp_path):
    """Elastic re-mesh: save unsharded, restore onto a mesh sharding."""
    mesh = make_host_mesh(1)
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    ckpt.save(tree, str(tmp_path), 0)
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data"))}
    like = {"w": jax.ShapeDtypeStruct((16,), jnp.float32)}
    got, _ = ckpt.restore(like, str(tmp_path), shardings=sh)
    assert got["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_watchdog_escalates_after_consecutive_slow_steps():
    fired = []
    wd = StepWatchdog(WatchdogConfig(deadline_s=1.0, max_consecutive_slow=3),
                      on_escalate=lambda: fired.append(1))
    for _ in range(2):
        assert not wd.observe(2.0)
    assert wd.observe(2.0)  # third consecutive -> escalate
    assert fired == [1]
    assert len(wd.slow_steps) == 3
    # resets after a fast step
    wd.observe(0.1)
    assert not wd.observe(2.0)


def test_elastic_remesh_plan():
    assert plan_elastic_remesh(512, 32, model_axis=16) == (30, 16)
    assert plan_elastic_remesh(512, 0, model_axis=16) == (32, 16)
    with pytest.raises(RuntimeError):
        plan_elastic_remesh(16, 8, model_axis=16)


def test_failure_policy():
    p = FailurePolicy()
    assert p.on_step_failure(1) == "retry"
    assert p.on_step_failure(2) == "restore"
    assert p.on_device_loss() == "remesh_restore"
    assert p.on_preemption_notice() == "checkpoint_now"


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_markov_sampler_matches_declared_distribution():
    s = MarkovGraphSampler(num_nodes=50, out_degree=8, zipf_s=1.5, seed=3)
    src, dst = s.sample_transitions(4000)
    # empirical top-1 dst of node src[0] should be the true argmax
    node = int(src[0])
    mask = src == node
    if mask.sum() > 100:
        vals, counts = np.unique(dst[mask], return_counts=True)
        emp_top = vals[np.argmax(counts)]
        true_dsts, true_p = s.true_probs(node)
        assert emp_top == true_dsts[0]


def test_token_stream_shapes():
    it = token_stream(128, 4, 16)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    assert b["targets"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["targets"][:, :-1]).all()


def test_shard_batch_on_host_mesh():
    mesh = make_host_mesh(1)
    out = shard_batch({"tokens": np.zeros((4, 8), np.int32)}, mesh)
    assert out["tokens"].shape == (4, 8)


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def test_concretize_strict_vs_lenient():
    """Strict mode drops non-divisible dims; lenient keeps them while GSPMD
    padding waste stays <= 50% (needs a >1 mesh axis -> subprocess)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro import compat
        from repro.sharding.specs import MODEL, concretize
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        P = jax.sharding.PartitionSpec
        # 3 % 4 != 0: strict drops; lenient pads to 4 (25% waste, kept)
        assert concretize((MODEL,), mesh, (3,), strict=True) == P(None)
        assert concretize((MODEL,), mesh, (3,), strict=False) == P("model")
        # 1 % 4: 75% padding waste -> dropped in both modes
        assert concretize((MODEL,), mesh, (1,), strict=False) == P(None)
        # divisible: kept in both
        assert concretize((MODEL,), mesh, (8,), strict=True) == P("model")
        print("CONCRETIZE-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-1500:]
    assert "CONCRETIZE-OK" in out.stdout


def test_partition_specs_cover_all_leaves():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("qwen2-7b")
    model = Model(cfg)
    params = model.abstract_params()
    mesh = make_host_mesh(1)
    specs = partition_specs(params, mesh, mode="train")
    n_p = len(jax.tree_util.tree_leaves(params))
    n_s = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert n_p == n_s


# ---------------------------------------------------------------------------
# train step integration (tiny)
# ---------------------------------------------------------------------------


def test_train_step_with_microbatches_and_compression():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("mamba2-130m")
    model = Model(cfg)
    tcfg = TrainConfig(microbatches=2, compress_grads=True, total_steps=10)
    state = init_state(model, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                               jnp.int32),
    }
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.opt.step) == 1
