"""Elastic scaling end-to-end: train on an 8-device mesh, checkpoint, lose
devices, resume bit-exactly on a 4-device mesh (fault-tolerance deliverable).
"""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro import compat
    from repro.checkpoint import ckpt
    from repro.configs import smoke_config
    from repro.models import Model
    from repro.runtime.fault_tolerance import plan_elastic_remesh
    from repro.sharding.specs import partition_specs
    from repro.train.train_step import TrainConfig, abstract_state, \\
        init_state, make_train_step
    from repro.data.synthetic import token_stream

    import dataclasses
    # f32 so the cross-mesh comparison sees mechanism, not bf16 reduction
    # reorder noise
    cfg = dataclasses.replace(smoke_config("qwen2-7b"), dtype="float32")
    model = Model(cfg)
    tcfg = TrainConfig(total_steps=10)
    ckdir = tempfile.mkdtemp()

    def mesh_of(data, model_ax):
        return compat.make_mesh((data, model_ax), ("data", "model"))

    stream = token_stream(cfg.vocab_size, 8, 32, seed=7)
    batches = [{k: jnp.asarray(v) for k, v in next(stream).items()}
               for _ in range(4)]

    # --- phase 1: 4x2 mesh, 2 steps, checkpoint --------------------------
    mesh = mesh_of(4, 2)
    with mesh:
        shapes = abstract_state(model, tcfg)
        sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            partition_specs(shapes, mesh, mode="train"))
        step = jax.jit(make_train_step(model, tcfg),
                       in_shardings=(sh, None), out_shardings=(sh, None))
        state = jax.device_put(init_state(model, jax.random.key(0), tcfg), sh)
        for b in batches[:2]:
            state, _ = step(state, b)
        ckpt.save(state, ckdir, 2)
        # reference: continue on the SAME mesh
        ref = state
        for b in batches[2:]:
            ref, _ = step(ref, b)
        ref_host = jax.tree_util.tree_map(lambda x: np.asarray(x), ref)

    # --- phase 2: "2 devices failed" -> 2x2 mesh, restore + continue -----
    d, m = plan_elastic_remesh(total_devices=8, failed_devices=4,
                               model_axis=2)
    assert (d, m) == (2, 2)
    mesh2 = mesh_of(d, m)
    with mesh2:
        sh2 = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh2, s),
            partition_specs(shapes, mesh2, mode="train"))
        restored, start = ckpt.restore(shapes, ckdir, shardings=sh2)
        assert start == 2
        step2 = jax.jit(make_train_step(model, tcfg),
                        in_shardings=(sh2, None), out_shardings=(sh2, None))
        for b in batches[2:]:
            restored, _ = step2(restored, b)

    got = jax.tree_util.tree_map(lambda x: np.asarray(x), restored)
    for a, b in zip(jax.tree_util.tree_leaves(ref_host),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)
    print("ELASTIC-OK")
""")


def test_elastic_remesh_training_resumes_exactly():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, (out.stdout[-800:], out.stderr[-2500:])
    assert "ELASTIC-OK" in out.stdout
