"""Attention unit tests vs a naive O(S^2) oracle: GQA grouping, causal and
sliding-window masks, chunked online softmax, linear + ring caches."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import attention as at


def naive_attention(q, k, v, *, causal, window, q_pos, kv_pos, kv_valid=None):
    """Direct softmax attention with GQA broadcast. All f32."""
    b, sq, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    out = np.zeros((b, sq, h, d), np.float32)
    for bi in range(b):
        for hi in range(h):
            ki = hi // g
            s = (q[bi, :, hi] @ k[bi, :, ki].T) / np.sqrt(d)  # [sq, t]
            mask = np.ones((sq, t), bool)
            if causal:
                mask &= kv_pos[bi][None, :] <= q_pos[bi][:, None]
            if window > 0:
                mask &= kv_pos[bi][None, :] > q_pos[bi][:, None] - window
            if kv_valid is not None:
                mask &= kv_pos[bi][None, :] < kv_valid[bi]
            mask &= kv_pos[bi][None, :] >= 0
            s = np.where(mask, s, -1e30)
            p = np.exp(s - s.max(-1, keepdims=True))
            p = p / np.maximum(p.sum(-1, keepdims=True), 1e-30)
            out[bi, :, hi] = p @ v[bi, :, ki]
    return out


@pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 3), (False, 0)])
@pytest.mark.parametrize("kv_chunk", [4, 16, 64])
def test_attend_matches_naive(h, kvh, causal, window, kv_chunk):
    rng = np.random.default_rng(h * 100 + window + kv_chunk)
    b, s, d = 2, 16, 8
    q = rng.normal(size=(b, s, h, d)).astype(np.float32)
    k = rng.normal(size=(b, s, kvh, d)).astype(np.float32)
    v = rng.normal(size=(b, s, kvh, d)).astype(np.float32)
    pos = np.broadcast_to(np.arange(s), (b, s)).astype(np.int32)
    got = at.attend(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                    causal=causal, window=window,
                    q_positions=jnp.asarray(pos),
                    kv_positions=jnp.asarray(pos), kv_chunk=kv_chunk)
    want = naive_attention(q, k, v, causal=causal, window=window,
                           q_pos=pos, kv_pos=pos)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_ring_cache_wraps_and_masks():
    """Ring cache of size W: positions older than the window disappear,
    recent W positions survive the wrap-around."""
    b, w, kvh, d = 1, 4, 1, 8
    cache = at.init_cache(b, w, kvh, d, jnp.float32, ring=True)
    rng = np.random.default_rng(0)
    keys, vals = [], []
    for pos in range(7):  # wraps once (7 > 4)
        kn = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
        vn = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
        keys.append(kn)
        vals.append(vn)
        cache = at.cache_insert(cache, jnp.asarray(kn), jnp.asarray(vn),
                                jnp.asarray([[pos]], jnp.int32))
    # slots must hold positions 3..6
    assert sorted(np.asarray(cache.positions)[0].tolist()) == [3, 4, 5, 6]
    # decode at pos 7 with window 4 sees positions 4,5,6 (+ self insert at 7)
    q = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
    kn = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
    vn = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
    cache = at.cache_insert(cache, jnp.asarray(kn), jnp.asarray(vn),
                            jnp.asarray([[7]], jnp.int32))
    got = at.decode_attend(jnp.asarray(q), cache, window=w,
                           q_positions=jnp.asarray([[7]], jnp.int32))
    # oracle over the full history with the same window
    k_all = np.concatenate(keys + [kn], axis=1)
    v_all = np.concatenate(vals + [vn], axis=1)
    pos_all = np.arange(8, dtype=np.int32)[None, :]
    want = naive_attention(q, k_all, v_all, causal=True, window=w,
                           q_pos=np.asarray([[7]], np.int32), kv_pos=pos_all)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_empty_cache_slots_are_masked():
    b, t, kvh, d = 1, 8, 1, 4
    cache = at.init_cache(b, t, kvh, d, jnp.float32)
    rng = np.random.default_rng(1)
    kn = rng.normal(size=(b, 2, kvh, d)).astype(np.float32)
    vn = rng.normal(size=(b, 2, kvh, d)).astype(np.float32)
    cache = at.cache_insert(cache, jnp.asarray(kn), jnp.asarray(vn),
                            jnp.asarray([[0, 1]], jnp.int32))
    q = rng.normal(size=(b, 1, kvh, d)).astype(np.float32)
    got = at.decode_attend(jnp.asarray(q), cache,
                           q_positions=jnp.asarray([[1]], jnp.int32))
    want = naive_attention(q, kn, vn, causal=True, window=0,
                           q_pos=np.asarray([[1]], np.int32),
                           kv_pos=np.asarray([[0, 1]], np.int32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_rope_relative_property():
    """RoPE: attention scores depend only on relative positions."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 1, 2, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 2, 16)).astype(np.float32))
    def score(qp, kp):
        qr = at.apply_rope(q, jnp.asarray([[qp]]), 10_000.0)
        kr = at.apply_rope(k, jnp.asarray([[kp]]), 10_000.0)
        return float(jnp.sum(qr[0, 0, 0] * kr[0, 0, 0]))
    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_sp_insert_attend_matches_plain_on_host_mesh():
    """shard_map SP path == plain insert+attend (1-device mesh degenerate)."""
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(3)
    b, t, kvh, h, d = 2, 16, 2, 4, 8
    cache = at.init_cache(b, t, kvh, d, jnp.float32)
    kn = rng.normal(size=(b, 4, kvh, d)).astype(np.float32)
    vn = rng.normal(size=(b, 4, kvh, d)).astype(np.float32)
    pos0 = np.asarray([[0, 1, 2, 3]] * b, np.int32)
    cache = at.cache_insert(cache, jnp.asarray(kn), jnp.asarray(vn),
                            jnp.asarray(pos0))
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)).astype(np.float32))
    k1 = jnp.asarray(rng.normal(size=(b, 1, kvh, d)).astype(np.float32))
    v1 = jnp.asarray(rng.normal(size=(b, 1, kvh, d)).astype(np.float32))
    qp = jnp.asarray([[4]] * b, jnp.int32)

    plain_cache = at.cache_insert(cache, k1, v1, qp)
    want = at.decode_attend(q, plain_cache, q_positions=qp)
    with mesh:
        got, sp_cache = at.sp_insert_attend(q, k1, v1, cache,
                                            q_positions=qp, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(sp_cache.k),
                               np.asarray(plain_cache.k), rtol=1e-6)
