"""Fault-matrix tests (DESIGN.md §12): every registered failpoint is
injected by at least one test asserting its retry / escalation /
degraded-mode contract, with the correct counters.

``FAULT_MATRIX`` below is the normative site -> injection-test table:
mcqlint rule MCQ-R001 statically requires every ``failpoint("name")``
call site in src/ to be named by this file, and
:func:`test_fault_matrix_is_total` closes the loop at runtime — the
table's keys must equal ``FAILPOINT_CATALOG`` and every named test must
exist here.  Engines run with ``num_shards=1`` (identity all_to_all —
the full routing machinery, single device); multi-shard degradation runs
under a device-count skipif, exercised by the CI multi-device matrix.
"""

import errno
import os
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import faults
from repro.checkpoint import ckpt
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.persist import snapshot as snapshot_io
from repro.persist.wal import SegmentRotationError, WriteAheadLog
from repro.runtime.fault_tolerance import (EngineWriteUnavailable,
                                           RetryBudgetExceeded, RetryPolicy,
                                           ShardDispatchError, ShardHealth,
                                           call_with_retry,
                                           classify_io_error,
                                           shard_from_exception)
from repro.serve.engine import (Engine, ServeConfig, ShardedEngine,
                                ShardedServeConfig)

#: tight backoff so escalation tests finish in milliseconds
FAST = RetryPolicy(max_attempts=3, base_delay_s=1e-4, max_delay_s=1e-3)

#: the fault-matrix table: every FAILPOINT_CATALOG site -> the test that
#: injects it (MCQ-R001 checks src-side sites against this file's text;
#: test_fault_matrix_is_total checks the table itself is closed)
FAULT_MATRIX = {
    "wal.segment_open": "test_wal_segment_open_transient_is_retried",
    "wal.append.write": "test_wal_append_enospc_poisons_write_path",
    "wal.append.fsync": "test_wal_fsync_failure_truncates_then_same_seq",
    "wal.rotate": "test_wal_rotate_failure_policy_dependent",
    "snapshot.meta_write": "test_checkpoint_fault_is_exception_safe",
    "snapshot.arrays_write": "test_checkpoint_fault_is_exception_safe",
    "snapshot.manifest_commit": "test_checkpoint_fault_is_exception_safe",
    "snapshot.io_thread": "test_async_snapshot_worker_death_is_counted",
    "snapshot.restore_read": "test_restore_read_fault_raises_cleanly",
    "engine.apply": "test_apply_exhaustion_poisons_and_restore_heals",
    "engine.publish": "test_publish_transient_fault_retries_transparently",
    "engine.query_dispatch": "test_query_dispatch_fault_degrades_not_raises",
    "engine.topn_dispatch": "test_topn_dispatch_fault_degrades_not_raises",
    "engine.learn": "test_engine_learn_failpoint_cuts_before_publish",
}


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.reset()
    faults.set_observer(None)
    yield
    faults.reset()
    faults.set_observer(None)


def _engine(tmp, *, wal=True, snap=True, shards=1, factor=2.0,
            fsync="always", **kw):
    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=64, capacity=8),
                            num_shards=shards, bucket_factor=factor)
    cfg = ShardedServeConfig(
        sharded=scfg,
        snapshot_dir=os.path.join(tmp, "snap") if snap else None,
        wal_dir=os.path.join(tmp, "wal") if wal else None,
        wal_fsync=fsync, retry=FAST, **kw)
    return ShardedEngine(cfg)


def _batch(seed=0, n=16, rows=64):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, rows, n).astype(np.int32),
            rng.integers(0, rows, n).astype(np.int32))


def _query_state(eng, rows=16):
    d, p, n = eng.query(np.arange(rows))
    return np.asarray(d), np.asarray(p), np.asarray(n)


# ---------------------------------------------------------------------------
# the table is total
# ---------------------------------------------------------------------------


def test_fault_matrix_is_total():
    """Every catalog site appears in the matrix and every named test
    exists — a new failpoint cannot land without a fault-matrix entry."""
    assert set(FAULT_MATRIX) == set(faults.FAILPOINT_CATALOG)
    for site, test_name in FAULT_MATRIX.items():
        fn = globals().get(test_name)
        assert callable(fn), f"{site}: matrix names missing test {test_name}"


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_registry_rejects_unknown_sites():
    with pytest.raises(KeyError):
        faults.arm("not.a.site", OSError())


def test_registry_triggers_nth_every_prob_count():
    log = []
    faults.arm("engine.apply", lambda ctx: log.append("nth"),
               trigger=("nth", 2))
    for _ in range(4):
        faults.failpoint("engine.apply")
    assert log == ["nth"]                      # exactly the 2nd hit
    faults.reset()

    faults.arm("engine.apply", lambda ctx: log.append("every"),
               trigger=("every", 2))
    for _ in range(6):
        faults.failpoint("engine.apply")
    assert log.count("every") == 3             # hits 2, 4, 6
    faults.reset()

    faults.arm("engine.apply", lambda ctx: log.append("cap"), count=2)
    for _ in range(5):
        faults.failpoint("engine.apply")
    assert log.count("cap") == 2               # count cap holds
    assert faults.fired("engine.apply") == 2
    assert faults.hits("engine.apply") == 5    # hits keep counting
    faults.reset()

    # prob trigger is deterministic from its seed
    def fires(seed):
        faults.reset()
        got = []
        faults.arm("engine.apply", lambda ctx: got.append(1),
                   trigger=("prob", 0.5, seed))
        for _ in range(32):
            faults.failpoint("engine.apply")
        return len(got)

    assert fires(7) == fires(7)
    assert 0 < fires(7) < 32


def test_registry_zero_cost_when_disarmed():
    """Disarmed, the site is one bool read: no hits recorded at all."""
    faults.failpoint("engine.apply")
    assert faults.hits("engine.apply") == 0
    assert faults.snapshot() == {}


def test_registry_observer_sees_every_hit_before_actions():
    seen = []
    faults.set_observer(lambda name, ctx: seen.append((name, dict(ctx))))
    faults.failpoint("engine.apply", items=3)
    faults.arm("engine.apply", faults.FaultInjected("engine.apply"))
    with pytest.raises(faults.FaultInjected):
        faults.failpoint("engine.apply", items=4)
    assert [s[0] for s in seen] == ["engine.apply", "engine.apply"]
    assert seen[1][1] == {"items": 4}          # observer ran before raise


def test_registry_env_arming_round_trip():
    n = faults.arm_from_env(
        "wal.append.fsync=raise:28@nth:2;engine.apply=sleep:0")
    assert n == 2
    faults.failpoint("wal.append.fsync")       # 1st hit: no fire
    with pytest.raises(faults.FaultInjected) as ei:
        faults.failpoint("wal.append.fsync")   # 2nd hit: fires
    assert ei.value.errno == errno.ENOSPC
    faults.failpoint("engine.apply")           # sleep:0 action runs
    with pytest.raises(ValueError):
        faults.arm_from_env("wal.rotate=explode")
    with pytest.raises(ValueError):
        faults.arm_from_env("wal.rotate")      # missing action


# ---------------------------------------------------------------------------
# retry ladder + health map units
# ---------------------------------------------------------------------------


def test_retry_ladder_classification_and_budget():
    assert classify_io_error(OSError(errno.ENOSPC, "")) == "persistent"
    assert classify_io_error(OSError(errno.EIO, "")) == "transient"
    assert classify_io_error(RuntimeError()) == "transient"

    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError(errno.EIO, "flake")
        return "ok"

    assert call_with_retry(flaky, policy=FAST, sleep=lambda s: None) == "ok"
    assert len(calls) == 3

    # persistent: no second attempt
    calls.clear()

    def full():
        calls.append(1)
        raise OSError(errno.ENOSPC, "disk full")

    with pytest.raises(OSError):
        call_with_retry(full, policy=FAST, sleep=lambda s: None)
    assert len(calls) == 1

    # exhausted: RetryBudgetExceeded chains the last fault
    calls.clear()

    def always():
        calls.append(1)
        raise OSError(errno.EIO, "still broken")

    with pytest.raises(RetryBudgetExceeded) as ei:
        call_with_retry(always, policy=FAST, sleep=lambda s: None)
    assert len(calls) == FAST.max_attempts
    assert isinstance(ei.value.__cause__, OSError)

    # delays: capped exponential, deterministic per seed
    a = list(RetryPolicy(max_attempts=5, seed=3).delays())
    b = list(RetryPolicy(max_attempts=5, seed=3).delays())
    assert a == b and len(a) == 4
    assert all(d <= RetryPolicy.max_delay_s for d in a)


def test_shard_health_strikes_defer_and_heal():
    h = ShardHealth(4, strike_limit=2, deferred_cap=8)
    assert not h.record_failure(1)
    assert h.record_failure(1)                 # 2nd strike: down
    assert h.down == frozenset({1}) and h.degraded
    assert list(h.healthy_mask()) == [True, False, True, True]
    h.record_failure(2)
    h.record_success(2)                        # success clears strikes
    assert not h.record_failure(2)

    src = np.arange(5, dtype=np.int32)
    assert h.defer(1, src, src, src)
    assert not h.defer(1, src, src, src)       # 10 > cap of 8: dropped
    assert h.stats() == {"shards_down": 1, "deferred_writes": 5}
    batches = h.heal(1)
    assert len(batches) == 1 and batches[0][0].size == 5
    assert h.stats() == {"shards_down": 0, "deferred_writes": 0}


def test_shard_health_dump_load_requeue_round_trip():
    """The health map is recovery state (A15): dump() -> JSON -> load()
    must reproduce the down-set and the deferred queue in order, and
    requeue() must put a failed heal's remainder back at the FRONT,
    cap-exempt."""
    import json

    h = ShardHealth(4, deferred_cap=64)
    h.mark_down(1)
    h.mark_down(3)
    a = np.arange(3, dtype=np.int32)
    assert h.defer(1, a, a + 1, None)
    assert h.defer(1, a + 10, a + 11, a * 0 + 2)
    assert h.defer(3, a, a, a)
    image = json.loads(json.dumps(h.dump()))   # must survive JSON

    h2 = ShardHealth(4, deferred_cap=64)
    h2.load(image)
    assert h2.down == frozenset({1, 3})
    assert h2.stats() == {"shards_down": 2, "deferred_writes": 9}
    b1 = h2.heal(1)
    assert len(b1) == 2
    np.testing.assert_array_equal(b1[0][0], a)       # arrival order kept
    assert b1[0][2] is None                          # None w round-trips
    np.testing.assert_array_equal(b1[1][2], a * 0 + 2)

    h2.requeue(1, b1[1:])                      # un-applied remainder back
    assert h2.stats()["deferred_writes"] == 6
    again = h2.heal(1)
    assert len(again) == 1
    np.testing.assert_array_equal(again[0][0], a + 10)


# ---------------------------------------------------------------------------
# WAL fsync-failure modes (satellite: replay stops at last durable record)
# ---------------------------------------------------------------------------


def test_wal_fsync_failure_truncates_then_same_seq(tmp_path):
    """fsync (policy=always) raising EIO: the record is scrubbed, the
    retry lands the SAME seq, and replay sees each batch exactly once."""
    wal = WriteAheadLog(str(tmp_path), fsync="always")
    wal.append([1], [2])
    faults.arm("wal.append.fsync", OSError(errno.EIO, "flake"), count=1)
    with pytest.raises(OSError):
        wal.append([3], [4])
    assert wal.append([3], [4]) == 1           # same seq after scrub
    recs = list(wal.replay())
    assert [r[0] for r in recs] == [0, 1]
    assert [int(r[1][0]) for r in recs] == [1, 3]
    wal.close()


def test_wal_append_torn_write_replay_stops_at_durable(tmp_path):
    """A write that lands partial bytes then dies (torn append): replay
    must stop at the last durable record, never crash, and the resumed
    writer continues through the tear."""
    wal = WriteAheadLog(str(tmp_path), fsync="never")
    wal.append([1], [1])

    def tear(ctx):
        ctx["fh"].write(ctx["record"][: len(ctx["record"]) // 2])
        raise OSError(errno.EIO, "died mid-write")

    faults.arm("wal.append.write", tear, count=1)
    with pytest.raises(OSError):
        wal.append([2], [2])
    # fresh handle on the same directory: sees only the durable prefix
    ro = WriteAheadLog(str(tmp_path), fsync="never")
    assert [r[0] for r in ro.replay()] == [0]
    assert ro.next_seq == 1                    # resumes at the torn seq
    ro.append([2], [2])
    assert [r[0] for r in ro.replay()] == [0, 1]
    ro.close()
    wal.close()


def test_wal_append_enospc_abandons_segment_and_recovers(tmp_path):
    """ENOSPC mid-append with the truncate also failing: the segment is
    abandoned; the next append opens a fresh segment at the same seq and
    replay stays contiguous across the two files."""
    wal = WriteAheadLog(str(tmp_path), fsync="never")
    wal.append([1], [1])

    def nospace(ctx):
        ctx["fh"].close()                      # truncate(start) now fails
        raise OSError(errno.ENOSPC, "disk full")

    faults.arm("wal.append.write", nospace, count=1)
    with pytest.raises(OSError):
        wal.append([2], [2])
    assert wal.append([2], [2]) == 1
    segs = sorted(p for p in os.listdir(tmp_path) if p.endswith(".seg"))
    assert len(segs) == 2                      # fresh segment, same seq
    assert [r[0] for r in wal.replay()] == [0, 1]
    wal.close()


def test_wal_rotate_failure_policy_dependent(tmp_path):
    """Rotation failing after an acknowledged append: under 'always' every
    record is already individually durable, so the failure is swallowed
    and counted (raising would make the engine retry an applied batch
    under a new seq); under 'rotate' the rotation fsync IS the segment's
    durability point, so it escalates unretryably instead of silently
    acknowledging a segment that may vanish on power loss."""
    wal = WriteAheadLog(str(tmp_path / "a"), segment_records=1,
                        fsync="always")
    faults.arm("wal.rotate", OSError(errno.EIO, "close failed"), count=1)
    assert wal.append([1], [1]) == 0           # no raise
    assert wal.io_errors == 1
    assert wal.append([2], [2]) == 1
    assert [r[0] for r in wal.replay()] == [0, 1]
    wal.close()
    faults.reset()

    wal = WriteAheadLog(str(tmp_path / "r"), segment_records=1,
                        fsync="rotate")
    faults.arm("wal.rotate", OSError(errno.EIO, "fsync failed"), count=1)
    with pytest.raises(SegmentRotationError) as ei:
        wal.append([1], [1])
    # no retry: the ladder must escalate, never re-log under a new seq
    assert classify_io_error(ei.value) == "persistent"
    assert wal.io_errors == 1
    # the in-cache record is still readable and the seq chain continues
    assert wal.append([2], [2]) == 1
    assert [r[0] for r in wal.replay()] == [0, 1]
    wal.close()


def test_wal_rotate_escalation_poisons_engine_under_rotate_policy(tmp_path):
    """Engine end to end under policy 'rotate': a failed rotation poisons
    the write path (the batch is NOT applied past an uncertain durability
    point) and restore() re-aligns state with what actually survived."""
    src0, dst0 = _batch(0)
    eng = _engine(str(tmp_path), fsync="rotate")
    eng.wal.segment_records = 1
    faults.arm("wal.rotate", OSError(errno.EIO, "fsync failed"), count=1)
    with pytest.raises(EngineWriteUnavailable):
        eng.observe(src0, dst0)
    faults.reset()
    assert not eng.write_available
    assert eng._seq == -1                      # never advanced
    assert eng.stats["updates"] == 0           # nothing applied
    for t in list(eng._io_threads):            # poison checkpoint-now
        t.join()
    eng.restore()                              # replays the durable record
    assert eng.write_available and eng._seq == 0
    healed = _query_state(eng)
    eng.close()

    oracle = _engine(str(tmp_path) + "_oracle")
    oracle.observe(src0, dst0)
    for a, b in zip(healed, _query_state(oracle)):
        np.testing.assert_array_equal(a, b)
    oracle.close()


def test_wal_segment_open_transient_is_retried(tmp_path):
    """segment_open raising is surfaced to the appender (nothing durable,
    nothing applied) and a bare retry succeeds — the caller's ladder owns
    the backoff."""
    wal = WriteAheadLog(str(tmp_path), fsync="never")
    faults.arm("wal.segment_open", OSError(errno.EIO, "transient"),
               count=1)
    with pytest.raises(OSError):
        wal.append([1], [1])
    assert wal.append([1], [1]) == 0
    assert [r[0] for r in wal.replay()] == [0]
    wal.close()


# ---------------------------------------------------------------------------
# engine write-path escalation (satellite: exception safety)
# ---------------------------------------------------------------------------


def test_wal_append_enospc_poisons_write_path(tmp_path):
    """Persistent WAL fault mid-observe: the writer lock is released, no
    half-applied epoch is published — query answers and counter_stats are
    bit-identical to the pre-step state — and writes raise
    EngineWriteUnavailable until restore() heals."""
    eng = _engine(str(tmp_path))
    src, dst = _batch(0)
    eng.observe(src, dst)
    before_q = _query_state(eng)
    before_stats = dict(eng.stats)

    faults.arm("wal.append.write", OSError(errno.ENOSPC, "disk full"))
    with pytest.raises(EngineWriteUnavailable):
        eng.observe(*_batch(1))
    faults.reset()

    assert not eng.write_available
    assert eng._seq == 0                       # never advanced
    after_q = _query_state(eng)
    for a, b in zip(before_q, after_q):
        np.testing.assert_array_equal(a, b)
    for key, val in before_stats.items():
        if key in ("queries",):                # reads above are counted
            continue
        if key == "write_errors":
            assert eng.stats[key] == val + 1
        elif key == "snapshots":
            # poison took a best-effort checkpoint-now
            assert eng.stats[key] >= val
        else:
            assert eng.stats[key] == val, key
    # writer lock was released: further writes fail-fast, reads serve
    with pytest.raises(EngineWriteUnavailable):
        eng.observe(*_batch(2))
    _query_state(eng)

    eng.restore()
    assert eng.write_available
    eng.observe(*_batch(3))                    # writes re-open
    eng.close()


def test_restore_drains_inflight_poison_checkpoint(tmp_path):
    """The poison path's best-effort checkpoint-now commits on a worker
    thread; an immediate restore() must join it rather than scan the
    snapshot directory past a still-committing step."""
    eng = _engine(str(tmp_path))
    eng.observe(*_batch(0))
    faults.arm("wal.append.write", OSError(errno.ENOSPC, "disk full"))
    faults.arm("snapshot.io_thread", 0.3)      # slow the worker's commit
    with pytest.raises(EngineWriteUnavailable):
        eng.observe(*_batch(1))
    faults.reset()                             # worker already mid-sleep
    eng.restore()                              # must join, not FileNotFound
    assert eng.write_available
    eng.observe(*_batch(2))
    eng.close()


def test_wal_transient_fault_is_retried_with_counters(tmp_path):
    """One EIO flake on the append write: the ladder absorbs it — same
    seq, batch applied once, wal_retries counts the backoff round."""
    eng = _engine(str(tmp_path))
    faults.arm("wal.append.write", OSError(errno.EIO, "flake"), count=1)
    eng.observe(*_batch(0))
    assert eng.stats["wal_retries"] == 1
    assert eng.stats["updates"] == 1 and eng._seq == 0
    assert eng.write_available
    eng.close()


def test_apply_exhaustion_poisons_and_restore_heals(tmp_path):
    """Apply faulting past the retry budget AFTER a durable append: the
    record is a ghost (durable, unapplied) — the write path poisons, and
    restore() replays the ghost so the final state equals an engine that
    never faulted."""
    src0, dst0 = _batch(0)
    src1, dst1 = _batch(1)

    eng = _engine(str(tmp_path))
    eng.observe(src0, dst0)
    eng.checkpoint()
    faults.arm("engine.apply", RuntimeError("device lost"))
    with pytest.raises(EngineWriteUnavailable):
        eng.observe(src1, dst1)
    faults.reset()
    assert not eng.write_available
    assert eng.stats["apply_retries"] == FAST.max_attempts - 1
    assert eng._seq == 0 and eng.wal.last_seq == 1  # the ghost record

    result = eng.restore()
    assert result["replayed"] >= 1 and eng._seq == 1
    healed_q = _query_state(eng)
    eng.close()

    # oracle: the same two batches with no fault anywhere
    oracle = _engine(str(tmp_path) + "_oracle")
    oracle.observe(src0, dst0)
    oracle.observe(src1, dst1)
    oracle_q = _query_state(oracle)
    oracle.close()
    for a, b in zip(healed_q, oracle_q):
        np.testing.assert_array_equal(a, b)


def test_apply_fault_without_wal_raises_and_leaves_state(tmp_path):
    """No WAL: an exhausted apply re-raises (nothing is durable, nothing
    forked) and the state is exactly the pre-step state."""
    eng = _engine(str(tmp_path), wal=False, snap=False)
    eng.observe(*_batch(0))
    before = _query_state(eng)
    faults.arm("engine.apply", RuntimeError("device lost"))
    with pytest.raises(RetryBudgetExceeded):
        eng.observe(*_batch(1))
    faults.reset()
    assert eng.write_available                 # no fork: not poisoned
    for a, b in zip(before, _query_state(eng)):
        np.testing.assert_array_equal(a, b)
    eng.observe(*_batch(1))                    # plain retry by the caller
    eng.close()


def test_publish_transient_fault_retries_transparently(tmp_path):
    """engine.publish cuts before the epoch swap: a one-shot fault there
    is retried by the ladder and the batch lands exactly once (the
    host-side plan is only committed after publish succeeds)."""
    eng = _engine(str(tmp_path))
    faults.arm("engine.publish", RuntimeError("flake"), count=1)
    eng.observe(*_batch(0))
    assert eng.stats["apply_retries"] == 1
    assert eng.stats["updates"] == 1           # applied exactly once
    faulted = _query_state(eng)
    eng.close()

    # the faulted engine's post-retry state matches a no-fault oracle
    oracle = _engine(str(tmp_path) + "_oracle")
    oracle.observe(*_batch(0))
    for a, b in zip(_query_state(oracle), faulted):
        np.testing.assert_array_equal(a, b)
    oracle.close()


def test_engine_learn_failpoint_cuts_before_publish():
    """The unsharded Engine's learn step: a fault at engine.learn aborts
    the whole acquire->observe->publish cycle, so the drafter snapshot
    and stats are untouched."""
    from types import SimpleNamespace
    from repro.core import speculative as spec

    stub = SimpleNamespace(prefill=lambda *a: None,
                           decode_step=lambda *a: None,
                           extend_step=lambda *a: None)
    ncfg = spec.NGramConfig(order=2,
                            mc=mc.MCConfig(num_rows=128, capacity=8))
    eng = Engine(stub, None, ServeConfig(ngram=ncfg))
    history = np.arange(12, dtype=np.int32).reshape(2, 6)
    eng._learn(history)
    version = eng.drafter_store.version
    stats_before = dict(eng.stats)

    faults.arm("engine.learn", RuntimeError("learner fault"))
    with pytest.raises(RuntimeError):
        eng._learn(history)
    faults.reset()
    assert eng.drafter_store.version == version    # nothing published
    assert eng.stats == stats_before
    eng._learn(history)                            # lock was released
    assert eng.drafter_store.version == version + 1


# ---------------------------------------------------------------------------
# snapshot faults (exception safety of checkpoint())
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("site", ["snapshot.meta_write",
                                  "snapshot.arrays_write",
                                  "snapshot.manifest_commit"])
def test_checkpoint_fault_is_exception_safe(tmp_path, site):
    """A sync checkpoint failing at any stage: the writer lock is
    released, the snapshots counter does not lie, no half-written step is
    ever restorable, and the engine keeps serving and writing."""
    eng = _engine(str(tmp_path))
    eng.observe(*_batch(0))
    path0 = eng.checkpoint()
    snaps = eng.stats["snapshots"]

    faults.arm(site, OSError(errno.EIO, "io fault"))
    with pytest.raises(OSError):
        eng.checkpoint(step=7)
    faults.reset()
    assert eng.stats["snapshots"] == snaps     # failed commit not counted
    # the aborted step is invisible to recovery
    assert snapshot_io.latest_complete_step(eng.cfg.snapshot_dir) == \
        int(os.path.basename(path0).split("_")[1])
    eng.observe(*_batch(1))                    # writer lock was released
    eng.checkpoint()                           # and checkpointing works
    eng.close()


def test_async_snapshot_worker_death_is_counted(tmp_path):
    """snapshot.io_thread faulting kills the worker: on_error counts it
    (snapshot_failures), no step dir is committed, serving continues —
    a silently dead IO thread would look exactly like progress."""
    eng = _engine(str(tmp_path))
    eng.observe(*_batch(0))
    faults.arm("snapshot.io_thread", OSError(errno.EIO, "worker died"))
    eng.checkpoint(sync=False)
    for t in list(eng._io_threads):
        t.join()
    faults.reset()
    assert eng.stats["snapshot_failures"] == 1
    assert snapshot_io.latest_complete_step(eng.cfg.snapshot_dir) is None
    eng.observe(*_batch(1))
    eng.close()


def test_restore_read_fault_raises_cleanly(tmp_path):
    """snapshot.restore_read faulting surfaces to the caller; the engine
    neither publishes a torn state nor loses its current one."""
    eng = _engine(str(tmp_path))
    eng.observe(*_batch(0))
    eng.checkpoint()
    before = _query_state(eng)
    faults.arm("snapshot.restore_read", OSError(errno.EIO, "read fault"))
    with pytest.raises(OSError):
        eng.restore()
    faults.reset()
    for a, b in zip(before, _query_state(eng)):
        np.testing.assert_array_equal(a, b)
    eng.restore()                              # clean retry works
    eng.close()


def test_cadence_snapshot_failure_never_fails_observe(tmp_path):
    """The background-cadence snapshot hitting a fault must cost a
    counter, not the write path."""
    eng = _engine(str(tmp_path), snapshot_every=2)
    faults.arm("snapshot.io_thread", OSError(errno.EIO, "cadence fault"))
    for i in range(4):
        eng.observe(*_batch(i))               # steps 2 and 4 snapshot
    for t in list(eng._io_threads):
        t.join()
    faults.reset()
    assert eng.stats["updates"] == 4
    assert eng.stats["snapshot_failures"] == 2
    eng.close()


# ---------------------------------------------------------------------------
# degraded reads (read path never raises)
# ---------------------------------------------------------------------------


def test_query_dispatch_fault_degrades_not_raises(tmp_path):
    """Exhausted query dispatch: empty answers with degraded_answers
    counted — and the next healthy call serves normally again."""
    eng = _engine(str(tmp_path), wal=False, snap=False)
    eng.observe(*_batch(0))
    faults.arm("engine.query_dispatch", RuntimeError("device lost"))
    d, p, n = eng.query(np.arange(8))
    faults.reset()
    assert (np.asarray(n) == 0).all()
    assert (np.asarray(d) == -1).all()
    assert eng.stats["degraded_answers"] == 8
    assert eng.stats["dispatch_retries"] == FAST.max_attempts - 1
    d2, p2, n2 = eng.query(np.arange(8))
    assert int(np.asarray(n2).sum()) > 0       # healthy again
    eng.close()


def test_query_dispatch_transient_fault_is_invisible(tmp_path):
    """A one-shot dispatch flake is absorbed by the ladder: answers are
    bit-identical to a fault-free call."""
    eng = _engine(str(tmp_path), wal=False, snap=False)
    eng.observe(*_batch(0))
    clean = _query_state(eng)
    faults.arm("engine.query_dispatch", RuntimeError("flake"), count=1)
    flaky = _query_state(eng)
    faults.reset()
    for a, b in zip(clean, flaky):
        np.testing.assert_array_equal(a, b)
    assert eng.stats["degraded_answers"] == 0
    eng.close()


def test_topn_dispatch_fault_degrades_not_raises(tmp_path):
    eng = _engine(str(tmp_path), wal=False, snap=False)
    eng.observe(*_batch(0))
    faults.arm("engine.topn_dispatch", RuntimeError("device lost"))
    srcs, dsts, probs = eng.topn(4)
    faults.reset()
    assert (np.asarray(srcs) == -1).all()
    assert eng.stats["degraded_answers"] == 4
    srcs2, _, probs2 = eng.topn(4)
    assert int(np.asarray(srcs2).max()) >= 0   # healthy again
    eng.close()


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (CI multi-device matrix; "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_mark_shard_down_degrades_reads_and_defers_writes(tmp_path):
    """Down shard: its items answer empty (counted), top-n filters its
    rows (survivors stay descending), writes defer bounded, heal_shard
    re-applies them and re-admits the shard."""
    eng = _engine(str(tmp_path), shards=2)
    src = np.arange(16, dtype=np.int32)
    eng.observe(src, (src + 1) % 64)
    own = eng.cfg.sharded.resolved_ownership()
    owner = np.asarray(own.owner_of(jnp.asarray(src)))

    eng.mark_shard_down(1)
    d, p, n = eng.query(src)
    assert (np.asarray(n)[owner == 1] == 0).all()
    assert (np.asarray(n)[owner == 0] > 0).any()
    assert eng.stats["degraded_answers"] >= int((owner == 1).sum())

    ts, td, tp = eng.topn(8)
    live = np.asarray(ts)[np.asarray(ts) >= 0]
    assert (np.asarray(own.owner_of(jnp.asarray(live))) != 1).all()
    p_live = np.asarray(tp)[: live.size]
    assert (np.diff(p_live) <= 1e-6).all()     # survivors stay sorted

    eng.observe(src, (src + 2) % 64)           # shard-1 items defer
    assert eng.stats["deferred_writes"] > 0
    healed = eng.heal_shard(1)
    assert healed == 1
    assert eng.stats["deferred_writes"] == 0
    assert eng.stats["shards_down"] == 0
    d2, p2, n2 = eng.query(src)
    assert (np.asarray(n2) > 0).all()          # everything serves again
    eng.close()


def test_deferred_writes_survive_snapshot_gc_and_crash(tmp_path):
    """A15 regression: a snapshot committing while a shard is down
    persists the deferred queue in its meta; WAL GC may then unlink the
    deferred batches' only log records, and a post-crash restore must
    still reinstate and heal them — never lose them."""
    src0, dst0 = _batch(0)
    src1, dst1 = _batch(1)
    eng = _engine(str(tmp_path))
    eng.wal.segment_records = 1                # every record GC-able
    eng.observe(src0, dst0)
    eng.mark_shard_down(0)
    eng.observe(src1, dst1)                    # defers; WAL seq 1
    assert eng.stats["deferred_writes"] == src1.size
    eng.checkpoint()                           # commit + GC through seq 1
    assert not os.listdir(eng.cfg.wal_dir)     # the WAL copy is GONE
    eng.close()

    eng2 = _engine(str(tmp_path))              # "fresh process"
    eng2.restore()
    assert eng2.stats["shards_down"] == 1      # down-set reinstated
    assert eng2.stats["deferred_writes"] == src1.size
    assert eng2.heal_shard(0) == 1             # the deferred batch healed
    healed = _query_state(eng2)
    # seq authority survives a fully-GC'd WAL: new records must continue
    # after the snapshot's wal_seq, not restart at 0 under it
    eng2.observe(*_batch(2))
    assert eng2.wal.last_seq == 2
    eng2.close()

    oracle = _engine(str(tmp_path) + "_oracle")
    oracle.observe(src0, dst0)
    oracle.observe(src1, dst1)
    for a, b in zip(healed, _query_state(oracle)):
        np.testing.assert_array_equal(a, b)
    oracle.close()


def test_restore_resets_health_map_before_replay(tmp_path):
    """In-process restore(): the live health map is replaced by the
    snapshot's image BEFORE replay, so a tail record owned by a live-down
    shard is applied directly (the snapshot never saw its deferral) —
    keeping it deferred on top of the snapshot image would double-apply
    it on the eventual heal."""
    src0, dst0 = _batch(0)
    src1, dst1 = _batch(1)
    eng = _engine(str(tmp_path))
    eng.observe(src0, dst0)
    eng.checkpoint()                           # healthy image, wal_seq 0
    eng.mark_shard_down(0)
    eng.observe(src1, dst1)                    # defers in memory; seq 1
    assert eng.stats["deferred_writes"] == src1.size

    result = eng.restore()                     # in-process, same engine
    assert result["replayed"] == 1             # seq 1 applied directly
    assert eng.stats["shards_down"] == 0       # snapshot image: healthy
    assert eng.stats["deferred_writes"] == 0
    assert eng.heal_shard(0) == 0              # nothing left to heal
    healed = _query_state(eng)
    eng.close()

    oracle = _engine(str(tmp_path) + "_oracle")
    oracle.observe(src0, dst0)
    oracle.observe(src1, dst1)                 # applied exactly once
    for a, b in zip(healed, _query_state(oracle)):
        np.testing.assert_array_equal(a, b)
    oracle.close()


def test_heal_shard_fault_requeues_remainder(tmp_path):
    """A dispatch fault mid-heal must not drop the already-popped
    remainder: the shard re-marks down, the unapplied batches (failed one
    included) requeue in order, and a clean retry heals them."""
    src0, dst0 = _batch(0)
    src1, dst1 = _batch(1)
    eng = _engine(str(tmp_path), wal=False, snap=False)
    eng.mark_shard_down(0)
    eng.observe(src0, dst0)
    eng.observe(src1, dst1)
    assert eng.stats["deferred_writes"] == src0.size + src1.size

    # first deferred batch applies; the second exhausts the ladder
    faults.arm("engine.apply", RuntimeError("device lost"),
               trigger=lambda hit: hit > 1)
    with pytest.raises(RetryBudgetExceeded):
        eng.heal_shard(0)
    faults.reset()
    assert eng.stats["shards_down"] == 1       # re-marked down
    assert eng.stats["deferred_writes"] == src1.size   # remainder kept

    assert eng.heal_shard(0) == 1              # clean retry applies it
    assert eng.stats["shards_down"] == 0
    assert eng.stats["deferred_writes"] == 0
    healed = _query_state(eng)
    eng.close()

    oracle = _engine(str(tmp_path) + "_oracle", wal=False, snap=False)
    oracle.observe(src0, dst0)
    oracle.observe(src1, dst1)
    for a, b in zip(healed, _query_state(oracle)):
        np.testing.assert_array_equal(a, b)
    oracle.close()


def test_dispatch_strikes_mark_shard_down_automatically(tmp_path):
    """The automatic path to down (no admin call): shard-attributable
    dispatch escalations (ShardDispatchError in the fault chain) strike
    the owner; after health_strikes consecutive escalations the shard is
    down — reads mask it without dispatching into it, writes defer — and
    heal_shard re-admits it."""
    eng = _engine(str(tmp_path), wal=False, snap=False, health_strikes=2)
    eng.observe(*_batch(0))
    assert shard_from_exception(None) is None

    faults.arm("engine.query_dispatch", ShardDispatchError(0, "rpc lost"))
    eng.query(np.arange(8))                    # escalates: strike 1
    assert eng.stats["shards_down"] == 0
    eng.query(np.arange(8))                    # strike 2: auto-down
    faults.reset()
    assert eng.stats["shards_down"] == 1
    assert eng.health.down == frozenset({0})

    d, p, n = eng.query(np.arange(8))          # masked: no dispatch fault
    assert (np.asarray(n) == 0).all()
    eng.observe(*_batch(1))                    # writes defer, not fail
    assert eng.stats["deferred_writes"] > 0
    assert eng.heal_shard(0) == 1
    assert (np.asarray(eng.query(np.arange(8))[2]) > 0).any()
    eng.close()


def test_dispatch_success_breaks_strike_streak(tmp_path):
    """Strikes are CONSECUTIVE failures: a healthy whole-mesh dispatch
    between two escalations resets the streak, so flapping faults never
    accumulate to a spurious down."""
    eng = _engine(str(tmp_path), wal=False, snap=False, health_strikes=2)
    eng.observe(*_batch(0))
    faults.arm("engine.query_dispatch", ShardDispatchError(0, "flap"),
               count=FAST.max_attempts)        # exactly one escalation
    eng.query(np.arange(8))                    # strike 1
    faults.reset()
    eng.query(np.arange(8))                    # healthy: streak broken
    faults.arm("engine.query_dispatch", ShardDispatchError(0, "flap"),
               count=FAST.max_attempts)
    eng.query(np.arange(8))                    # strike 1 again, not 2
    faults.reset()
    assert eng.stats["shards_down"] == 0
    assert not eng.health.down
    eng.close()


# ---------------------------------------------------------------------------
# overflow-retry tier (satellite: route_dropped -> retried/lost)
# ---------------------------------------------------------------------------


def test_route_overflow_prediction_matches_device(tmp_path):
    """The host-side drop predictor must agree bit-exactly with the
    device routing — the tier's correctness rests on it."""
    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=64, capacity=8),
                            num_shards=1, bucket_factor=0.5)
    eng = ShardedEngine(ShardedServeConfig(sharded=scfg))
    rng = np.random.default_rng(5)
    for trial in range(5):
        # heavy skew: most items hit a handful of rows
        src = rng.choice([0, 1, 2, 63], size=24,
                         p=[0.6, 0.2, 0.1, 0.1]).astype(np.int32)
        dst = rng.integers(0, 64, 24).astype(np.int32)
        predicted = int(sh.predict_route_overflow(scfg, src).sum())
        before = eng.stats.get("route_dropped", 0)
        eng.observe(src, dst)
        device = eng.stats["route_dropped"] - before
        assert predicted == device, f"trial {trial}"
    eng.close()


def test_route_retry_tier_requeues_and_drains(tmp_path):
    """With the tier on, skew drops are masked before dispatch (device
    route_dropped stays 0), requeued with a bounded budget, and drained
    across later steps; exhausted items count into route_lost."""
    def mk(budget):
        return _engine(str(tmp_path) + f"_{budget}", snap=False,
                       factor=0.5, route_retry_budget=budget,
                       route_retry_slice=8)

    src = np.zeros(24, np.int32)
    dst = np.arange(24, dtype=np.int32)

    eng0 = mk(0)
    eng0.observe(src, dst)
    assert eng0.stats["route_dropped"] > 0     # tier off: device drops
    eng0.close()

    eng = mk(8)
    eng.observe(src, dst)
    assert eng.stats["route_dropped"] == 0     # tier on: masked pre-dispatch
    assert eng.stats["route_retried"] > 0
    assert sum(int(c[0].size) for c in eng._retry_queue) > 0
    steps = 0
    while eng._retry_queue and steps < 64:
        eng.observe(np.full(1, -1, np.int32), np.zeros(1, np.int32))
        steps += 1
    assert not eng._retry_queue                # queue fully drained
    assert eng.stats["route_dropped"] == 0
    applied_or_lost = eng.stats["route_lost"]
    assert applied_or_lost >= 0                # bounded loss, counted
    eng.close()


def test_route_retry_queue_survives_snapshot_restore(tmp_path):
    """The carry-over queue is recovery state: it rides snapshot meta and
    replay re-plans from it deterministically."""
    eng = _engine(str(tmp_path), factor=0.5, route_retry_budget=8,
                  route_retry_slice=8)
    eng.observe(np.zeros(24, np.int32), np.arange(24, dtype=np.int32))
    queued = sum(int(c[0].size) for c in eng._retry_queue)
    assert queued > 0
    eng.checkpoint()
    eng.close()

    eng2 = _engine(str(tmp_path), factor=0.5, route_retry_budget=8,
                   route_retry_slice=8)
    eng2.restore()
    assert sum(int(c[0].size) for c in eng2._retry_queue) == queued
    eng2.close()


def test_query_overflow_retry_answers_skewed_batch(tmp_path):
    """In-call query retry: a skew-dropped query batch is re-dispatched
    round-robin across sender slices until answered; the tier-off call
    answers strictly fewer items."""
    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=64, capacity=8),
                            num_shards=1, bucket_factor=0.5)
    src_w = np.arange(32, dtype=np.int32) % 64

    eng0 = ShardedEngine(ShardedServeConfig(sharded=scfg))
    eng0.observe(src_w, (src_w + 1) % 64)
    _, _, n0 = eng0.query(np.zeros(32, np.int32))
    eng0.close()

    eng = ShardedEngine(ShardedServeConfig(sharded=scfg,
                                           query_retry_budget=4,
                                           retry=FAST))
    eng.observe(src_w, (src_w + 1) % 64)
    _, _, n1 = eng.query(np.zeros(32, np.int32))
    assert eng.stats["query_dropped"] > 0
    assert eng.stats["query_retried"] > 0
    answered0 = int((np.asarray(n0) > 0).sum())
    answered1 = int((np.asarray(n1) > 0).sum())
    assert answered1 == 32 - eng.stats["query_lost"]
    assert answered1 > answered0
    eng.close()
