"""Validate the trip-count-aware HLO cost analyzer against ground truth."""

import pytest

import jax
import jax.numpy as jnp

from repro import compat
from repro.launch import hlo_cost


def _compile(f, *specs, **jit_kw):
    return jax.jit(f, **jit_kw).lower(*specs).compile()


def test_plain_matmul_flops():
    m, k, n = 64, 128, 256
    co = _compile(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((m, k), jnp.float32),
                  jax.ShapeDtypeStruct((k, n), jnp.float32))
    cost = hlo_cost.analyze(co.as_text())
    assert cost.flops == 2 * m * k * n
    assert cost.collective_bytes == 0


def test_scan_multiplies_by_trip_count():
    layers, m, d = 7, 32, 64

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    co = _compile(f, jax.ShapeDtypeStruct((layers, d, d), jnp.float32),
                  jax.ShapeDtypeStruct((m, d), jnp.float32))
    cost = hlo_cost.analyze(co.as_text())
    assert cost.flops == layers * 2 * m * d * d, cost.loops
    assert any(t == layers for _, t in cost.loops)


def test_scan_matches_unrolled_xla_cost():
    """Our loop-corrected flops == XLA's own count on the unrolled version."""
    layers, m, d = 5, 16, 32

    def scanned(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    def unrolled(ws, x):
        for i in range(layers):
            x = x @ ws[i]
        return x

    ws = jax.ShapeDtypeStruct((layers, d, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((m, d), jnp.float32)
    co_s = _compile(scanned, ws, xs)
    co_u = _compile(unrolled, ws, xs)
    ours = hlo_cost.analyze(co_s.as_text()).flops
    xla_unrolled = compat.cost_analysis(co_u)["flops"]
    assert ours == pytest.approx(xla_unrolled, rel=0.01)


def test_nested_scans_multiply():
    inner, outer, d = 3, 4, 16

    def f(ws, x):
        def outer_body(x, w_outer):
            def inner_body(x2, _):
                return jnp.sin(x2 @ w_outer), None
            x2, _ = jax.lax.scan(inner_body, x, None, length=inner)
            return x2, None
        x, _ = jax.lax.scan(outer_body, x, ws)
        return x

    co = _compile(f, jax.ShapeDtypeStruct((outer, d, d), jnp.float32),
                  jax.ShapeDtypeStruct((8, d), jnp.float32))
    cost = hlo_cost.analyze(co.as_text())
    assert cost.flops == outer * inner * 2 * 8 * d * d


def test_grad_of_scan_counts_fwd_and_bwd():
    layers, m, d = 6, 8, 16

    def loss(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x * x)

    co = _compile(jax.grad(loss), jax.ShapeDtypeStruct((layers, d, d), jnp.float32),
                  jax.ShapeDtypeStruct((m, d), jnp.float32))
    cost = hlo_cost.analyze(co.as_text())
    # fwd: 2md^2 per layer; bwd: dx (2md^2) + dw (2md^2) per layer => 3x fwd
    want = layers * 3 * 2 * m * d * d
    assert cost.flops == pytest.approx(want, rel=0.05), (cost.flops, want)


def test_collectives_inside_loops_are_multiplied():
    import subprocess, sys, os, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.launch import hlo_cost
        mesh = compat.make_mesh((8,), ("model",))
        L, m, d = 5, 32, 64
        def f(ws, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(body, x, ws)
            return x
        ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        xs = jax.ShapeDtypeStruct((m, d), jnp.float32)
        co = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, None, "model")),
            NamedSharding(mesh, P(None, "model"))),
            out_shardings=NamedSharding(mesh, P(None, "model"))
        ).lower(ws, xs).compile()
        cost = hlo_cost.analyze(co.as_text())
        # per trip the sharded matmul needs at least one gather/reduce step;
        # whatever XLA chose, the total must scale with L (counted > once)
        per_loop = [t for _, t in cost.loops]
        assert L in per_loop, cost.loops
        assert cost.collective_bytes > 0
        single = cost.collective_bytes / L
        # sanity: collective bytes are a multiple of the per-trip cost
        assert abs(cost.collective_bytes - single * L) < 1e-6
        print("COLL-OK", cost.collective_bytes, cost.coll_by_class)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL-OK" in out.stdout


def test_bytes_model_counts_dots_not_elementwise():
    """Fusion-aware HBM model: matmul operands/results count; pure
    elementwise chains are treated as fused epilogues (~free)."""
    m, k, n = 64, 128, 256

    def heavy(a, b):
        return jnp.tanh(a @ b) * 2.0

    co = _compile(heavy, jax.ShapeDtypeStruct((m, k), jnp.float32),
                  jax.ShapeDtypeStruct((k, n), jnp.float32))
    cost = hlo_cost.analyze(co.as_text())
    dot_io = 4 * (m * k + k * n + m * n)
    assert cost.bytes_accessed >= dot_io
    assert cost.bytes_accessed < 4 * dot_io  # not counting every op

    def elementwise_only(x):
        def body(c, _):
            return jnp.tanh(c) * 2.0, None
        c, _ = jax.lax.scan(body, x, None, length=10)
        return c

    co2 = _compile(elementwise_only, jax.ShapeDtypeStruct((1024,), jnp.float32))
    cost2 = hlo_cost.analyze(co2.as_text())
    # only loop-state copies remain; far below the 10x read+write upper bound
    assert cost2.bytes_accessed < 10 * 2 * 4096
