"""End-to-end system tests: the paper's claims on the full stack.

1. MCPrioQ learns a ground-truth Zipf Markov graph online and recovers the
   true descending-probability ranking (the paper's §II recommender claim).
2. The LM training loop reduces loss on learnable synthetic data.
3. The serving engine with the MCPrioQ drafter emits identical tokens to
   plain greedy decoding (speculation is lossless) while accepting drafts.
4. Train -> checkpoint -> restore -> continue is bit-exact.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core import mcprioq as mc
from repro.core import speculative as spec
from repro.data.synthetic import MarkovGraphSampler, token_stream
from repro.models import Model
from repro.serve.engine import Engine, ServeConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def test_mcprioq_recovers_true_ranking_online():
    graph = MarkovGraphSampler(num_nodes=60, out_degree=8, zipf_s=1.8, seed=0)
    cfg = mc.MCConfig(num_rows=128, capacity=16, sort_passes=2)
    state = mc.init(cfg)
    for _ in range(60):
        src, dst = graph.sample_transitions(256)
        state = mc.update_batch(state, jnp.asarray(src), jnp.asarray(dst),
                                cfg=cfg)
    # after ~15k transitions the head of every queue matches the true top-1
    hits = 0
    for node in range(60):
        true_dsts, true_p = graph.true_probs(node)
        dsts, probs = mc.query_topk(state, jnp.asarray([node], jnp.int32),
                                    cfg=cfg, k=3)
        if int(dsts[0, 0]) == int(true_dsts[0]):
            hits += 1
    assert hits >= 50, f"top-1 recovered for only {hits}/60 nodes"
    # threshold queries touch few items for a steep Zipf (CDF^-1 claim)
    _, _, n_needed = mc.query_threshold(
        state, jnp.arange(60, dtype=jnp.int32), 0.8, cfg=cfg, max_items=16)
    assert float(jnp.mean(n_needed.astype(jnp.float32))) < 6.0


def test_training_reduces_loss():
    from repro.optim import adamw
    cfg = smoke_config("starcoder2-3b")
    model = Model(cfg)
    tcfg = TrainConfig(total_steps=100, warmup_steps=5,
                       optimizer=adamw.AdamWConfig(lr=3e-3, clip_norm=16.0))
    state = init_state(model, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    stream = token_stream(cfg.vocab_size, 8, 64, seed=0)
    losses = []
    for i, batch in zip(range(80), stream):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.4, losses[::16]


def test_speculative_serving_is_lossless_greedy():
    cfg = smoke_config("qwen2-7b")
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)

    def gen(draft_len):
        eng = Engine(model, params, ServeConfig(
            max_new_tokens=16, max_cache_len=64, draft_len=draft_len))
        out = eng.generate({"tokens": prompt}, jax.random.key(0))
        return out, eng

    plain, _ = gen(0)
    spec_out, eng = gen(4)
    np.testing.assert_array_equal(plain, spec_out)


def test_drafter_learns_and_accelerates():
    """Feed the drafter a highly deterministic stream; drafts must match."""
    ncfg = spec.NGramConfig(order=2,
                            mc=mc.MCConfig(num_rows=512, capacity=16,
                                           sort_passes=2))
    st = spec.init(ncfg)
    # periodic sequence 0,1,2,...,9,0,1,...
    seq = jnp.asarray(np.tile(np.arange(10), 30)[None].astype(np.int32))
    st = spec.observe(st, seq, cfg=ncfg)
    ctx = jnp.asarray([[3, 4]], jnp.int32)
    draft, ok = spec.draft(st, ctx, cfg=ncfg, k=4)
    assert np.asarray(ok).all()
    np.testing.assert_array_equal(np.asarray(draft)[0], [5, 6, 7, 8])
    # cumulative-threshold candidates concentrate on the true successor
    dsts, probs, n = spec.candidates(st, ctx, 0.9, cfg=ncfg, max_items=4)
    assert int(n[0]) == 1 and int(dsts[0, 0]) == 5


@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-130m",
                                  "recurrentgemma-9b", "deepseek-moe-16b"])
def test_extend_step_matches_sequential_decode(arch):
    """extend_step over K tokens == K sequential decode_steps (the exactness
    speculative verification relies on), for every layer family.  f32 so the
    comparison tests the mechanism, not bf16 accumulation noise."""
    import dataclasses
    cfg = dataclasses.replace(smoke_config(arch), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(4)
    b, s, k = 2, 8, 4
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    extra = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, k)), jnp.int32)

    _, caches = jax.jit(lambda p, bt: model.prefill(p, bt, 32))(
        params, {"tokens": prompt})

    # sequential decodes
    c_seq = caches
    seq_logits = []
    for j in range(k):
        lg, c_seq = jax.jit(model.decode_step)(
            params, c_seq, extra[:, j:j + 1], jnp.full((b,), s + j, jnp.int32))
        seq_logits.append(np.asarray(lg, np.float32))

    # one extend
    ext_logits, _ = jax.jit(model.extend_step)(
        params, caches, extra, jnp.full((b,), s, jnp.int32))
    ext_logits = np.asarray(ext_logits, np.float32)

    for j in range(k):
        np.testing.assert_allclose(ext_logits[:, j], seq_logits[j],
                                   rtol=2e-3, atol=2e-3)


def test_train_checkpoint_restore_bitexact(tmp_path):
    from repro.checkpoint import ckpt
    cfg = smoke_config("mamba2-130m")
    model = Model(cfg)
    tcfg = TrainConfig(total_steps=10)
    state = init_state(model, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    stream = token_stream(cfg.vocab_size, 4, 32, seed=3)
    batches = [next(stream) for _ in range(4)]
    bt = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]

    for b in bt[:2]:
        state, _ = step(state, b)
    ckpt.save(state, str(tmp_path), 2)
    cont = state
    for b in bt[2:]:
        cont, _ = step(cont, b)

    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, s0 = ckpt.restore(like, str(tmp_path))
    assert s0 == 2
    for b in bt[2:]:
        restored, _ = step(restored, b)
    for a, b2 in zip(jax.tree_util.tree_leaves(cont),
                     jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b2))
