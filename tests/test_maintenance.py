"""Incremental maintenance subsystem (DESIGN.md §6) + the PR's bugfixes.

Covers: rolling decay through ``ops.decay_sort`` (coverage, bounded per-call
touch set, cursor wrap, ref/pallas equivalence), incremental dst-hash repair
(tombstones, rebuild threshold, consistency), the tombstone-saturated-chain
insert fix, the EpochStore synchronize backoff, and the serialised serving
learner (no lost updates under concurrent requests).
"""

import dataclasses
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import mcprioq as mc
from repro.core import speculative as spec
from repro.core.epoch import EpochStore
from repro.core.hashtable import EMPTY, TOMB


def _churned_state(cfg, iters=6, seed=0, srcs=12, dsts=10, batch=64):
    rng = np.random.default_rng(seed)
    state = mc.init(cfg)
    for _ in range(iters):
        s = jnp.asarray(rng.integers(0, srcs, batch).astype(np.int32))
        d = jnp.asarray(rng.integers(0, dsts, batch).astype(np.int32))
        w = jnp.asarray(rng.integers(1, 4, batch).astype(np.int32))
        state = mc.update_batch(state, s, d, weights=w, cfg=cfg)
    return state


# ---------------------------------------------------------------------------
# satellite: hashtable.insert must reuse TOMB when the window saturates
# ---------------------------------------------------------------------------


def test_insert_reuses_tomb_on_saturated_window():
    """Probe window full of tombstones: insert must land on the first TOMB
    instead of dropping the key (the seed returned slot=-1, ok=False)."""
    tab = ht.HashTable(keys=jnp.full((8,), TOMB, jnp.int32),
                       vals=jnp.full((8,), EMPTY, jnp.int32))
    tab, slot, ok = ht.insert(tab, jnp.int32(5), jnp.int32(42), max_probes=4)
    assert bool(ok) and int(slot) >= 0
    val, found = ht.lookup(tab, jnp.int32(5), max_probes=4)
    assert bool(found) and int(val) == 42


def test_insert_tombstone_chain_regression():
    """Build a real tombstone-saturated chain: fill a window, delete all,
    then insert a fresh key through the tombs."""
    size, probes = 16, 4
    tab = ht.make(size)
    # occupy the new key's entire probe window with colliding inserts
    key = jnp.int32(7)
    h0 = int(ht._slot0(key, size))
    victims = []
    filler = 1000
    while len(victims) < probes:
        if int(ht._slot0(jnp.int32(filler), size)) == h0:
            victims.append(filler)
            tab, _, ok = ht.insert(tab, jnp.int32(filler), jnp.int32(0),
                                   max_probes=size)
            assert bool(ok)
        filler += 1
    for v in victims:
        tab, deleted = ht.delete(tab, jnp.int32(v), max_probes=size)
        assert bool(deleted)
    # window now TOMB-saturated for `key`
    window = [int(tab.keys[(h0 + i) % size]) for i in range(probes)]
    assert all(k == TOMB for k in window), window
    tab, slot, ok = ht.insert(tab, key, jnp.int32(99), max_probes=probes)
    assert bool(ok), "insert dropped a key despite reusable tombstones"
    val, found = ht.lookup(tab, key, max_probes=probes)
    assert bool(found) and int(val) == 99


# ---------------------------------------------------------------------------
# satellite: EpochStore.synchronize must not starve its readers
# ---------------------------------------------------------------------------


def test_synchronize_yields_to_releasing_reader():
    store = EpochStore({"v": 0})
    snap = store.acquire()
    store.publish({"v": 1})

    def release_later():
        time.sleep(0.05)
        store.release(snap)

    t = threading.Thread(target=release_later)
    t0 = time.perf_counter()
    t.start()
    store.synchronize()          # must return once the reader releases
    dt = time.perf_counter() - t0
    t.join()
    assert 0.04 <= dt < 2.0
    assert snap.version in store.retired_versions


def test_synchronize_no_readers_returns_immediately():
    store = EpochStore(0)
    store.publish(1)
    t0 = time.perf_counter()
    store.synchronize()
    assert time.perf_counter() - t0 < 0.05


# ---------------------------------------------------------------------------
# tentpole: rolling decay
# ---------------------------------------------------------------------------


def test_rolling_decay_full_cycle_equals_stop_the_world_counts():
    cfg_roll = mc.MCConfig(num_rows=16, capacity=8, sort_passes=1,
                           decay_block_rows=4)
    cfg_stw = dataclasses.replace(cfg_roll, decay_block_rows=0)
    base = _churned_state(cfg_stw, srcs=14)
    stw = mc.decay(base, cfg=cfg_stw)
    roll = base
    for _ in range(4):                      # 16 rows / 4-row blocks
        roll = mc.decay(roll, cfg=cfg_roll)
    np.testing.assert_array_equal(np.asarray(roll.slabs.cnt),
                                  np.asarray(stw.slabs.cnt))
    np.testing.assert_array_equal(np.asarray(roll.slabs.tot),
                                  np.asarray(stw.slabs.tot))
    np.testing.assert_array_equal(np.asarray(roll.slabs.dst),
                                  np.asarray(stw.slabs.dst))
    assert int(roll.decay_steps) == 4 and int(stw.decay_steps) == 1
    assert int(roll.decay_cursor) == 4      # wraps via remainder on next call


def test_rolling_decay_touches_only_the_cursor_block():
    cfg = mc.MCConfig(num_rows=16, capacity=8, sort_passes=1,
                      decay_block_rows=4)
    state = _churned_state(cfg, srcs=14)
    before = np.asarray(state.slabs.cnt).copy()
    after1 = mc.decay(state, cfg=cfg)
    got = np.asarray(after1.slabs.cnt)
    np.testing.assert_array_equal(got[4:], before[4:])       # untouched rows
    np.testing.assert_array_equal(got[:4], before[:4] >> 1)  # halved block
    inv = mc.check_invariants(after1)
    assert inv["tot_matches_cnt_sum"] and inv["free_slots_consistent"]


def test_rolling_decay_cursor_wraps():
    cfg = mc.MCConfig(num_rows=8, capacity=4, sort_passes=1,
                      decay_block_rows=4)
    state = _churned_state(cfg, srcs=8, dsts=4, batch=32)
    for i in range(5):                       # 2 blocks -> wraps twice + one
        state = mc.decay(state, cfg=cfg)
    # 5 calls over 2 blocks: block 0 decayed 3x, block 1 decayed 2x
    assert int(state.decay_steps) == 5
    assert int(state.decay_cursor) % 2 == 1


@pytest.mark.parametrize("block", [0, 4], ids=["stw", "rolling"])
def test_decay_ref_pallas_equivalent(block):
    """Acceptance: decay dispatches through ops.decay_sort identically for
    impl='ref' and impl='pallas' (interpret off-TPU)."""
    mk = lambda impl: mc.MCConfig(num_rows=16, capacity=16, sort_passes=1,
                                  use_dst_hash=True, decay_block_rows=block,
                                  impl=impl)
    cfg_r, cfg_p = mk("ref"), mk("pallas")
    s_r = _churned_state(cfg_r, seed=3)
    s_p = _churned_state(cfg_p, seed=3)
    for _ in range(2):
        s_r = mc.decay(s_r, cfg=cfg_r)
        s_p = mc.decay(s_p, cfg=cfg_p)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(s_r),
                    jax.tree_util.tree_leaves(s_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_maybe_decay_rolling_drains_pressure_over_calls():
    cfg = mc.MCConfig(num_rows=8, capacity=4, sort_passes=1,
                      decay_block_rows=4)
    state = mc.init(cfg)
    src = jnp.asarray([0, 5], jnp.int32)     # rows 0 and 1 (alloc order)
    state = mc.update_batch(state, src, jnp.asarray([1, 2], jnp.int32),
                            weights=jnp.asarray([60, 60], jnp.int32), cfg=cfg)
    # both rows over threshold: each call halves one block until drained
    out = mc.maybe_decay(state, cfg=cfg, total_threshold=50)
    assert int(out.decay_steps) == 1
    out = mc.maybe_decay(out, cfg=cfg, total_threshold=50)
    assert int(out.decay_steps) in (1, 2)    # drained iff both rows in block 0
    for _ in range(3):
        out = mc.maybe_decay(out, cfg=cfg, total_threshold=50)
    assert not bool(jnp.any(out.slabs.tot > 50))
    steps_done = int(out.decay_steps)
    out2 = mc.maybe_decay(out, cfg=cfg, total_threshold=50)
    assert int(out2.decay_steps) == steps_done   # below threshold: no-op


# ---------------------------------------------------------------------------
# tentpole: incremental dst-hash repair + rebuild threshold
# ---------------------------------------------------------------------------


def test_decay_repair_tombstones_dead_entries_only():
    cfg = mc.MCConfig(num_rows=8, capacity=8, sort_passes=1,
                      use_dst_hash=True)
    state = mc.init(cfg)
    src = jnp.zeros((4,), jnp.int32)
    dst = jnp.asarray([10, 11, 12, 13], jnp.int32)
    w = jnp.asarray([8, 4, 2, 1], jnp.int32)
    state = mc.update_batch(state, src, dst, weights=w, cfg=cfg)
    state = mc.decay(state, cfg=cfg)         # w=1 edge dies
    assert int(state.dh_tombstones) == 1
    assert int(state.dh_rebuilds) == 0       # repair, not rebuild
    assert int(np.sum(np.asarray(state.dh_keys) == TOMB)) == 1
    inv = mc.check_invariants(state, cfg)
    assert inv["dst_hash_consistent"]
    # the dead dst is gone from the hash, live ones still resolve
    rows, _ = mc.lookup_rows(state, src[:1], cfg=cfg)
    _, found = mc._find_slots(state, rows, jnp.asarray([13], jnp.int32), cfg)
    assert not bool(found[0])
    _, found = mc._find_slots(state, rows, jnp.asarray([10], jnp.int32), cfg)
    assert bool(found[0])


def test_dh_rebuild_triggers_on_tombstone_load():
    # threshold ~0: the first dead entry forces a full rebuild
    cfg = mc.MCConfig(num_rows=8, capacity=8, sort_passes=1,
                      use_dst_hash=True, dh_rebuild_fraction=0.0)
    state = mc.init(cfg)
    src = jnp.zeros((4,), jnp.int32)
    dst = jnp.asarray([10, 11, 12, 13], jnp.int32)
    w = jnp.asarray([8, 4, 2, 1], jnp.int32)
    state = mc.update_batch(state, src, dst, weights=w, cfg=cfg)
    state = mc.decay(state, cfg=cfg)
    assert int(state.dh_rebuilds) == 1
    assert int(state.dh_tombstones) == 0     # reset by the rebuild
    assert int(np.sum(np.asarray(state.dh_keys) == TOMB)) == 0
    assert mc.check_invariants(state, cfg)["dst_hash_consistent"]


def test_repeated_decay_keeps_dst_hash_consistent():
    cfg = mc.MCConfig(num_rows=16, capacity=8, sort_passes=1,
                      use_dst_hash=True, decay_block_rows=4,
                      dh_rebuild_fraction=0.02)
    rng = np.random.default_rng(5)
    state = mc.init(cfg)
    for i in range(12):
        s = jnp.asarray(rng.integers(0, 12, 64).astype(np.int32))
        d = jnp.asarray(rng.integers(0, 12, 64).astype(np.int32))
        state = mc.update_batch(state, s, d, cfg=cfg)
        state = mc.decay(state, cfg=cfg)
        inv = mc.check_invariants(state, cfg)
        assert inv["dst_hash_consistent"], f"iteration {i}"
        assert inv["tot_matches_cnt_sum"] and inv["free_slots_consistent"]
    assert int(state.dh_rebuilds) >= 1       # tight threshold must trip
    stats = mc.maintenance_stats(state)
    assert stats["decay_steps"] == 12


# ---------------------------------------------------------------------------
# satellite: serialised serving learner (no lost updates)
# ---------------------------------------------------------------------------


def test_engine_learn_conserves_transitions_under_threads():
    """acquire -> observe -> publish is a read-modify-write; concurrent
    requests must not publish from the same base (lost update).  The learner
    path never traces the model, so the Engine gets a stub."""
    from types import SimpleNamespace

    from repro.serve.engine import Engine, ServeConfig

    stub_model = SimpleNamespace(prefill=lambda *a: None,
                                 decode_step=lambda *a: None,
                                 extend_step=lambda *a: None)

    # num_rows comfortably above the number of distinct contexts so no
    # row-drops occur: conservation is then exact and order-independent
    ncfg = spec.NGramConfig(
        order=2, mc=mc.MCConfig(num_rows=2048, capacity=16, sort_passes=1))
    rng = np.random.default_rng(6)
    histories = [rng.integers(0, 50, (2, 18)).astype(np.int32)
                 for _ in range(12)]

    def total_mass(store):
        return int(jnp.sum(store._snap.state.chain.slabs.tot))

    # sequential oracle
    eng_seq = Engine(stub_model, None, ServeConfig(ngram=ncfg))
    for h in histories:
        eng_seq._learn(h)
    expected = total_mass(eng_seq.drafter_store)
    assert expected > 0

    # concurrent learners over the same histories
    eng = Engine(stub_model, None, ServeConfig(ngram=ncfg))
    errs = []

    def worker(chunk):
        try:
            for h in chunk:
                eng._learn(h)
        except Exception as e:  # pragma: no cover - surfaced via errs
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(histories[i::4],))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert total_mass(eng.drafter_store) == expected
    assert eng.drafter_store.version == len(histories)
    assert "decay_steps" in eng.stats and "dh_tombstones" in eng.stats
