"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.kernels import cdf_gather as cgk
from repro.kernels import cdf_query as cdfk
from repro.kernels import oddeven as oek
from repro.kernels import probe as prk
from repro.kernels import ref
from repro.kernels import slab_update as suk
from repro.kernels import walk as wkk

SHAPES_2D = [(8, 16), (64, 128), (32, 256), (256, 128), (7, 32)]


def _rand_slabs(rng, n, c, density=0.7, dtype=np.int32):
    cnt = (rng.random((n, c)) < density) * rng.integers(1, 1000, (n, c))
    cnt = cnt.astype(dtype)
    dst = np.where(cnt > 0, rng.integers(0, 10_000, (n, c)), -1).astype(np.int32)
    tot = cnt.sum(axis=1).astype(dtype)
    order = np.argsort(-cnt, axis=1, kind="stable").astype(np.int32)
    return jnp.asarray(dst), jnp.asarray(cnt), jnp.asarray(tot), jnp.asarray(order)


# ---------------------------------------------------------------------------
# oddeven
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", SHAPES_2D)
@pytest.mark.parametrize("passes", [1, 2, 5])
def test_oddeven_kernel_matches_ref(n, c, passes):
    rng = np.random.default_rng(n * 1000 + c + passes)
    cnt = jnp.asarray(rng.integers(0, 100, (n, c)).astype(np.int32))
    order = jnp.asarray(
        np.stack([rng.permutation(c) for _ in range(n)]).astype(np.int32))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    # pad rows to the block multiple the kernel requires
    rb = min(oek.DEFAULT_ROWS_PER_BLOCK, n)
    pad = (-n) % rb
    c_pad = jnp.pad(c_ord, ((0, pad), (0, 0)))
    o_pad = jnp.pad(order, ((0, pad), (0, 0)))
    got_c, got_o = oek.oddeven_pallas(
        c_pad, o_pad, passes=passes, rows_per_block=rb, interpret=True)
    want_c, want_o = ref.oddeven_ref(c_ord, order, passes)
    np.testing.assert_array_equal(np.asarray(got_c)[:n], np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_o)[:n], np.asarray(want_o))


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_oddeven_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    cnt = jnp.asarray(rng.integers(0, 50, (16, 64))).astype(dtype)
    order = jnp.asarray(
        np.stack([rng.permutation(64) for _ in range(16)]).astype(np.int32))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    got_c, got_o = oek.oddeven_pallas(
        c_ord, order, passes=3, rows_per_block=16, interpret=True)
    want_c, want_o = ref.oddeven_ref(c_ord, order, 3)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))


def test_oddeven_ref_equals_slab_semantics():
    """kernel-layout oracle == core slab.oddeven_passes semantics."""
    rng = np.random.default_rng(1)
    cnt = jnp.asarray(rng.integers(0, 100, (32, 64)).astype(np.int32))
    order = jnp.asarray(
        np.stack([rng.permutation(64) for _ in range(32)]).astype(np.int32))
    want = sl.oddeven_passes(cnt, order, 2)
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    _, got = ref.oddeven_ref(c_ord, order, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_oddeven_full_sort_after_C_passes():
    rng = np.random.default_rng(2)
    n, c = 16, 64
    cnt = jnp.asarray(rng.integers(0, 10_000, (n, c)).astype(np.int32))
    order = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (n, c))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    got_c, got_o = oek.oddeven_pallas(
        c_ord, order, passes=c // 2 + 1, rows_per_block=n, interpret=True)
    got_c = np.asarray(got_c)
    assert np.all(got_c[:, :-1] >= got_c[:, 1:]), "not fully sorted"
    # permutation property retained
    assert np.all(np.sort(np.asarray(got_o), axis=1) == np.arange(c))


# ---------------------------------------------------------------------------
# slab_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(16, 32), (256, 128), (64, 64)])
@pytest.mark.parametrize("batch", [4, 64, 256])
def test_slab_update_kernel_matches_ref(n, c, batch):
    rng = np.random.default_rng(n + batch)
    dst, cnt, tot, _ = _rand_slabs(rng, n, c)
    # build updates: half hit existing edges, half miss / padding
    rows = rng.integers(0, n, batch).astype(np.int32)
    rows[rng.random(batch) < 0.2] = -1  # padding
    dsts = np.empty(batch, np.int32)
    dnp, cnp = np.asarray(dst), np.asarray(cnt)
    for i, r in enumerate(rows):
        live = np.nonzero((r >= 0) * (cnp[max(r, 0)] > 0))[0]
        if r >= 0 and len(live) and rng.random() < 0.7:
            dsts[i] = dnp[r, rng.choice(live)]
        else:
            dsts[i] = 123456 + i  # guaranteed miss
    w = rng.integers(1, 5, batch).astype(np.int32)
    rb = min(suk.DEFAULT_ROWS_PER_BLOCK, n)
    got_cnt, got_tot = suk.slab_update_pallas(
        jnp.asarray(rows), jnp.asarray(dsts), jnp.asarray(w),
        dst, cnt, tot, rows_per_block=rb, interpret=True)
    _, want_cnt, want_tot, _ = ref.slab_update_ref(
        jnp.asarray(rows), jnp.asarray(dsts), jnp.asarray(w), dst, cnt, tot)
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(want_cnt))
    np.testing.assert_array_equal(np.asarray(got_tot), np.asarray(want_tot))


def test_slab_update_duplicate_aggregation():
    """In-batch duplicates of one edge aggregate like contended atomics."""
    dst = jnp.asarray([[5, 7, -1, -1]], jnp.int32)
    cnt = jnp.asarray([[10, 3, 0, 0]], jnp.int32)
    tot = jnp.asarray([13], jnp.int32)
    rows = jnp.zeros((8,), jnp.int32)
    dsts = jnp.asarray([5] * 8, jnp.int32)
    w = jnp.ones((8,), jnp.int32)
    got_cnt, got_tot = suk.slab_update_pallas(
        rows, dsts, w, dst, cnt, tot, rows_per_block=1, interpret=True)
    assert int(got_cnt[0, 0]) == 18
    assert int(got_tot[0]) == 21


# ---------------------------------------------------------------------------
# cdf_query
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,c", [(8, 16), (128, 128), (64, 256)])
@pytest.mark.parametrize("t", [0.5, 0.9, 0.99])
@pytest.mark.parametrize("chunks", [1, 4])
def test_cdf_query_kernel_matches_ref(b, c, t, chunks):
    rng = np.random.default_rng(b + int(t * 100) + chunks)
    # zipf-ish sorted counts
    raw = np.sort(rng.zipf(1.5, (b, c)).astype(np.int32), axis=1)[:, ::-1]
    raw[rng.random((b, c)) < 0.1] = 0
    raw = np.sort(raw, axis=1)[:, ::-1].copy()
    c_ord = jnp.asarray(raw)
    d_ord = jnp.asarray(rng.integers(0, 1000, (b, c)).astype(np.int32))
    tot = jnp.asarray(raw.sum(axis=1).astype(np.int32))
    qb = min(cdfk.DEFAULT_QUERIES_PER_BLOCK, b)
    got_d, got_p, got_n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, t, max_items=16, queries_per_block=qb,
        chunks=chunks, interpret=True)
    want_d, want_p, want_n = ref.cdf_query_ref(c_ord, d_ord, tot, t, 16)
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-6, atol=1e-7)


def test_cdf_query_empty_rows():
    c_ord = jnp.zeros((4, 32), jnp.int32)
    d_ord = jnp.zeros((4, 32), jnp.int32)
    tot = jnp.zeros((4,), jnp.int32)
    got_d, got_p, got_n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, 0.9, max_items=8, queries_per_block=4,
        interpret=True)
    assert np.all(np.asarray(got_n) == 0)
    assert np.all(np.asarray(got_p) == 0)


def test_cdf_query_complexity_matches_quantile():
    """n_needed equals the quantile function of the edge distribution —
    the paper's O(CDF^-1(t)) claim, checked exactly."""
    # geometric-ish distribution: p_i ~ 2^-i  ->  CDF^-1(0.9) is ~4 items
    c_ord = jnp.asarray([[512, 256, 128, 64, 32, 16, 8, 8]], jnp.int32)
    d_ord = jnp.arange(8, dtype=jnp.int32)[None]
    tot = jnp.asarray([1024], jnp.int32)
    _, _, n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, 0.9, max_items=8, queries_per_block=1,
        interpret=True)
    # cumsum/1024: .5 .75 .875 .9375 -> 4 items needed
    assert int(n[0]) == 4


# ---------------------------------------------------------------------------
# probe (paper §II.1-2: the shared open-addressing lookup as a batched kernel)
# ---------------------------------------------------------------------------


def _rand_row_tables(rng, n, h, fill=0.4, tomb=0.2, max_probes=64):
    """Per-row tables built through real core inserts/deletes so the probe
    chains (including tombstones) are exactly what production produces."""
    keys = np.full((n, h), ht.EMPTY, np.int32)
    vals = np.full((n, h), ht.EMPTY, np.int32)
    live = {}
    for r in range(n):
        tab = ht.make(h)
        inserted = []
        for i in range(int(fill * h)):
            k = int(rng.integers(0, 100_000))
            tab, _, ok = ht.insert(tab, jnp.int32(k), jnp.int32(i),
                                   max_probes=max_probes)
            if bool(ok):
                inserted.append((k, i))
        rng.shuffle(inserted)
        n_del = int(tomb * len(inserted))
        for k, _ in inserted[:n_del]:
            tab, _ = ht.delete(tab, jnp.int32(k), max_probes=max_probes)
        live[r] = dict(inserted[n_del:])
        keys[r] = np.asarray(tab.keys)
        vals[r] = np.asarray(tab.vals)
    return jnp.asarray(keys), jnp.asarray(vals), live


@pytest.mark.parametrize("n,h", [(4, 32), (16, 128), (7, 64)])
def test_dh_find_kernel_matches_ref(n, h):
    rng = np.random.default_rng(n * 100 + h)
    keys, vals, live = _rand_row_tables(rng, n, h)
    batch = 64
    rows = rng.integers(0, n, batch).astype(np.int32)
    rows[rng.random(batch) < 0.15] = -1          # padding
    dsts = np.empty(batch, np.int32)
    for i, r in enumerate(rows):
        pool = list(live.get(int(max(r, 0)), {}))
        if r >= 0 and pool and rng.random() < 0.7:
            dsts[i] = pool[int(rng.integers(0, len(pool)))]
        else:
            dsts[i] = 900_000 + i                # guaranteed miss
    rows_j, dsts_j = jnp.asarray(rows), jnp.asarray(dsts)
    rb = min(prk.DEFAULT_ROWS_PER_BLOCK, n)
    pad = (-n) % rb
    keys_p = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=ht.EMPTY)
    vals_p = jnp.pad(vals, ((0, pad), (0, 0)), constant_values=ht.EMPTY)
    got_s, got_f = prk.probe_find_pallas(
        rows_j, dsts_j, keys_p, vals_p, max_probes=64, rows_per_block=rb,
        interpret=True)
    want_s, want_f = ref.dh_find_ref(rows_j, dsts_j, keys, vals, 64)
    np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                  np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    # oracle of the oracle: ref agrees with the per-row core probe + the
    # ground-truth live dict
    for i, (r, d) in enumerate(zip(rows, dsts)):
        if r < 0:
            assert not bool(want_f[i])
            continue
        expect = live[int(r)].get(int(d))
        assert bool(want_f[i]) == (expect is not None)
        if expect is not None:
            assert int(want_s[i]) == expect


def test_dh_find_tombstone_chains_probe_through():
    """Probes must walk through TOMB lanes (deleted keys) to later entries."""
    h = 32
    tab = ht.make(h)
    # three keys colliding into one chain
    base = jnp.int32(11)
    h0 = int(ht._slot0(base, h))
    chain = [k for k in range(2000)
             if int(ht._slot0(jnp.int32(k), h)) == h0][:3]
    assert len(chain) == 3
    for i, k in enumerate(chain):
        tab, _, _ = ht.insert(tab, jnp.int32(k), jnp.int32(i))
    tab, _ = ht.delete(tab, jnp.int32(chain[0]))   # TOMB at chain head
    keys, vals = tab.keys[None], tab.vals[None]
    rows = jnp.zeros((3,), jnp.int32)
    dsts = jnp.asarray(chain, jnp.int32)
    got_s, got_f = prk.probe_find_pallas(rows, dsts, keys, vals,
                                      max_probes=16, rows_per_block=1,
                                      interpret=True)
    want_s, want_f = ref.dh_find_ref(rows, dsts, keys, vals, 16)
    np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                  np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    assert not bool(got_f[0])                      # deleted
    assert bool(got_f[1]) and bool(got_f[2])       # found through the TOMB


# ---------------------------------------------------------------------------
# fused decay (composes the oddeven kernel; paper §II.C)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_decay_sort_matches_core_decay(impl):
    from repro.core import slab as slab_mod
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    n, c = 16, 32
    dst, cnt, tot, order = _rand_slabs(rng, n, c)
    got_cnt, got_dst, got_order, got_tot = ops.decay_sort(
        cnt, dst, order, impl=impl)
    slabs, _ = slab_mod.decay(slab_mod.Slabs(dst, cnt, tot, order))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(slabs.cnt))
    np.testing.assert_array_equal(np.asarray(got_dst), np.asarray(slabs.dst))
    np.testing.assert_array_equal(np.asarray(got_tot), np.asarray(slabs.tot))
    # order: both must be fully sorted descending (ties may permute)
    c_got = np.take_along_axis(np.asarray(got_cnt), np.asarray(got_order), 1)
    assert np.all(c_got[:, :-1] >= c_got[:, 1:])
    # permutation property
    assert np.all(np.sort(np.asarray(got_order), 1) == np.arange(c))


# ---------------------------------------------------------------------------
# probe: flat src table (N = 1 case of the shared kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t_size", [64, 256])
def test_probe_flat_table_matches_core_lookup(t_size):
    """ops.ht_find == hashtable.lookup_batch on a real src table with
    tombstones, for both dispatches."""
    from repro.kernels import ops

    rng = np.random.default_rng(t_size)
    tab = ht.make(t_size)
    keys = rng.choice(100_000, size=t_size // 4, replace=False).astype(np.int32)
    for i, k in enumerate(keys):
        tab, _, ok = ht.insert(tab, jnp.int32(k), jnp.int32(i))
        assert bool(ok)
    for k in keys[:: 5]:                         # delete every 5th -> TOMBs
        tab, _ = ht.delete(tab, jnp.int32(k))
    queries = np.concatenate([keys, 900_000 + np.arange(16, dtype=np.int32)])
    rng.shuffle(queries)
    q = jnp.asarray(queries)
    want_v, want_f = ht.lookup_batch(tab, q)
    for impl in ("ref", "pallas"):
        got_v, got_f = ops.ht_find(q, tab.keys, tab.vals, impl=impl)
        np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                      np.asarray(want_f), err_msg=impl)
        # lookup_batch leaves val EMPTY when not found; ht_find matches
        np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v),
                                      err_msg=impl)
    # the kernel routing inside lookup_batch itself
    kv, kf = ht.lookup_batch(tab, q, impl="pallas")
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(kf).astype(bool),
                                  np.asarray(want_f))


# ---------------------------------------------------------------------------
# cdf_query: top-k mode + chunk-invariance (integer-walk contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunks", [1, 2, 4])
def test_cdf_query_topk_mode_matches_ref(chunks):
    rng = np.random.default_rng(chunks)
    b, c = 32, 64
    raw = np.sort(rng.zipf(1.5, (b, c)).astype(np.int32), axis=1)[:, ::-1]
    raw[rng.random((b, c)) < 0.3] = 0
    raw = np.sort(raw, axis=1)[:, ::-1].copy()
    c_ord, tot = jnp.asarray(raw), jnp.asarray(raw.sum(1).astype(np.int32))
    d_ord = jnp.asarray(rng.integers(0, 1000, (b, c)).astype(np.int32))
    got_d, got_p, got_n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, max_items=8, queries_per_block=b, chunks=chunks,
        topk=True, interpret=True)
    want_d, want_p, want_n = ref.cdf_query_ref(c_ord, d_ord, tot, None, 8)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))
    assert np.asarray(got_p).tobytes() == np.asarray(want_p).tobytes()
    # top-k keeps every live item in the window
    np.testing.assert_array_equal(np.asarray(want_n), (raw > 0).sum(1))


@pytest.mark.parametrize("t", [0.3, 0.9])
def test_cdf_query_chunkings_bit_identical(t):
    """Any chunking == any other, bit for bit: the integer-walk contract."""
    rng = np.random.default_rng(int(t * 10))
    b, c = 64, 128
    raw = np.sort(rng.zipf(1.3, (b, c)).astype(np.int32), axis=1)[:, ::-1]
    raw[rng.random((b, c)) < 0.2] = 0
    raw = np.sort(raw, axis=1)[:, ::-1].copy()
    c_ord, tot = jnp.asarray(raw), jnp.asarray(raw.sum(1).astype(np.int32))
    d_ord = jnp.asarray(rng.integers(0, 1000, (b, c)).astype(np.int32))
    outs = [cdfk.cdf_query_pallas(c_ord, d_ord, tot, t, max_items=16,
                                  queries_per_block=32, chunks=ch,
                                  interpret=True)
            for ch in (1, 2, 4)]
    for other in outs[1:]:
        for a, bb in zip(outs[0], other):
            assert np.asarray(a).tobytes() == np.asarray(bb).tobytes()


# ---------------------------------------------------------------------------
# cdf_gather: fused in-kernel row gather (scalar prefetch)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(16, 32), (64, 128)])
@pytest.mark.parametrize("t,chunks", [(0.5, 1), (0.9, 2), (None, 1)])
def test_cdf_gather_kernel_matches_fused_and_unfused_ref(n, c, t, chunks):
    rng = np.random.default_rng(n + c + chunks)
    dst, cnt, tot, order = _rand_slabs(rng, n, c)
    b = 24
    rows = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    found = jnp.asarray(rng.random(b) < 0.8)
    rows = jnp.where(found, rows, 0)
    k = 8
    got = cgk.cdf_query_fused_pallas(
        rows, found.astype(jnp.int32), cnt, dst, order, tot,
        0.0 if t is None else t, max_items=k, chunks=chunks,
        topk=t is None, interpret=True)
    want = ref.cdf_query_fused_ref(rows, found, cnt, dst, order, tot, t, k)
    # and the unfused pipeline on the same gathered rows
    ord_r = order[rows]
    c_ord = jnp.where(found[:, None],
                      jnp.take_along_axis(cnt[rows], ord_r, axis=1), 0)
    d_ord = jnp.take_along_axis(dst[rows], ord_r, axis=1)
    unfused = ref.cdf_query_ref(c_ord, d_ord, tot[rows], t, k)
    for g, w, u in zip(got, want, unfused):
        assert np.asarray(g).tobytes() == np.asarray(w).tobytes()
        assert np.asarray(w).tobytes() == np.asarray(u).tobytes()


# ---------------------------------------------------------------------------
# walk: one-shot k-step greedy draft kernel
# ---------------------------------------------------------------------------


def _walk_fixture(rng, n_tokens=64, order=2):
    """A chain learned from a noisy successor stream, plus its raw arrays."""
    from repro.core import mcprioq as mc
    from repro.core import speculative as spec

    ncfg = spec.NGramConfig(
        order=order, mc=mc.MCConfig(num_rows=256, capacity=8, sort_passes=2))
    st = spec.init(ncfg)
    succ = rng.integers(0, n_tokens, (n_tokens,)).astype(np.int32)
    toks = np.empty((4, 256), np.int32)
    toks[:, 0] = rng.integers(0, n_tokens, 4)
    for i in range(1, 256):
        follow = succ[toks[:, i - 1]]
        noise = rng.integers(0, n_tokens, 4)
        toks[:, i] = np.where(rng.random(4) < 0.9, follow, noise)
    st = spec.observe(st, jnp.asarray(toks), cfg=ncfg)
    return st, ncfg, toks


@pytest.mark.parametrize("k", [1, 4, 7])
def test_draft_walk_kernel_matches_scan_oracle(k):
    rng = np.random.default_rng(k)
    st, ncfg, toks = _walk_fixture(rng)
    chain = st.chain
    # mix of learned contexts and unknown ones (dead lanes)
    window = jnp.asarray(np.concatenate(
        [toks[:, 100:102], np.full((2, 2), 7777, np.int32)]).astype(np.int32))
    args = (window, chain.src_table.keys, chain.src_table.vals,
            chain.slabs.cnt, chain.slabs.dst, chain.slabs.order[:, 0])
    got_t, got_o = wkk.draft_walk_pallas(
        *args, k=k, max_probes=64, queries_per_block=window.shape[0],
        interpret=True)
    want_t, want_o = ref.draft_walk_ref(*args, k=k, max_probes=64)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))
    # dead lanes emit token 0 / ok False from the first step
    assert not np.asarray(got_o)[-2:].any()
    assert not np.asarray(got_t)[-2:].any()


def test_draft_walk_ok_is_prefix_monotone():
    """ok rows are all-True prefixes: once a lane dies it stays dead."""
    rng = np.random.default_rng(11)
    st, ncfg, toks = _walk_fixture(rng)
    chain = st.chain
    window = jnp.asarray(toks[:, 17:19])
    _, oks = wkk.draft_walk_pallas(
        window, chain.src_table.keys, chain.src_table.vals,
        chain.slabs.cnt, chain.slabs.dst, chain.slabs.order[:, 0],
        k=6, max_probes=64, queries_per_block=window.shape[0],
        interpret=True)
    oks = np.asarray(oks).astype(bool)
    assert np.all(oks == (np.cumprod(oks, axis=1) > 0))
