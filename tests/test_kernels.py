"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.kernels import cdf_query as cdfk
from repro.kernels import dh_find as dhk
from repro.kernels import oddeven as oek
from repro.kernels import ref
from repro.kernels import slab_update as suk

SHAPES_2D = [(8, 16), (64, 128), (32, 256), (256, 128), (7, 32)]


def _rand_slabs(rng, n, c, density=0.7, dtype=np.int32):
    cnt = (rng.random((n, c)) < density) * rng.integers(1, 1000, (n, c))
    cnt = cnt.astype(dtype)
    dst = np.where(cnt > 0, rng.integers(0, 10_000, (n, c)), -1).astype(np.int32)
    tot = cnt.sum(axis=1).astype(dtype)
    order = np.argsort(-cnt, axis=1, kind="stable").astype(np.int32)
    return jnp.asarray(dst), jnp.asarray(cnt), jnp.asarray(tot), jnp.asarray(order)


# ---------------------------------------------------------------------------
# oddeven
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", SHAPES_2D)
@pytest.mark.parametrize("passes", [1, 2, 5])
def test_oddeven_kernel_matches_ref(n, c, passes):
    rng = np.random.default_rng(n * 1000 + c + passes)
    cnt = jnp.asarray(rng.integers(0, 100, (n, c)).astype(np.int32))
    order = jnp.asarray(
        np.stack([rng.permutation(c) for _ in range(n)]).astype(np.int32))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    # pad rows to the block multiple the kernel requires
    rb = min(oek.DEFAULT_ROWS_PER_BLOCK, n)
    pad = (-n) % rb
    c_pad = jnp.pad(c_ord, ((0, pad), (0, 0)))
    o_pad = jnp.pad(order, ((0, pad), (0, 0)))
    got_c, got_o = oek.oddeven_pallas(
        c_pad, o_pad, passes=passes, rows_per_block=rb, interpret=True)
    want_c, want_o = ref.oddeven_ref(c_ord, order, passes)
    np.testing.assert_array_equal(np.asarray(got_c)[:n], np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_o)[:n], np.asarray(want_o))


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_oddeven_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    cnt = jnp.asarray(rng.integers(0, 50, (16, 64))).astype(dtype)
    order = jnp.asarray(
        np.stack([rng.permutation(64) for _ in range(16)]).astype(np.int32))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    got_c, got_o = oek.oddeven_pallas(
        c_ord, order, passes=3, rows_per_block=16, interpret=True)
    want_c, want_o = ref.oddeven_ref(c_ord, order, 3)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))


def test_oddeven_ref_equals_slab_semantics():
    """kernel-layout oracle == core slab.oddeven_passes semantics."""
    rng = np.random.default_rng(1)
    cnt = jnp.asarray(rng.integers(0, 100, (32, 64)).astype(np.int32))
    order = jnp.asarray(
        np.stack([rng.permutation(64) for _ in range(32)]).astype(np.int32))
    want = sl.oddeven_passes(cnt, order, 2)
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    _, got = ref.oddeven_ref(c_ord, order, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_oddeven_full_sort_after_C_passes():
    rng = np.random.default_rng(2)
    n, c = 16, 64
    cnt = jnp.asarray(rng.integers(0, 10_000, (n, c)).astype(np.int32))
    order = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (n, c))
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    got_c, got_o = oek.oddeven_pallas(
        c_ord, order, passes=c // 2 + 1, rows_per_block=n, interpret=True)
    got_c = np.asarray(got_c)
    assert np.all(got_c[:, :-1] >= got_c[:, 1:]), "not fully sorted"
    # permutation property retained
    assert np.all(np.sort(np.asarray(got_o), axis=1) == np.arange(c))


# ---------------------------------------------------------------------------
# slab_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c", [(16, 32), (256, 128), (64, 64)])
@pytest.mark.parametrize("batch", [4, 64, 256])
def test_slab_update_kernel_matches_ref(n, c, batch):
    rng = np.random.default_rng(n + batch)
    dst, cnt, tot, _ = _rand_slabs(rng, n, c)
    # build updates: half hit existing edges, half miss / padding
    rows = rng.integers(0, n, batch).astype(np.int32)
    rows[rng.random(batch) < 0.2] = -1  # padding
    dsts = np.empty(batch, np.int32)
    dnp, cnp = np.asarray(dst), np.asarray(cnt)
    for i, r in enumerate(rows):
        live = np.nonzero((r >= 0) * (cnp[max(r, 0)] > 0))[0]
        if r >= 0 and len(live) and rng.random() < 0.7:
            dsts[i] = dnp[r, rng.choice(live)]
        else:
            dsts[i] = 123456 + i  # guaranteed miss
    w = rng.integers(1, 5, batch).astype(np.int32)
    rb = min(suk.DEFAULT_ROWS_PER_BLOCK, n)
    got_cnt, got_tot = suk.slab_update_pallas(
        jnp.asarray(rows), jnp.asarray(dsts), jnp.asarray(w),
        dst, cnt, tot, rows_per_block=rb, interpret=True)
    _, want_cnt, want_tot, _ = ref.slab_update_ref(
        jnp.asarray(rows), jnp.asarray(dsts), jnp.asarray(w), dst, cnt, tot)
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(want_cnt))
    np.testing.assert_array_equal(np.asarray(got_tot), np.asarray(want_tot))


def test_slab_update_duplicate_aggregation():
    """In-batch duplicates of one edge aggregate like contended atomics."""
    dst = jnp.asarray([[5, 7, -1, -1]], jnp.int32)
    cnt = jnp.asarray([[10, 3, 0, 0]], jnp.int32)
    tot = jnp.asarray([13], jnp.int32)
    rows = jnp.zeros((8,), jnp.int32)
    dsts = jnp.asarray([5] * 8, jnp.int32)
    w = jnp.ones((8,), jnp.int32)
    got_cnt, got_tot = suk.slab_update_pallas(
        rows, dsts, w, dst, cnt, tot, rows_per_block=1, interpret=True)
    assert int(got_cnt[0, 0]) == 18
    assert int(got_tot[0]) == 21


# ---------------------------------------------------------------------------
# cdf_query
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,c", [(8, 16), (128, 128), (64, 256)])
@pytest.mark.parametrize("t", [0.5, 0.9, 0.99])
@pytest.mark.parametrize("chunks", [1, 4])
def test_cdf_query_kernel_matches_ref(b, c, t, chunks):
    rng = np.random.default_rng(b + int(t * 100) + chunks)
    # zipf-ish sorted counts
    raw = np.sort(rng.zipf(1.5, (b, c)).astype(np.int32), axis=1)[:, ::-1]
    raw[rng.random((b, c)) < 0.1] = 0
    raw = np.sort(raw, axis=1)[:, ::-1].copy()
    c_ord = jnp.asarray(raw)
    d_ord = jnp.asarray(rng.integers(0, 1000, (b, c)).astype(np.int32))
    tot = jnp.asarray(raw.sum(axis=1).astype(np.int32))
    qb = min(cdfk.DEFAULT_QUERIES_PER_BLOCK, b)
    got_d, got_p, got_n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, t, max_items=16, queries_per_block=qb,
        chunks=chunks, interpret=True)
    want_d, want_p, want_n = ref.cdf_query_ref(c_ord, d_ord, tot, t, 16)
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(want_n))
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-6, atol=1e-7)


def test_cdf_query_empty_rows():
    c_ord = jnp.zeros((4, 32), jnp.int32)
    d_ord = jnp.zeros((4, 32), jnp.int32)
    tot = jnp.zeros((4,), jnp.int32)
    got_d, got_p, got_n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, 0.9, max_items=8, queries_per_block=4,
        interpret=True)
    assert np.all(np.asarray(got_n) == 0)
    assert np.all(np.asarray(got_p) == 0)


def test_cdf_query_complexity_matches_quantile():
    """n_needed equals the quantile function of the edge distribution —
    the paper's O(CDF^-1(t)) claim, checked exactly."""
    # geometric-ish distribution: p_i ~ 2^-i  ->  CDF^-1(0.9) is ~4 items
    c_ord = jnp.asarray([[512, 256, 128, 64, 32, 16, 8, 8]], jnp.int32)
    d_ord = jnp.arange(8, dtype=jnp.int32)[None]
    tot = jnp.asarray([1024], jnp.int32)
    _, _, n = cdfk.cdf_query_pallas(
        c_ord, d_ord, tot, 0.9, max_items=8, queries_per_block=1,
        interpret=True)
    # cumsum/1024: .5 .75 .875 .9375 -> 4 items needed
    assert int(n[0]) == 4


# ---------------------------------------------------------------------------
# dh_find (paper §II.2 per-row dst hash as a batched kernel)
# ---------------------------------------------------------------------------


def _rand_row_tables(rng, n, h, fill=0.4, tomb=0.2, max_probes=64):
    """Per-row tables built through real core inserts/deletes so the probe
    chains (including tombstones) are exactly what production produces."""
    keys = np.full((n, h), ht.EMPTY, np.int32)
    vals = np.full((n, h), ht.EMPTY, np.int32)
    live = {}
    for r in range(n):
        tab = ht.make(h)
        inserted = []
        for i in range(int(fill * h)):
            k = int(rng.integers(0, 100_000))
            tab, _, ok = ht.insert(tab, jnp.int32(k), jnp.int32(i),
                                   max_probes=max_probes)
            if bool(ok):
                inserted.append((k, i))
        rng.shuffle(inserted)
        n_del = int(tomb * len(inserted))
        for k, _ in inserted[:n_del]:
            tab, _ = ht.delete(tab, jnp.int32(k), max_probes=max_probes)
        live[r] = dict(inserted[n_del:])
        keys[r] = np.asarray(tab.keys)
        vals[r] = np.asarray(tab.vals)
    return jnp.asarray(keys), jnp.asarray(vals), live


@pytest.mark.parametrize("n,h", [(4, 32), (16, 128), (7, 64)])
def test_dh_find_kernel_matches_ref(n, h):
    rng = np.random.default_rng(n * 100 + h)
    keys, vals, live = _rand_row_tables(rng, n, h)
    batch = 64
    rows = rng.integers(0, n, batch).astype(np.int32)
    rows[rng.random(batch) < 0.15] = -1          # padding
    dsts = np.empty(batch, np.int32)
    for i, r in enumerate(rows):
        pool = list(live.get(int(max(r, 0)), {}))
        if r >= 0 and pool and rng.random() < 0.7:
            dsts[i] = pool[int(rng.integers(0, len(pool)))]
        else:
            dsts[i] = 900_000 + i                # guaranteed miss
    rows_j, dsts_j = jnp.asarray(rows), jnp.asarray(dsts)
    rb = min(dhk.DEFAULT_ROWS_PER_BLOCK, n)
    pad = (-n) % rb
    keys_p = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=ht.EMPTY)
    vals_p = jnp.pad(vals, ((0, pad), (0, 0)), constant_values=ht.EMPTY)
    got_s, got_f = dhk.dh_find_pallas(
        rows_j, dsts_j, keys_p, vals_p, max_probes=64, rows_per_block=rb,
        interpret=True)
    want_s, want_f = ref.dh_find_ref(rows_j, dsts_j, keys, vals, 64)
    np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                  np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    # oracle of the oracle: ref agrees with the per-row core probe + the
    # ground-truth live dict
    for i, (r, d) in enumerate(zip(rows, dsts)):
        if r < 0:
            assert not bool(want_f[i])
            continue
        expect = live[int(r)].get(int(d))
        assert bool(want_f[i]) == (expect is not None)
        if expect is not None:
            assert int(want_s[i]) == expect


def test_dh_find_tombstone_chains_probe_through():
    """Probes must walk through TOMB lanes (deleted keys) to later entries."""
    h = 32
    tab = ht.make(h)
    # three keys colliding into one chain
    base = jnp.int32(11)
    h0 = int(ht._slot0(base, h))
    chain = [k for k in range(2000)
             if int(ht._slot0(jnp.int32(k), h)) == h0][:3]
    assert len(chain) == 3
    for i, k in enumerate(chain):
        tab, _, _ = ht.insert(tab, jnp.int32(k), jnp.int32(i))
    tab, _ = ht.delete(tab, jnp.int32(chain[0]))   # TOMB at chain head
    keys, vals = tab.keys[None], tab.vals[None]
    rows = jnp.zeros((3,), jnp.int32)
    dsts = jnp.asarray(chain, jnp.int32)
    got_s, got_f = dhk.dh_find_pallas(rows, dsts, keys, vals,
                                      max_probes=16, rows_per_block=1,
                                      interpret=True)
    want_s, want_f = ref.dh_find_ref(rows, dsts, keys, vals, 16)
    np.testing.assert_array_equal(np.asarray(got_f).astype(bool),
                                  np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))
    assert not bool(got_f[0])                      # deleted
    assert bool(got_f[1]) and bool(got_f[2])       # found through the TOMB


# ---------------------------------------------------------------------------
# fused decay (composes the oddeven kernel; paper §II.C)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_decay_sort_matches_core_decay(impl):
    from repro.core import slab as slab_mod
    from repro.kernels import ops

    rng = np.random.default_rng(7)
    n, c = 16, 32
    dst, cnt, tot, order = _rand_slabs(rng, n, c)
    got_cnt, got_dst, got_order, got_tot = ops.decay_sort(
        cnt, dst, order, impl=impl)
    slabs, _ = slab_mod.decay(slab_mod.Slabs(dst, cnt, tot, order))
    np.testing.assert_array_equal(np.asarray(got_cnt), np.asarray(slabs.cnt))
    np.testing.assert_array_equal(np.asarray(got_dst), np.asarray(slabs.dst))
    np.testing.assert_array_equal(np.asarray(got_tot), np.asarray(slabs.tot))
    # order: both must be fully sorted descending (ties may permute)
    c_got = np.take_along_axis(np.asarray(got_cnt), np.asarray(got_order), 1)
    assert np.all(c_got[:, :-1] >= c_got[:, 1:])
    # permutation property
    assert np.all(np.sort(np.asarray(got_order), 1) == np.arange(c))
