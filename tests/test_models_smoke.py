"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (deliverable f)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.models import Model

ALL = sorted(ARCHS)


def _smoke_batch(cfg, rng, b=2, s=32):
    batch = {}
    s_text = s
    if cfg.frontend == "patch":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
        s_text = 16
    batch["tokens"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_text)).astype(np.int32))
    batch["targets"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s_text)).astype(np.int32))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_loss_finite(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg, np.random.default_rng(0))
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss {loss}"
    # random init on V=512 vocab: CE should be near log(512)=6.24
    assert 3.0 < float(metrics["ce"]) < 12.0, float(metrics["ce"])


@pytest.mark.parametrize("arch", ALL)
def test_train_step_updates_params(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = _smoke_batch(cfg, np.random.default_rng(1))

    @jax.jit
    def step(p, b):
        grads = jax.grad(lambda pp: model.loss_fn(pp, b)[0])(p)
        return jax.tree_util.tree_map(lambda x, g: x - 1e-3 * g, p, grads)

    new_params = step(params, batch)
    # gradients reached the embedding table and deepest block params
    diff = jax.tree_util.tree_map(
        lambda a, b2: float(jnp.max(jnp.abs(a - b2))), params, new_params)
    flat = jax.tree_util.tree_leaves(diff)
    assert max(flat) > 0, f"{arch}: no parameter moved"
    leaves = jax.tree_util.tree_leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves), arch


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_consistency(arch):
    """Greedy decode logits from (prefill + decode_step) must match the
    teacher-forced forward at the same positions."""
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(2)
    b, s = 2, 16
    batch = _smoke_batch(cfg, rng, b=b, s=s)
    tokens = batch["tokens"]
    max_len = 64

    logits_p, caches = jax.jit(
        lambda p, bt: model.prefill(p, bt, max_len))(params, batch)
    assert logits_p.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits_p, np.float32)))

    # one decode step after the prompt
    nxt = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)[:, None]
    pos = jnp.full((b,), tokens.shape[1], jnp.int32)
    logits_d, caches = jax.jit(model.decode_step)(params, caches, nxt, pos)
    assert logits_d.shape == (b, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits_d, np.float32)))


def test_param_counts_sane():
    # full configs should be within 25% of their nominal sizes
    nominal = {
        "granite-34b": 34e9, "starcoder2-7b": 7e9, "qwen2-7b": 7.6e9,
        "starcoder2-3b": 3e9, "phi-3-vision-4.2b": 3.8e9,
        "mamba2-130m": 130e6, "recurrentgemma-9b": 9e9,
        # assigned spec says 48L x 64e which is ~28B; the hf "16B" label
        # corresponds to 27L — the assigned shape wins (DESIGN.md)
        "moonshot-v1-16b-a3b": 28e9, "deepseek-moe-16b": 16.4e9,
    }
    for name, want in nominal.items():
        got = ARCHS[name].param_count()
        assert 0.7 * want < got < 1.35 * want, (name, got, want)
    # whisper-base ~74M
    got = ARCHS["whisper-base"].param_count()
    assert 50e6 < got < 110e6, got


def test_moe_active_params():
    cfg = ARCHS["moonshot-v1-16b-a3b"]
    active = cfg.active_param_count()
    # "A3B" at the hf 27-layer depth; the assigned 48L scales it to ~5B
    assert 2e9 < active < 6.5e9, active
    assert active < 0.25 * cfg.param_count()
