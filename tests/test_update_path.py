"""Equivalence tests for the kernel-routed update/query pipeline.

The fused pipeline (pre-aggregation + ops.slab_update + bounded slow path +
ops.oddeven_sort) must agree with ``update_batch_reference`` (the pre-kernel
O(B)-scan oracle) on edge counts, and ``impl='ref'`` must agree bit-exactly
with ``impl='pallas'`` (interpret mode off-TPU) on the same seeds.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core.hashtable import EMPTY


def edge_counts(state, n_srcs, cfg):
    """Logical view {src: ({dst: cnt}, tot)} — slot-assignment independent."""
    rows, found = mc.lookup_rows(state, jnp.arange(n_srcs, dtype=jnp.int32),
                                 cfg=cfg)
    rows, found = np.asarray(rows), np.asarray(found)
    dstm, cntm = np.asarray(state.slabs.dst), np.asarray(state.slabs.cnt)
    totm = np.asarray(state.slabs.tot)
    out = {}
    for s in range(n_srcs):
        if not found[s]:
            continue
        r = rows[s]
        live = dstm[r] != EMPTY
        out[s] = ({int(d): int(c) for d, c in zip(dstm[r][live], cntm[r][live])},
                  int(totm[r]))
    return out


def assert_invariants(state):
    inv = mc.check_invariants(state)
    assert inv["order_is_permutation"]
    assert inv["tot_matches_cnt_sum"]
    assert inv["free_slots_consistent"]
    assert inv["counts_nonnegative"]


@pytest.mark.parametrize("dup_srcs,dup_dsts", [(4, 3), (16, 12)],
                         ids=["dup_heavy", "dup_light"])
def test_duplicate_heavy_batches_match_reference(dup_srcs, dup_dsts):
    """Many duplicates per batch: aggregation must not change the counts."""
    cfg = mc.MCConfig(num_rows=64, capacity=16, sort_passes=2)
    rng = np.random.default_rng(0)
    s_new, s_ref = mc.init(cfg), mc.init(cfg)
    for _ in range(5):
        src = jnp.asarray(rng.integers(0, dup_srcs, 128).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, dup_dsts, 128).astype(np.int32))
        w = jnp.asarray(rng.integers(1, 4, 128).astype(np.int32))
        s_new = mc.update_batch(s_new, src, dst, weights=w, cfg=cfg)
        s_ref = mc.update_batch_reference(s_ref, src, dst, weights=w, cfg=cfg)
    assert_invariants(s_new)
    assert edge_counts(s_new, dup_srcs, cfg) == edge_counts(s_ref, dup_srcs, cfg)
    assert int(s_new.n_rows) == int(s_ref.n_rows)
    assert int(s_new.deferred_new) == 0


def test_all_new_batches_match_reference():
    """Every item is a new edge: the whole batch goes down the slow path."""
    cfg = mc.MCConfig(num_rows=64, capacity=32, sort_passes=1)
    s_new, s_ref = mc.init(cfg), mc.init(cfg)
    src = jnp.asarray(np.repeat(np.arange(8), 4).astype(np.int32))
    dst = jnp.asarray(np.tile(np.arange(4), 8).astype(np.int32))
    s_new = mc.update_batch(s_new, src, dst, cfg=cfg)
    s_ref = mc.update_batch_reference(s_ref, src, dst, cfg=cfg)
    assert_invariants(s_new)
    assert edge_counts(s_new, 8, cfg) == edge_counts(s_ref, 8, cfg)
    assert int(s_new.deferred_new) == 0


def test_fast_only_batches_are_bit_identical():
    """With no new edges the pipelines share slot assignment, so the states
    must agree bit-for-bit (and the lax.cond must skip the scan cleanly)."""
    cfg = mc.MCConfig(num_rows=32, capacity=8, sort_passes=1)
    rng = np.random.default_rng(1)
    base = mc.init(cfg)
    src0 = jnp.asarray(np.repeat(np.arange(4), 4).astype(np.int32))
    dst0 = jnp.asarray(np.tile(np.arange(4), 4).astype(np.int32))
    base = mc.update_batch(base, src0, dst0, cfg=cfg)  # shared seeding
    src = jnp.asarray(rng.integers(0, 4, 64).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, 4, 64).astype(np.int32))
    w = jnp.asarray(rng.integers(1, 5, 64).astype(np.int32))
    s_new = mc.update_batch(base, src, dst, weights=w, cfg=cfg)
    s_ref = mc.update_batch_reference(base, src, dst, weights=w, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(s_new.slabs.cnt),
                                  np.asarray(s_ref.slabs.cnt))
    np.testing.assert_array_equal(np.asarray(s_new.slabs.tot),
                                  np.asarray(s_ref.slabs.tot))
    np.testing.assert_array_equal(np.asarray(s_new.slabs.order),
                                  np.asarray(s_ref.slabs.order))


def test_slow_path_overflow_defers_and_counts():
    """More new edges than max_new_per_batch: the prefix is applied, the
    rest is counted in deferred_new, and invariants still hold."""
    cfg = mc.MCConfig(num_rows=64, capacity=8, sort_passes=1,
                      max_new_per_batch=4)
    state = mc.init(cfg)
    # 10 unique new edges, batch of 20 (each edge duplicated once)
    src = jnp.asarray(np.repeat(np.arange(10), 2).astype(np.int32))
    dst = jnp.asarray(np.repeat(np.arange(10) + 100, 2).astype(np.int32))
    state = mc.update_batch(state, src, dst, cfg=cfg)
    assert_invariants(state)
    assert int(state.deferred_new) == 6          # 10 unique - 4 prefix
    assert int(state.n_rows) == 4
    # resubmitting the batch drains 4 more (now-existing edges go fast path)
    state = mc.update_batch(state, src, dst, cfg=cfg)
    assert int(state.n_rows) == 8
    assert int(state.deferred_new) == 6 + 2
    assert_invariants(state)


@pytest.mark.parametrize("use_dst_hash", [False, True], ids=["scan", "hash"])
def test_impl_ref_vs_pallas_agree(use_dst_hash):
    """impl='ref' and impl='pallas' (interpret) produce identical states and
    identical query outputs on the same seeds."""
    mk = lambda impl: mc.MCConfig(num_rows=32, capacity=16, sort_passes=2,
                                  use_dst_hash=use_dst_hash, impl=impl)
    cfg_r, cfg_p = mk("ref"), mk("pallas")
    s_r, s_p = mc.init(cfg_r), mc.init(cfg_p)
    rng = np.random.default_rng(2)
    for _ in range(3):
        src = jnp.asarray(rng.integers(0, 12, 64).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, 10, 64).astype(np.int32))
        s_r = mc.update_batch(s_r, src, dst, cfg=cfg_r)
        s_p = mc.update_batch(s_p, src, dst, cfg=cfg_p)
    for a, b in zip(s_r.slabs, s_p.slabs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    srcs = jnp.arange(12, dtype=jnp.int32)
    d_r, p_r, n_r = mc.query_threshold(s_r, srcs, 0.9, cfg=cfg_r, max_items=8)
    d_p, p_p, n_p = mc.query_threshold(s_p, srcs, 0.9, cfg=cfg_p, max_items=8)
    np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_p))
    np.testing.assert_array_equal(np.asarray(n_r), np.asarray(n_p))
    np.testing.assert_allclose(np.asarray(p_r), np.asarray(p_p),
                               rtol=1e-6, atol=1e-7)


def test_query_threshold_bit_identical_to_inline_seed_path():
    """ops.cdf_query routing reproduces the seed's inline computation
    bit-for-bit on the same state (acceptance criterion)."""
    cfg = mc.MCConfig(num_rows=32, capacity=16, sort_passes=4)
    state = mc.init(cfg)
    rng = np.random.default_rng(3)
    for _ in range(10):
        src = jnp.asarray(rng.integers(0, 8, 64).astype(np.int32))
        dst = jnp.asarray((rng.zipf(1.7, 64) % 12).astype(np.int32))
        state = mc.update_batch(state, src, dst, cfg=cfg)
    srcs = jnp.asarray(np.r_[np.arange(8), [99]].astype(np.int32))  # 99 unknown
    t, k = 0.9, 8
    got_d, got_p, got_n = mc.query_threshold(state, srcs, t, cfg=cfg,
                                             max_items=k)

    # the seed's inline computation, verbatim
    rows, found = mc.lookup_rows(state, srcs, cfg=cfg)
    order = state.slabs.order[rows]
    c = jnp.take_along_axis(state.slabs.cnt[rows], order, axis=1)
    d = jnp.take_along_axis(state.slabs.dst[rows], order, axis=1)
    tot = jnp.maximum(state.slabs.tot[rows], 1).astype(jnp.float32)
    p = c.astype(jnp.float32) / tot[:, None]
    cum = jnp.cumsum(p, axis=1)
    before = cum - p
    needed = (before < t) & (c > 0) & found[:, None]
    n_needed = jnp.sum(needed.astype(jnp.int32), axis=1)
    dk = jnp.where(needed[:, :k], d[:, :k], EMPTY)
    pk = jnp.where(needed[:, :k], p[:, :k], 0.0)

    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(got_n), np.asarray(n_needed))
    # bit-identical: same float ops in the same order
    assert np.asarray(got_p).tobytes() == np.asarray(pk).tobytes()


def test_query_topk_matches_inline_seed_path():
    cfg = mc.MCConfig(num_rows=32, capacity=16, sort_passes=4)
    state = mc.init(cfg)
    rng = np.random.default_rng(4)
    for _ in range(6):
        src = jnp.asarray(rng.integers(0, 6, 64).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, 10, 64).astype(np.int32))
        state = mc.update_batch(state, src, dst, cfg=cfg)
    srcs = jnp.asarray(np.r_[np.arange(6), [77]].astype(np.int32))
    k = 8
    got_d, got_p = mc.query_topk(state, srcs, cfg=cfg, k=k)

    rows, found = mc.lookup_rows(state, srcs, cfg=cfg)
    order = state.slabs.order[rows][:, :k]
    c = jnp.take_along_axis(state.slabs.cnt[rows], order, axis=1)
    d = jnp.take_along_axis(state.slabs.dst[rows], order, axis=1)
    tot = jnp.maximum(state.slabs.tot[rows], 1).astype(jnp.float32)
    p = c.astype(jnp.float32) / tot[:, None]
    live = (c > 0) & found[:, None]
    np.testing.assert_array_equal(np.asarray(got_d),
                                  np.asarray(jnp.where(live, d, EMPTY)))
    assert (np.asarray(got_p).tobytes()
            == np.asarray(jnp.where(live, p, 0.0)).tobytes())


def test_zero_new_edge_batch_skips_slow_path_state_effects():
    """A batch with zero new edges must leave allocator state untouched."""
    cfg = mc.MCConfig(num_rows=16, capacity=8, sort_passes=1)
    state = mc.init(cfg)
    src = jnp.asarray([0, 1, 2, 3], jnp.int32)
    dst = jnp.asarray([5, 5, 5, 5], jnp.int32)
    state = mc.update_batch(state, src, dst, cfg=cfg)
    n_rows0 = int(state.n_rows)
    state2 = mc.update_batch(state, src, dst, cfg=cfg)
    assert int(state2.n_rows) == n_rows0
    assert int(state2.evictions) == int(state.evictions)
    assert int(state2.deferred_new) == 0
    assert_invariants(state2)
