"""Telemetry subsystem tests (DESIGN.md §13): histogram accuracy against
numpy quantiles, lock-free shard merging under thread hammering, flight
recorder ring + incident dump schema, span nesting/exception safety, the
exposition surface (Prometheus text, JSONL, HTTP endpoint), and the engine
integration (consistent stats snapshot, traffic vectors, poison incident).
"""

import errno
import json
import os
import threading
import urllib.request

import numpy as np
import pytest

from repro import faults
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.obs import metrics as obs
from repro.obs.export import (MetricsDumper, MetricsServer, render_jsonl,
                              render_prometheus)
from repro.runtime.fault_tolerance import EngineWriteUnavailable, RetryPolicy
from repro.serve.engine import ShardedEngine, ShardedServeConfig

FAST = RetryPolicy(max_attempts=3, base_delay_s=1e-4, max_delay_s=1e-3)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    yield
    obs.disarm()
    faults.reset()


def _engine(tmp, *, wal=True, snap=False, **kw):
    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=64, capacity=8),
                            num_shards=1, bucket_factor=2.0)
    cfg = ShardedServeConfig(
        sharded=scfg,
        snapshot_dir=os.path.join(tmp, "snap") if snap else None,
        wal_dir=os.path.join(tmp, "wal") if wal else None,
        wal_fsync="always", retry=FAST, **kw)
    return ShardedEngine(cfg)


def _batch(seed=0, n=16, rows=64):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, rows, n).astype(np.int32),
            rng.integers(0, rows, n).astype(np.int32))


# ---------------------------------------------------------------------------
# histogram accuracy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bpo", [1, 4, 16])
def test_histogram_percentiles_track_numpy(bpo):
    """Log-bucket quantile estimates stay within the analytic bound: the
    reported value is the containing bucket's upper edge, so est/true is
    in [1, (B+1)/B] for B buckets per octave (modulo nearest-rank vs
    interpolated-quantile slack on a finite sample)."""
    reg = obs.Registry(buckets_per_octave=bpo)
    rng = np.random.default_rng(0)
    data = rng.lognormal(mean=-6.0, sigma=1.5, size=4000)
    with obs.armed():
        for v in data:
            reg.hist_record("engine.observe", float(v))
    h = reg.snapshot()["histograms"]["engine.observe"]
    assert h["count"] == data.size
    assert h["max"] == pytest.approx(float(data.max()))
    assert h["sum"] == pytest.approx(float(data.sum()), rel=1e-9)
    bound = (bpo + 1.0) / bpo
    for key, q in (("p50", 0.5), ("p90", 0.9), ("p99", 0.99)):
        true = float(np.quantile(data, q))
        assert 0.95 * true <= h[key] <= bound * true * 1.05, (
            f"bpo={bpo} {key}: est {h[key]} vs true {true}")


def test_histogram_extreme_values_clamp_to_edge_buckets():
    reg = obs.Registry()
    with obs.armed():
        reg.hist_record("engine.query", 0.0)
        reg.hist_record("engine.query", -1.0)
        reg.hist_record("engine.query", 1e-30)   # below E_MIN octave
        reg.hist_record("engine.query", 1e9)     # above E_MAX octave
    h = reg.snapshot()["histograms"]["engine.query"]
    assert h["count"] == 4
    assert h["max"] == pytest.approx(1e9)
    # out-of-range samples clamp to the edge octave: the estimate is the
    # top bucket's upper edge (~1024s), while max tracks the exact value
    assert h["p99"] == pytest.approx(1024.0)


# ---------------------------------------------------------------------------
# lock-free shard merge
# ---------------------------------------------------------------------------


def test_concurrent_counter_merge_is_exact():
    """N threads x M increments merge to exactly N*M once writers quiesce
    (each thread owns its shard; nothing is lost to racing increments)."""
    reg = obs.Registry()
    n_threads, m = 8, 5000
    barrier = threading.Barrier(n_threads)

    def work():
        barrier.wait()
        for _ in range(m):
            reg.counter_add("updates")
    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.snapshot()["counters"]["updates"] == n_threads * m


def test_concurrent_histogram_merge_is_exact():
    reg = obs.Registry()
    n_threads, m = 6, 2000
    with obs.armed():
        def work(seed):
            rng = np.random.default_rng(seed)
            for v in rng.uniform(1e-4, 1e-1, m):
                reg.hist_record("engine.observe", float(v))
        ts = [threading.Thread(target=work, args=(i,))
              for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    h = reg.snapshot()["histograms"]["engine.observe"]
    assert h["count"] == n_threads * m


# ---------------------------------------------------------------------------
# spans + flight recorder
# ---------------------------------------------------------------------------


def test_span_nesting_records_parent():
    reg = obs.Registry()
    with obs.armed():
        with reg.span("engine.observe"):
            with reg.span("engine.apply"):
                pass
    by = {s["name"]: s for s in reg.spans()}
    assert by["engine.apply"]["parent"] == "engine.observe"
    assert by["engine.observe"]["parent"] is None
    snap = reg.snapshot()["histograms"]
    assert snap["engine.observe"]["count"] == 1
    assert snap["engine.apply"]["count"] == 1


def test_span_exception_safety():
    """A raising body still closes the span (recorded with error=True),
    still lands in the histogram, and the exception propagates."""
    reg = obs.Registry()
    with obs.armed():
        with pytest.raises(RuntimeError, match="boom"):
            with reg.span("engine.query"):
                raise RuntimeError("boom")
        with reg.span("engine.topn"):   # stack is clean after the raise
            pass
    by = {s["name"]: s for s in reg.spans()}
    assert by["engine.query"]["error"] is True
    assert by["engine.topn"]["error"] is False
    assert by["engine.topn"]["parent"] is None
    assert reg.snapshot()["histograms"]["engine.query"]["count"] == 1


def test_flight_recorder_ring_wraparound():
    reg = obs.Registry(flight_spans=4)
    with obs.armed():
        for i in range(10):
            with reg.span("engine.query", i=i):
                pass
    spans = reg.spans()
    assert len(spans) == 4
    assert [s["attrs"]["i"] for s in spans] == [6, 7, 8, 9]


def test_disarmed_span_and_hist_are_noops():
    reg = obs.Registry(vectors={"bucket_traffic": 4})
    assert reg.span("engine.query") is obs.NOOP_SPAN
    reg.hist_record("engine.query", 1.0)
    reg.vector_add("bucket_traffic", np.ones(4, np.int64))
    snap = reg.snapshot()
    assert snap["histograms"]["engine.query"]["count"] == 0
    assert sum(snap["vectors"]["bucket_traffic"]) == 0
    assert reg.spans() == []


# ---------------------------------------------------------------------------
# incident dumps
# ---------------------------------------------------------------------------


def test_incident_dump_schema_deltas_and_cap(tmp_path):
    reg = obs.Registry(flight_spans=8, incident_dir=str(tmp_path),
                       max_incidents=2)
    with obs.armed():
        reg.counter_add("updates", 5)
        with reg.span("engine.observe"):
            pass
        path = reg.incident("strike_out", shard=1, error=ValueError("x"))
        assert path is not None and os.path.exists(path)
        with open(path) as f:
            doc = json.load(f)
        assert doc["schema"] == "mcq-incident-v1"
        assert doc["reason"] == "strike_out"
        assert doc["seq"] == 1
        assert doc["ctx"]["shard"] == 1
        assert "ValueError" in doc["ctx"]["error"]
        assert any(s["name"] == "engine.observe" for s in doc["spans"])
        assert doc["deltas"]["updates"] == 5
        # second incident reports only what moved since the first
        reg.counter_add("updates", 3)
        path2 = reg.incident("strike_out")
        with open(path2) as f:
            assert json.load(f)["deltas"]["updates"] == 3
        # past the cap: no file, but the counter still bumps
        assert reg.incident("strike_out") is None
        assert reg.snapshot()["counters"]["incidents"] == 3


def test_incident_without_dir_counts_but_writes_nothing():
    reg = obs.Registry()
    with obs.armed():
        assert reg.incident("poison") is None
    assert reg.snapshot()["counters"]["incidents"] == 1


# ---------------------------------------------------------------------------
# exposition surface
# ---------------------------------------------------------------------------


def _demo_registry():
    reg = obs.Registry(vectors={"bucket_traffic": 4, "shard_traffic": 2})
    with obs.armed():
        reg.counter_add("updates", 2)
        reg.gauge_set("store_version", 7)
        reg.hist_record("engine.observe", 0.01)
        reg.hist_record("engine.query", 0.001)
        reg.vector_add("bucket_traffic", np.array([1, 0, 2, 0]))
        reg.vector_add("shard_traffic", np.array([3, 0]))
    return reg


def test_prometheus_render_series():
    text = render_prometheus(_demo_registry().snapshot())
    assert "# TYPE mcq_updates counter" in text
    assert "mcq_updates 2" in text
    assert "mcq_store_version 7" in text
    assert "# TYPE mcq_engine_observe_seconds summary" in text
    assert 'mcq_engine_observe_seconds{quantile="0.5"}' in text
    assert "mcq_engine_observe_seconds_count 1" in text
    assert 'mcq_bucket_traffic{bucket="2"} 2' in text
    assert 'mcq_shard_traffic{shard="0"} 3' in text


def test_jsonl_render_parses_line_per_metric():
    lines = render_jsonl(_demo_registry().snapshot()).strip().splitlines()
    rows = [json.loads(line) for line in lines]
    by = {(r["type"], r["name"]): r for r in rows}
    assert by[("counter", "updates")]["value"] == 2
    assert by[("histogram", "engine.query")]["count"] == 1
    assert by[("vector", "bucket_traffic")]["nonzero"] == {"0": 1, "2": 2}


def test_metrics_http_endpoint_smoke():
    reg = _demo_registry()
    srv = MetricsServer(reg, port=0).start()
    try:
        url = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(url + "/metrics").read().decode()
        jbody = urllib.request.urlopen(url + "/metrics.json").read().decode()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(url + "/nope")
    finally:
        srv.close()
    assert 'mcq_engine_observe_seconds{quantile="0.5"}' in body
    assert 'mcq_engine_query_seconds{quantile="0.99"}' in body
    assert 'mcq_bucket_traffic{bucket="0"} 1' in body
    snap = json.loads(jbody)
    assert snap["counters"]["updates"] == 2


def test_metrics_dumper_writes_final_image(tmp_path):
    reg = _demo_registry()
    path = str(tmp_path / "metrics.jsonl")
    dumper = MetricsDumper(reg, path, every_s=30.0).start()
    dumper.close()   # final image lands even if no cadence tick fired
    rows = [json.loads(line) for line in open(path)]
    assert any(r["name"] == "engine.observe" for r in rows)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def test_engine_telemetry_and_consistent_stats_snapshot(tmp_path):
    with obs.armed():
        eng = _engine(str(tmp_path))
        s, d = _batch()
        eng.observe(s, d)
        eng.query(np.arange(8).astype(np.int32))
        eng.topn()
        snap = eng.metrics.snapshot()
        st = eng.stats_snapshot()
    # one consistent view: host counters + health + device counters
    assert st["updates"] == 1 and st["queries"] == 1
    assert st["shards_down"] == 0
    assert st["n_rows"] > 0
    # spans landed per phase
    hists = snap["histograms"]
    assert hists["engine.observe"]["count"] == 1
    assert hists["engine.apply"]["count"] == 1
    assert hists["engine.query"]["count"] == 1
    assert hists["engine.topn"]["count"] == 1
    assert hists["wal.append"]["count"] == 1
    assert hists["wal.fsync"]["count"] >= 1
    # traffic vectors: every observed item lands in exactly one bucket
    assert sum(snap["vectors"]["bucket_traffic"]) == len(s)
    assert sum(snap["vectors"]["shard_traffic"]) == len(s)
    # gauges + provider merge
    assert snap["gauges"]["store_version"] == eng.store.version
    assert snap["gauges"]["read_epoch_lag"] == 0
    assert snap["provided"]["updates"] == 1


def test_engine_disarmed_still_serves_stats(tmp_path):
    eng = _engine(str(tmp_path), wal=False)
    s, d = _batch()
    eng.observe(s, d)
    st = eng.stats_snapshot()
    assert st["updates"] == 1
    snap = eng.metrics.snapshot()
    assert snap["histograms"]["engine.observe"]["count"] == 0
    assert sum(snap["vectors"]["bucket_traffic"]) == 0


def test_poison_fires_incident_dump(tmp_path):
    inc = str(tmp_path / "inc")
    with obs.armed():
        eng = _engine(str(tmp_path), incident_dir=inc)
        eng.observe(*_batch())
        faults.arm("wal.append.write", OSError(errno.ENOSPC, "disk full"))
        with pytest.raises(EngineWriteUnavailable):
            eng.observe(*_batch(1))
    files = sorted(os.listdir(inc))
    assert files, "poison produced no incident dump"
    with open(os.path.join(inc, files[0])) as f:
        doc = json.load(f)
    assert doc["schema"] == "mcq-incident-v1"
    assert doc["reason"] == "poison"
    assert any(sp["name"] == "engine.observe" for sp in doc["spans"])
    assert doc["deltas"], "incident carries no metric deltas"
