"""mcqlint self-tests (DESIGN.md §11): the fixture corpus is the linter's
own regression suite — every rule flags exactly its seeded violation and
nothing else, and the real tree is clean.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:  # tools/ lives at the repo root, not under src/
    sys.path.insert(0, REPO)

from tools.mcqlint import catalog, run_paths                  # noqa: E402
from tools.mcqlint.core import all_rules                      # noqa: E402

FIXTURES = os.path.join(REPO, "tools", "mcqlint", "fixtures")
SRC = os.path.join(REPO, "src")
TESTS = os.path.join(REPO, "tests")

#: every rule has exactly one seeded-violation fixture
RULE_TO_FIXTURE = {
    "MCQ-L001": "fixture_l001.py",
    "MCQ-L002": "fixture_l002.py",
    "MCQ-L003": "fixture_l003.py",
    "MCQ-L004": "fixture_l004.py",
    "MCQ-O001": "fixture_o001.py",
    "MCQ-O002": "fixture_o002.py",
    "MCQ-P001": "fixture_p001.py",
    "MCQ-C001": "fixture_c001.py",
    "MCQ-U001": "fixture_u001.py",
    "MCQ-F401": "fixture_f401.py",
    "MCQ-E741": "fixture_e741.py",
    "MCQ-R001": "fixture_r001.py",
    "MCQ-M001": "fixture_m001.py",
}


def _fixture(name):
    return os.path.join(FIXTURES, name)


# ---------------------------------------------------------------------------
# rule <-> fixture diagonal
# ---------------------------------------------------------------------------


def test_every_rule_has_a_fixture():
    ids = {r.id for r in all_rules()}
    assert ids == set(RULE_TO_FIXTURE), (
        "rule set and fixture corpus diverged")


@pytest.mark.parametrize("rule_id,fixture", sorted(RULE_TO_FIXTURE.items()))
def test_fixture_trips_exactly_its_rule(rule_id, fixture):
    """Standalone, a fixture produces findings for its own rule ONLY —
    a seeded violation that also trips a neighbouring rule would make the
    corpus useless for localising regressions."""
    findings = run_paths([_fixture(fixture)])
    assert findings, f"{fixture} produced no findings"
    assert {f.rule for f in findings} == {rule_id}, (
        f"{fixture} tripped {sorted({f.rule for f in findings})}, "
        f"expected only {rule_id}")


@pytest.mark.parametrize("rule_id,fixture", sorted(RULE_TO_FIXTURE.items()))
def test_rule_selects_its_fixture_from_the_corpus(rule_id, fixture):
    """Each rule, run alone over the whole corpus, flags its own fixture
    (other fixtures may legitimately contain secondary matter for the same
    rule, but the designated one must be found)."""
    findings = run_paths([FIXTURES], select=[rule_id])
    assert findings, f"{rule_id} found nothing in the corpus"
    assert all(f.rule == rule_id for f in findings)
    flagged = {os.path.basename(f.path) for f in findings}
    assert fixture in flagged, (
        f"{rule_id} flagged {sorted(flagged)} but not {fixture}")


# ---------------------------------------------------------------------------
# the real tree is clean (the CI gate's contract)
# ---------------------------------------------------------------------------


def test_real_tree_is_clean():
    findings = run_paths([SRC], tests_dir=TESTS)
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# CLI behaviour (exit codes + junit artifact)
# ---------------------------------------------------------------------------


def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "tools.mcqlint", *args],
        cwd=REPO, env=env, capture_output=True, text=True)


def test_cli_clean_tree_exits_zero(tmp_path):
    junit = tmp_path / "lint.xml"
    proc = _run_cli("src", "--junit", str(junit))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    xml = junit.read_text()
    assert 'failures="0"' in xml
    assert "MCQ-L001" in xml  # one testcase per rule, even when clean


def test_cli_fixture_corpus_exits_nonzero(tmp_path):
    junit = tmp_path / "lint.xml"
    proc = _run_cli("tools/mcqlint/fixtures", "--tests-dir", "",
                    "--junit", str(junit))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    # every rule fires on the corpus -> every junit testcase fails
    xml = junit.read_text()
    assert xml.count("<failure") == len(RULE_TO_FIXTURE)


@pytest.mark.parametrize("fixture", sorted(RULE_TO_FIXTURE.values()))
def test_cli_each_fixture_exits_nonzero(fixture):
    proc = _run_cli(os.path.join("tools", "mcqlint", "fixtures", fixture),
                    "--tests-dir", "")
    assert proc.returncode == 1, (
        f"{fixture}: expected findings, got\n{proc.stdout}{proc.stderr}")


# ---------------------------------------------------------------------------
# catalog consistency
# ---------------------------------------------------------------------------


def test_catalog_covers_every_rule_and_assumption_links():
    by_rule = catalog.by_rule()
    for rule in all_rules():
        inv = by_rule[rule.id]
        if inv.key != "I-hygiene":  # pure style: no assumption to cite
            assert inv.assumptions, f"{inv.id} cites no A-assumptions"
        assert all(a.startswith("A") for a in inv.assumptions)


def test_catalog_table_renders():
    table = catalog.render_table()
    for inv in catalog.CATALOG:
        assert inv.id in table
    assert "MCQ-L003" in table
