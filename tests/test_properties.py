"""Property-based tests (hypothesis) for the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import mcprioq as mc
from repro.core import slab as sl

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# hash table
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(st.lists(st.integers(min_value=0, max_value=10_000),
                min_size=1, max_size=60, unique=True))
def test_hashtable_insert_then_lookup(keys):
    tab = ht.make(256)
    for i, k in enumerate(keys):
        tab, _, ok = ht.insert(tab, jnp.int32(k), jnp.int32(i))
        assert bool(ok)
    for i, k in enumerate(keys):
        val, found = ht.lookup(tab, jnp.int32(k))
        assert bool(found) and int(val) == i


@settings(**SETTINGS)
@given(st.lists(st.integers(min_value=0, max_value=500), min_size=2,
                max_size=40, unique=True),
       st.data())
def test_hashtable_delete_preserves_others(keys, data):
    tab = ht.make(128)
    for i, k in enumerate(keys):
        tab, _, _ = ht.insert(tab, jnp.int32(k), jnp.int32(i))
    victim = data.draw(st.sampled_from(keys))
    tab, deleted = ht.delete(tab, jnp.int32(victim))
    assert bool(deleted)
    for i, k in enumerate(keys):
        val, found = ht.lookup(tab, jnp.int32(k))
        if k == victim:
            assert not bool(found)
        else:
            assert bool(found) and int(val) == i
    # tombstone slot is reusable
    tab, _, ok = ht.insert(tab, jnp.int32(victim), jnp.int32(999))
    val, found = ht.lookup(tab, jnp.int32(victim))
    assert bool(ok) and bool(found) and int(val) == 999


# ---------------------------------------------------------------------------
# odd-even transposition (the paper's lock-free bubble sort)
# ---------------------------------------------------------------------------


def _total_inversions(cnt, order):
    """Global (not adjacent) inversions wrt descending order, per batch.
    Compare-exchange networks never increase THIS count; the adjacent count
    can transiently rise (hypothesis found the counterexample)."""
    c = np.take_along_axis(np.asarray(cnt), np.asarray(order), axis=1)
    return int(sum(np.sum(np.triu(row[:, None] < row[None, :], k=1))
                   for row in c))


@settings(**SETTINGS)
@given(st.integers(min_value=2, max_value=32),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_oddeven_pass_properties(cap, seed):
    rng = np.random.default_rng(seed)
    cnt = jnp.asarray(rng.integers(0, 1000, (4, cap)).astype(np.int32))
    order = jnp.asarray(
        np.stack([rng.permutation(cap) for _ in range(4)]).astype(np.int32))
    new_order = sl.oddeven_passes(cnt, order, 1)
    # (1) permutation preserved
    assert np.all(np.sort(np.asarray(new_order), 1) == np.arange(cap))
    # (2) total inversions never increase (compare-exchange theorem)
    assert _total_inversions(cnt, new_order) <= _total_inversions(cnt, order)
    # (3) cap passes fully sort
    done = sl.oddeven_passes(cnt, order, cap)
    assert int(jnp.sum(sl.inversions(cnt, done))) == 0


@settings(**SETTINGS)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_single_increment_fixed_by_one_pass(seed):
    """The paper's normal case: a sorted queue plus one small increment needs
    at most one pass (single adjacent swap)."""
    rng = np.random.default_rng(seed)
    cap = 16
    base = np.sort(rng.integers(1, 1000, cap).astype(np.int32))[::-1].copy()
    pos = rng.integers(0, cap)
    inc = base.copy()
    # small increment: at most up to the next-larger neighbour + 1
    inc[pos] += rng.integers(1, 3)
    cnt = jnp.asarray(inc[None])
    order = jnp.arange(cap, dtype=jnp.int32)[None]
    after = sl.oddeven_passes(cnt, order, 1)
    inv = int(sl.inversions(cnt, after)[0])
    # one pass fixes a single out-of-place element moving <= 1 slot; larger
    # jumps may need one more pass, never more than 2 for a +2 bump
    if inv:
        after2 = sl.oddeven_passes(cnt, after, 1)
        assert int(sl.inversions(cnt, after2)[0]) == 0


# ---------------------------------------------------------------------------
# MCPrioQ end-to-end invariants
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=4))
def test_mcprioq_invariants_random_streams(seed, passes):
    cfg = mc.MCConfig(num_rows=32, capacity=8, sort_passes=passes)
    state = mc.init(cfg)
    rng = np.random.default_rng(seed)
    for _ in range(4):
        src = jnp.asarray(rng.integers(0, 16, 32).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, 24, 32).astype(np.int32))
        w = jnp.asarray(rng.integers(1, 5, 32).astype(np.int32))
        state = mc.update_batch(state, src, dst, weights=w, cfg=cfg)
        inv = mc.check_invariants(state)
        assert inv["order_is_permutation"]
        assert inv["tot_matches_cnt_sum"]
        assert inv["free_slots_consistent"]
        assert inv["counts_nonnegative"]
    # decay keeps every invariant too
    state = mc.decay(state, cfg=cfg)
    inv = mc.check_invariants(state)
    assert all(v for k, v in inv.items() if isinstance(v, bool))
    # after decay the order is exactly sorted (compaction contract)
    assert inv["sorted_fraction"] == 1.0


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_query_threshold_monotone_in_t(seed):
    """CDF^-1(t) is monotone: higher threshold never needs fewer items."""
    cfg = mc.MCConfig(num_rows=16, capacity=16, sort_passes=16)
    state = mc.init(cfg)
    rng = np.random.default_rng(seed)
    src = jnp.zeros(64, jnp.int32)
    dst = jnp.asarray((rng.zipf(1.6, 64) % 12).astype(np.int32))
    state = mc.update_batch(state, src, dst, cfg=cfg)
    prev = 0
    for t in (0.1, 0.3, 0.5, 0.7, 0.9, 0.99):
        _, _, n = mc.query_threshold(state, src[:1], t, cfg=cfg, max_items=16)
        assert int(n[0]) >= prev
        prev = int(n[0])


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.sampled_from([0, 4]))
def test_dst_hash_on_off_bit_identical_under_churn(seed, decay_block):
    """The dst hash is an *optimisation*: with it on or off, the structure
    must evolve bit-identically (slabs, src table, allocator, Space-Saving
    evictions) through interleaved update/decay/eviction churn — and the
    hash itself must stay consistent (every live slot reachable, no stale
    entries after decay repair)."""
    import dataclasses
    cfg_h = mc.MCConfig(num_rows=16, capacity=4, sort_passes=1,
                        use_dst_hash=True, decay_block_rows=decay_block,
                        dh_rebuild_fraction=0.1)
    cfg_s = dataclasses.replace(cfg_h, use_dst_hash=False)
    s_h, s_s = mc.init(cfg_h), mc.init(cfg_s)
    rng = np.random.default_rng(seed)
    for i in range(6):
        # capacity 4 with 8 dsts per src: constant Space-Saving eviction
        src = jnp.asarray(rng.integers(0, 12, 48).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, 8, 48).astype(np.int32))
        w = jnp.asarray(rng.integers(1, 5, 48).astype(np.int32))
        s_h = mc.update_batch(s_h, src, dst, weights=w, cfg=cfg_h)
        s_s = mc.update_batch(s_s, src, dst, weights=w, cfg=cfg_s)
        if i % 2 == 1:
            s_h = mc.decay(s_h, cfg=cfg_h)
            s_s = mc.decay(s_s, cfg=cfg_s)
        for name in ("slabs", "src_table"):
            for a, b in zip(getattr(s_h, name), getattr(s_s, name)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for name in ("n_rows", "dropped_rows", "dropped_probes",
                     "evictions", "deferred_new", "decay_cursor",
                     "decay_steps"):
            assert int(getattr(s_h, name)) == int(getattr(s_s, name)), name
        inv = mc.check_invariants(s_h, cfg_h)
        assert inv["dst_hash_consistent"]
        assert inv["tot_matches_cnt_sum"] and inv["free_slots_consistent"]


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_update_batch_order_independence_for_existing_edges(seed):
    """Fast-path updates are a commutative scatter-add: permuting the batch
    gives the identical counts (the determinism analogue of atomics)."""
    cfg = mc.MCConfig(num_rows=8, capacity=8, sort_passes=0)
    base = mc.init(cfg)
    # seed all edges first so everything takes the fast path
    src0 = jnp.asarray(np.repeat(np.arange(4), 4).astype(np.int32))
    dst0 = jnp.asarray(np.tile(np.arange(4), 4).astype(np.int32))
    base = mc.update_batch(base, src0, dst0, cfg=cfg)

    rng = np.random.default_rng(seed)
    src = rng.integers(0, 4, 32).astype(np.int32)
    dst = rng.integers(0, 4, 32).astype(np.int32)
    w = rng.integers(1, 9, 32).astype(np.int32)
    perm = rng.permutation(32)
    s1 = mc.update_batch(base, jnp.asarray(src), jnp.asarray(dst),
                         weights=jnp.asarray(w), cfg=cfg)
    s2 = mc.update_batch(base, jnp.asarray(src[perm]), jnp.asarray(dst[perm]),
                         weights=jnp.asarray(w[perm]), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(s1.slabs.cnt),
                                  np.asarray(s2.slabs.cnt))
    np.testing.assert_array_equal(np.asarray(s1.slabs.tot),
                                  np.asarray(s2.slabs.tot))


# ---------------------------------------------------------------------------
# inference path (DESIGN.md §8): fused gather, chunked walk, draft walk
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.sampled_from([1, 2, 4]),
       st.sampled_from(["ref", "pallas"]),
       st.booleans(),
       st.sampled_from([0.3, 0.5, 0.9, 0.99]))
def test_query_fused_unfused_chunks_impl_bit_identical(seed, chunks, impl,
                                                       fused, t):
    """Acceptance property: every (chunks, impl, fused) combination produces
    byte-identical threshold and top-k results — the integer-walk contract
    makes chunking associativity-free, the fused gather is a pure layout
    change, and the kernels match the ref oracle exactly."""
    import dataclasses
    base = mc.MCConfig(num_rows=32, capacity=8, sort_passes=1)
    state = mc.init(base)
    rng = np.random.default_rng(seed)
    for _ in range(3):
        src = jnp.asarray(rng.integers(0, 12, 48).astype(np.int32))
        dst = jnp.asarray((rng.zipf(1.5, 48) % 10).astype(np.int32))
        state = mc.update_batch(state, src, dst, cfg=base)
    srcs = jnp.asarray(np.r_[np.arange(12), [4242]].astype(np.int32))
    want = mc.query_threshold(state, srcs, t, cfg=base, max_items=8)
    want_top = mc.query_topk(state, srcs, cfg=base, k=8)
    cfg = dataclasses.replace(base, fused_query=fused, impl=impl,
                              query_chunks=chunks)
    got = mc.query_threshold(state, srcs, t, cfg=cfg, max_items=8)
    got_top = mc.query_topk(state, srcs, cfg=cfg, k=8)
    for a, b in zip(want, got):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    for a, b in zip(want_top, got_top):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=6),
       st.sampled_from(["ref", "pallas"]))
def test_draft_walk_kernel_matches_scan_oracle(seed, k, impl):
    """Acceptance property: the one-shot walk kernel == the k-dispatch scan
    oracle token-for-token, including dead lanes (unknown contexts)."""
    import dataclasses
    from repro.core import speculative as spec
    ncfg = spec.NGramConfig(
        order=2, mc=mc.MCConfig(num_rows=128, capacity=8, sort_passes=1,
                                impl=impl))
    drafter = spec.init(ncfg)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, 32, (4, 64)).astype(np.int32))
    drafter = spec.observe(drafter, toks, cfg=ncfg)
    ctx = jnp.asarray(np.concatenate(
        [np.asarray(toks)[:, 30:32],
         rng.integers(50_000, 60_000, (2, 2)).astype(np.int32)]))
    got_t, got_o = spec.draft(drafter, ctx, cfg=ncfg, k=k)
    want_t, want_o = spec.draft_reference(drafter, ctx, cfg=ncfg, k=k)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(got_o), np.asarray(want_o))
    # ok rows are prefixes: a dead lane never revives
    oks = np.asarray(got_o).astype(bool)
    assert np.all(oks == (np.cumprod(oks, axis=1) > 0))
