"""Expert-parallel MoE (shard_map all_to_all) vs the dense-pjit oracle."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.configs import smoke_config
    from repro.models import moe as moe_mod

    # 8 experts over model axis 4 -> 2 experts/shard; generous capacity so
    # both paths drop nothing and must agree exactly
    cfg = dataclasses.replace(
        smoke_config("deepseek-moe-16b"),
        num_experts=8, experts_per_token=2, num_shared_experts=1,
        moe_d_ff=32, capacity_factor=8.0, dtype="float32")
    p = moe_mod.make_moe(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))

    ref, aux_ref = moe_mod.apply_moe(p, x, cfg)  # dense path, no mesh

    mesh = compat.make_mesh((2, 4), ("data", "model"))
    cfg_ep = dataclasses.replace(cfg, moe_impl="ep")
    with mesh:
        got, aux = jax.jit(
            lambda pp, xx: moe_mod.apply_moe(pp, xx, cfg_ep))(p, x)
    got, ref = np.asarray(got), np.asarray(ref)
    assert int(aux["moe_dropped"]) == 0, int(aux["moe_dropped"])
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)
    # aux losses agree (lb loss is exact when token counts are balanced
    # across shards by construction here: same tokens, pmean'd stats)
    np.testing.assert_allclose(float(aux["moe_z_loss"]),
                               float(aux_ref["moe_z_loss"]), rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(aux["moe_expert_counts"]),
                                  np.asarray(aux_ref["moe_expert_counts"]))

    # gradients flow through routing (router + experts move)
    def loss(pp):
        with mesh:
            out, aux2 = moe_mod.apply_moe(pp, x, cfg_ep)
        return jnp.sum(out * out) + 1e-2 * aux2["moe_lb_loss"]
    g = jax.grad(loss)(p)
    for name in ("router", "we1", "we2"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
    print("MOE-EP-OK")
""")


def test_moe_ep_matches_dense_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    assert "MOE-EP-OK" in out.stdout
