"""Interleaving-explorer regression tests (DESIGN.md §11).

The contract: with the shipped pre-fix bodies of the three races the
PR-4/PR-5 reviews caught, the explorer finds each violation and the
violating schedule replays deterministically; the current (fixed) code
paths are exhaustively clean under the same schedule space.
"""

import numpy as np
import pytest

from repro.analysis import explorer as ex


RACES = sorted(s.name for s in ex.RACE_SCENARIOS)


# ---------------------------------------------------------------------------
# reverted fixes -> race re-found, deterministically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", RACES)
def test_reverted_race_is_found(name):
    result = ex.explore(ex.SCENARIOS[name], reverted=True)
    assert result.found, (
        f"{name}: no violation in {result.runs} schedules")
    first = result.violations[0]
    assert first.violations and first.trace


@pytest.mark.parametrize("name", RACES)
def test_violating_schedule_replays_deterministically(name):
    result = ex.explore(ex.SCENARIOS[name], reverted=True)
    assert result.found
    first = result.violations[0]
    replay_a = ex.replay(ex.SCENARIOS[name], reverted=True,
                         trace=first.trace)
    replay_b = ex.replay(ex.SCENARIOS[name], reverted=True,
                         trace=first.trace)
    assert replay_a.trace == first.trace, "replay diverged from the record"
    assert replay_a.violations == first.violations
    assert replay_b == replay_a, "two replays of one schedule disagreed"


def test_exploration_itself_is_deterministic():
    a = ex.explore(ex.SCENARIOS["stats_lost_update"], reverted=True)
    b = ex.explore(ex.SCENARIOS["stats_lost_update"], reverted=True)
    assert a.first_trace == b.first_trace
    assert a.runs == b.runs


def test_wal_double_replay_reproduces_the_double_apply():
    """Among the reverted recovery driver's violations there is the literal
    double apply — marker 99 (the concurrent observe) replayed twice."""
    result = ex.explore(ex.SCENARIOS["wal_double_replay"], reverted=True,
                        stop_on_violation=False)
    assert result.exhausted
    doubled = [v for v in result.violations
               if any("exactly-once" in m and "99, 99" in m
                      for m in v.violations)]
    assert doubled, "the double-applied batch was never observed"


# ---------------------------------------------------------------------------
# HEAD is clean, exhaustively, under the same schedule space
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", RACES)
def test_head_is_clean_exhaustively(name):
    result = ex.explore(ex.SCENARIOS[name], reverted=False,
                        stop_on_violation=False)
    assert result.exhausted, (
        f"{name}: schedule space not drained ({result.runs} runs)")
    assert not result.found, "\n".join(
        "; ".join(v.violations) for v in result.violations)


def test_mixed_head_random_is_clean():
    result = ex.explore(ex.SCENARIOS["mixed_head"], reverted=False,
                        mode="random", random_runs=32, seed=7,
                        stop_on_violation=False)
    assert result.runs == 32
    assert not result.found, "\n".join(
        "; ".join(v.violations) for v in result.violations)


# ---------------------------------------------------------------------------
# scheduler mechanics
# ---------------------------------------------------------------------------


def test_deadlock_is_detected_as_a_violation():
    class DeadlockScenario(ex.Scenario):
        name = "deadlock_probe"

        def build(self, sched, reverted):
            a = ex.SchedLock(sched, "a")
            b = ex.SchedLock(sched, "b")

            def t1():
                with a:
                    with b:
                        pass

            def t2():
                with b:
                    with a:
                        pass

            from collections import OrderedDict
            threads = OrderedDict((("t1", t1), ("t2", t2)))
            return ex.ScenarioInstance(threads, lambda: [], lambda: None)

    result = ex.explore(DeadlockScenario(), reverted=False,
                        stop_on_violation=True)
    assert result.found
    assert any("deadlock" in m for m in result.violations[0].violations)


def test_sched_lock_blocks_until_released():
    """A SchedLock waiter is not runnable while the lock is held — the
    driver never schedules it into a busy-wait."""
    events = []

    class HandoffScenario(ex.Scenario):
        name = "handoff_probe"

        def build(self, sched, reverted):
            lock = ex.SchedLock(sched, "only")

            def holder():
                with lock:
                    sched.yield_point("inside")  # offer a switch point
                    events.append("holder-critical")
                events.append("holder-exit")

            def waiter():
                with lock:
                    events.append("waiter-critical")

            from collections import OrderedDict
            threads = OrderedDict((("holder", holder), ("waiter", waiter)))
            return ex.ScenarioInstance(threads, lambda: [], lambda: None)

    result = ex.explore(HandoffScenario(), reverted=False,
                        stop_on_violation=False)
    assert result.exhausted and not result.found
    # in every explored schedule the critical sections never interleaved
    assert events.count("holder-critical") == result.runs
    assert events.count("waiter-critical") == result.runs


def test_fake_kernel_layer_restores_the_real_factories():
    from repro.core import mcprioq as mc
    from repro.core import sharded as sh
    real = (sh.make_update_fn, mc.counter_stats)
    with ex.fake_kernel_layer():
        assert sh.make_update_fn is ex._fake_make_update_fn
    assert (sh.make_update_fn, mc.counter_stats) == real


def test_instrumented_stats_update_routes_through_setitem():
    sched = ex.Scheduler()
    stats = ex.InstrumentedStats(sched, {"a": 0})
    stats.update({"a": 2, "b": 3})
    stats.update(c=4)
    assert dict(stats) == {"a": 2, "b": 3, "c": 4}


def test_smoke_cli_passes(tmp_path, capsys):
    junit = tmp_path / "explorer.xml"
    rc = ex.main(["--smoke", "--junit", str(junit)])
    assert rc == 0
    xml = junit.read_text()
    assert 'failures="0"' in xml
    for name in RACES:
        assert f"{name}:reverted" in xml
        assert f"{name}:head" in xml


def test_single_scenario_cli_exit_codes():
    assert ex.main(["--scenario", "stats_lost_update", "--reverted"]) == 1
    assert ex.main(["--scenario", "stats_lost_update"]) == 0


def test_fixed_restore_matches_engine_restore_semantics():
    """The fixed driver used for the HEAD variant really is the shipped
    shape: replay happens entirely inside one write-lock hold (mirrors
    ShardedEngine.restore), so a trailing writer observes a consistent
    position."""
    sched = ex.Scheduler()
    with ex.fake_kernel_layer():
        import os
        import tempfile
        tmp = tempfile.mkdtemp(prefix="mcq-explorer-test-")
        try:
            eng = ex.build_engine(sched, wal_dir=os.path.join(tmp, "wal"))
            dst = np.array([0], np.int32)
            for marker in (4, 5):
                eng.observe(np.array([marker], np.int32), dst)
            replayed = ex._fixed_restore(eng)
            assert replayed == 2
            markers = [int(m) for m in eng.store._snap.state.markers]
            assert markers == [4, 5]
            assert eng._seq == 1
        finally:
            eng.wal.close()
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)
