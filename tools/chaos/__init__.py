"""Crash-soak chaos harness (DESIGN.md §12): SIGKILL a serving worker in
a loop and assert bit-exact recovery against a WAL-replay oracle."""
