"""Crash-soak harness (DESIGN.md §12): kill a serving worker under load,
recover, and prove the recovered state bit-exact.

One *life* = spawn a worker subprocess that observes deterministic
seeded batches through a durable :class:`ShardedEngine` (WAL every batch,
async snapshot cadence), then kill it — either an external SIGKILL mid
load, or a self-SIGKILL armed *inside* a persistence failpoint via
``MCQ_FAILPOINTS`` (``site=kill@nth:K``), so deaths land mid-append,
mid-fsync, mid-snapshot-write and mid-manifest-commit, not just between
steps.  After each death the harness:

  1. recovers in-process (``restore()`` = newest complete snapshot + WAL
     replay), timing it — the recovery-time series is the B9 benchmark;
  2. rebuilds an *oracle* engine with no persistence at all by replaying
     every durable WAL record from an empty chain through the same
     ``observe()`` pipeline;
  3. asserts every array leaf of the recovered published snapshot equals
     the oracle's bit-for-bit, and that the recovered WAL position equals
     the last durable record.

Because a batch is WAL-appended strictly before it is applied (I3) and
the apply pipeline is replay-deterministic (I7/A12), snapshot+tail-replay
and full-replay-from-empty must converge to the identical state whatever
instant the process died at.  Any divergence — a torn record applied, a
record applied twice across a snapshot boundary, a half-published epoch
restored — fails the soak.

  PYTHONPATH=src python -m tools.chaos.soak --kills 20 \
      --junit chaos.xml --out benchmarks/BENCH_faults.json

Rows land in ``BENCH_faults.json`` (schema-checked by
``benchmarks/run.py --validate``); ``--junit`` writes one testcase per
kill for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time
from typing import List, Optional
from xml.sax.saxutils import escape

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(_HERE))

#: kill schedule, cycled per life: None = external SIGKILL at a jittered
#: step; otherwise the failpoint armed (via MCQ_FAILPOINTS) to SIGKILL the
#: worker from *inside* the persistence edge at a jittered hit count
KILL_MODES = (
    None,
    "wal.append.write",
    None,
    "wal.append.fsync",
    None,
    "snapshot.arrays_write",
    "wal.append.write",
    "snapshot.manifest_commit",
)

#: hard cap on steps per life — an armed failpoint the worker never
#: reaches (e.g. snapshot cadence not yet due) falls back to an external
#: kill instead of hanging the soak
MAX_STEPS_PER_LIFE = 40


def batch_for(seed: int, step: int, rows: int, batch: int):
    """The deterministic load stream: batch ``step`` is a pure function of
    (seed, step), so worker lives and the oracle generate identical data
    without sharing anything but the WAL."""
    rng = np.random.default_rng([seed, step])
    src = rng.integers(0, rows, batch).astype(np.int32)
    dst = rng.integers(0, rows, batch).astype(np.int32)
    return src, dst


# ---------------------------------------------------------------------------
# engine plumbing (imported lazily: --help must not pay jax init)
# ---------------------------------------------------------------------------


def _build_engine(workdir: Optional[str], rows: int, *,
                  snapshot_every: int = 0):
    from repro.core import mcprioq as mc
    from repro.core import sharded as sh
    from repro.serve.engine import ShardedEngine, ShardedServeConfig

    scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=rows, capacity=16,
                                             sort_passes=1),
                            num_shards=1, bucket_factor=2.0)
    cfg = ShardedServeConfig(
        sharded=scfg,
        snapshot_dir=os.path.join(workdir, "snap") if workdir else None,
        wal_dir=os.path.join(workdir, "wal") if workdir else None,
        wal_fsync="always",
        snapshot_every=snapshot_every,
        decay_threshold=1 << 30,   # no decay: lives stay comparable
    )
    return ShardedEngine(cfg)


def worker_main(args) -> None:
    """The killable serving loop: restore (or lay down the step-0 base
    snapshot), then observe deterministic batches forever, one WAL record
    per step, printing ``STEP <seq>`` after each durable+applied batch."""
    eng = _build_engine(args.dir, args.rows,
                        snapshot_every=args.snapshot_every)
    try:
        info = eng.restore()
        print(f"RESTORED step={info['step']} replayed={info['replayed']}",
              flush=True)
    except FileNotFoundError:
        eng.checkpoint()   # step-0 base: recovery always has a snapshot
    start = eng.wal.next_seq
    print(f"READY {start}", flush=True)
    step = start
    while True:
        src, dst = batch_for(args.seed, step, args.rows, args.batch)
        eng.observe(src, dst)
        print(f"STEP {step}", flush=True)
        step += 1
        if args.sleep:
            time.sleep(args.sleep)


# ---------------------------------------------------------------------------
# the soak loop (parent)
# ---------------------------------------------------------------------------


def _spawn_worker(workdir: str, rows: int, batch: int, seed: int,
                  snapshot_every: int, kill_site: Optional[str],
                  kill_hit: int, telemetry: bool = False,
                  poison: bool = False) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO, env.get("PYTHONPATH", "")])
    if telemetry:
        # arm the obs gate in the worker (spans, histograms, incidents) and
        # point the flight recorder's incident dumps at the workdir
        env["MCQ_METRICS"] = "1"
        env["MCQ_METRICS_INCIDENT_DIR"] = os.path.join(workdir, "incidents")
    else:
        env.pop("MCQ_METRICS", None)
        env.pop("MCQ_METRICS_INCIDENT_DIR", None)
    if kill_site is not None:
        # a poison life raises ENOSPC (persistent) instead of SIGKILLing:
        # the write path poisons, dumps a flight-recorder incident, and the
        # worker dies on the escalation — a diagnosable death, not a silent
        # one, exercising the incident pipeline under real load
        action = "raise:28" if poison else "kill"
        env["MCQ_FAILPOINTS"] = f"{kill_site}={action}@nth:{kill_hit}"
    else:
        env.pop("MCQ_FAILPOINTS", None)
    return subprocess.Popen(
        [sys.executable, "-m", "tools.chaos.soak", "--worker",
         "--dir", workdir, "--rows", str(rows), "--batch", str(batch),
         "--seed", str(seed), "--snapshot-every", str(snapshot_every)],
        cwd=REPO, env=env, stdout=subprocess.PIPE, text=True)


def _run_life(proc: subprocess.Popen, kill_after_steps: int) -> dict:
    """Read worker progress until the kill moment (or the armed failpoint
    fires); returns what the parent observed about the life."""
    steps_seen = 0
    armed_death = False
    deadline_steps = kill_after_steps
    for line in proc.stdout:
        if line.startswith("STEP "):
            steps_seen += 1
            if steps_seen >= deadline_steps:
                break
    else:
        armed_death = True   # stdout closed: the failpoint killed it
    if not armed_death:
        proc.send_signal(signal.SIGKILL)
    proc.wait()
    proc.stdout.close()
    return {"steps_seen": steps_seen, "armed_death": armed_death,
            "exit": proc.returncode}


def _verify_recovery(workdir: str, rows: int, batch: int, seed: int):
    """Recover, rebuild the oracle from the full deterministic history,
    and compare bit-exactly.

    The WAL alone is not the full history — committed snapshots GC the
    segments they cover — so the oracle replays ``batch_for(seed, 0..L)``
    from an empty chain through the same ``observe()`` pipeline, where
    ``L`` (the last durable step) is established independently of the
    recovered engine: the newest complete snapshot's ``wal_seq`` plus the
    WAL tail.  Each surviving WAL record is also checked against the
    deterministic stream, so a torn record that replay failed to reject
    is caught directly.

    Returns (recovery_seconds, last_seq, replayed, mismatches).
    """
    import jax
    from repro.persist import snapshot as snapshot_io
    from repro.persist.wal import WriteAheadLog

    t0 = time.perf_counter()
    eng = _build_engine(workdir, rows)
    info = eng.restore()
    recovery_s = time.perf_counter() - t0

    mismatches: List[str] = []
    snap_dir = os.path.join(workdir, "snap")
    step = snapshot_io.latest_complete_step(snap_dir)
    last = snapshot_io.load_meta(snap_dir, step)["wal_seq"] if step is not None else -1
    for seq, s, d, w in WriteAheadLog(os.path.join(workdir, "wal")).replay():
        last = max(last, seq)
        es, ed = batch_for(seed, seq, rows, batch)
        if not (np.array_equal(s, es) and np.array_equal(d, ed)
                and np.all(np.asarray(w) == 1)):
            mismatches.append(f"durable record {seq} does not match the "
                              f"deterministic stream (torn record "
                              f"survived replay)")
    if eng._seq != last:
        mismatches.append(
            f"wal position: recovered seq {eng._seq} != last durable "
            f"step {last}")

    oracle = _build_engine(None, rows)
    for i in range(last + 1):
        oracle.observe(*batch_for(seed, i, rows, batch))
    durable = last + 1   # number of durable steps
    snap_r, snap_o = eng.store.acquire(), oracle.store.acquire()
    try:
        leaves_r = jax.tree_util.tree_leaves(snap_r.state)
        leaves_o = jax.tree_util.tree_leaves(snap_o.state)
        for i, (lr, lo) in enumerate(zip(leaves_r, leaves_o)):
            if not np.array_equal(np.asarray(lr), np.asarray(lo)):
                mismatches.append(f"state leaf {i} diverged from the "
                                  f"WAL-replay oracle")
    finally:
        eng.store.release(snap_r)
        oracle.store.release(snap_o)

    # probe reads must agree too (the user-visible surface of the state)
    probe = np.arange(min(rows, 64), dtype=np.int32)
    for name, (a, b) in {
        "query": (eng.query(probe), oracle.query(probe)),
        "topn": (eng.topn(8), oracle.topn(8)),
    }.items():
        for xa, xb in zip(a, b):
            if not np.array_equal(np.asarray(xa), np.asarray(xb)):
                mismatches.append(f"{name} answers diverged")
                break
    eng.close()
    oracle.close()
    return recovery_s, durable, info["replayed"], mismatches


def _check_incidents(directory: str):
    """Every incident dump a poisoned worker left behind must parse and
    carry the flight-recorder payload (spans + metric deltas); returns
    ``(ok, message, count)``."""
    files = sorted(f for f in (os.listdir(directory)
                               if os.path.isdir(directory) else [])
                   if f.endswith(".json"))
    if not files:
        return False, "poison lives ran but no incident dump landed", 0
    bad = []
    for name in files:
        try:
            with open(os.path.join(directory, name)) as f:
                doc = json.load(f)
            if doc.get("schema") != "mcq-incident-v1":
                bad.append(f"{name}: wrong schema")
            elif not doc.get("spans"):
                bad.append(f"{name}: no spans")
            elif "deltas" not in doc or "reason" not in doc:
                bad.append(f"{name}: missing deltas/reason")
        except (OSError, json.JSONDecodeError) as e:
            bad.append(f"{name}: unparseable ({e})")
    if bad:
        return False, "; ".join(bad), len(files)
    return True, (f"{len(files)} incident dump(s), all parseable with "
                  f"spans + deltas"), len(files)


def run_soak(kills: int, *, rows: int = 256, batch: int = 128, seed: int = 0,
             snapshot_every: int = 5, min_steps: int = 3,
             max_steps: int = 12, workdir: Optional[str] = None,
             telemetry: bool = False) -> dict:
    """Run the kill/recover/verify loop; returns BENCH-shaped rows plus an
    ok flag (every life recovered bit-exactly).  ``telemetry=True`` arms
    the obs gate in every worker and turns ``wal.append.write`` lives into
    poison-raise lives, so the soak also proves a killed-under-load run
    leaves a parseable flight-recorder incident dump behind."""
    owns_dir = workdir is None
    workdir = workdir or tempfile.mkdtemp(prefix="mcq-chaos-")
    rng = np.random.default_rng(seed)
    rows_out, all_ok = [], True
    recoveries = []
    n_poison = 0
    try:
        for k in range(kills):
            site = KILL_MODES[k % len(KILL_MODES)]
            kill_hit = int(rng.integers(1, 8))
            kill_after = int(rng.integers(min_steps, max_steps + 1))
            if site is not None:
                kill_after = MAX_STEPS_PER_LIFE   # fallback external kill
            poison = telemetry and site == "wal.append.write"
            n_poison += int(poison)
            proc = _spawn_worker(workdir, rows, batch, seed,
                                 snapshot_every, site, kill_hit,
                                 telemetry=telemetry, poison=poison)
            life = _run_life(proc, kill_after)
            t_rec, durable, replayed, bad = _verify_recovery(
                workdir, rows, batch, seed)
            ok = not bad
            all_ok &= ok
            recoveries.append(t_rec)
            mode = site or "sigkill"
            rows_out.append({
                "name": f"B9_crash_soak[kill={k};mode={mode}]",
                "us_per_call": round(t_rec * 1e6, 1),
                "derived": (f"recovered {durable} records "
                            f"(replayed {replayed}) "
                            f"{'bit-exact' if ok else 'DIVERGED: ' + '; '.join(bad)}"),
                "kill_mode": mode, "steps": durable,
                "replayed": replayed, "bitexact": ok,
            })
            print(f"kill {k}: mode={mode} durable={durable} "
                  f"replayed={replayed} recovery={t_rec * 1e3:.0f} ms "
                  f"{'ok' if ok else 'DIVERGED'}", flush=True)
            if not ok:
                break   # state is wrong: every later life would be too
        if telemetry and n_poison:
            inc_ok, inc_msg, n_inc = _check_incidents(
                os.path.join(workdir, "incidents"))
            all_ok &= inc_ok
            rows_out.append({
                "name": "B9_telemetry_incidents",
                "us_per_call": 0.0,
                "derived": inc_msg,
                "incidents": n_inc, "parseable": inc_ok,
            })
            print(f"incidents: {inc_msg}", flush=True)
        if recoveries:
            rows_out.append({
                "name": "B9_recovery_summary",
                "us_per_call": round(float(np.mean(recoveries)) * 1e6, 1),
                "derived": (f"{len(recoveries)} kills, max recovery "
                            f"{max(recoveries) * 1e3:.0f} ms, "
                            f"all bit-exact={all_ok}"),
                "kills": len(recoveries),
                "mean_recovery_us": round(float(np.mean(recoveries)) * 1e6, 1),
                "max_recovery_us": round(float(np.max(recoveries)) * 1e6, 1),
                "bitexact": all_ok,
            })
    finally:
        if owns_dir:
            shutil.rmtree(workdir, ignore_errors=True)
    return {"rows": rows_out, "ok": all_ok}


def write_junit(result: dict, path: str) -> None:
    cases = []
    for row in result["rows"]:
        body = ""
        if not row.get("bitexact", True):
            body = (f'<failure message="divergence">'
                    f'{escape(row["derived"])}</failure>')
        cases.append(f'<testcase classname="chaos" '
                     f'name="{escape(row["name"])}" '
                     f'time="{row["us_per_call"] / 1e6:.3f}">{body}'
                     f"</testcase>")
    fails = sum(1 for c in cases if "<failure" in c)
    xml = ('<?xml version="1.0" encoding="utf-8"?>\n'
           f'<testsuite name="chaos-soak" tests="{len(cases)}" '
           f'failures="{fails}">' + "".join(cases) + "</testsuite>\n")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(xml)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.chaos.soak", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--kills", type=int, default=20)
    ap.add_argument("--rows", type=int, default=256)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--snapshot-every", type=int, default=5)
    ap.add_argument("--sleep", type=float, default=0.0,
                    help="worker inter-step sleep (worker mode)")
    ap.add_argument("--dir", default=None,
                    help="persist under this directory instead of a "
                         "temp dir (worker mode: required)")
    ap.add_argument("--out", default=os.path.join(REPO, "benchmarks",
                                                  "BENCH_faults.json"),
                    help="BENCH JSON path ('' to skip writing)")
    ap.add_argument("--junit", default=None, metavar="FILE")
    ap.add_argument("--telemetry", action="store_true",
                    help="arm the obs gate in every worker and verify "
                         "poisoned lives leave parseable flight-recorder "
                         "incident dumps (DESIGN.md §13)")
    ap.add_argument("--worker", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.worker:
        if not args.dir:
            ap.error("--worker requires --dir")
        worker_main(args)
        return 0

    result = run_soak(args.kills, rows=args.rows, batch=args.batch,
                      seed=args.seed, snapshot_every=args.snapshot_every,
                      workdir=args.dir, telemetry=args.telemetry)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"bench": "faults", "rows": result["rows"]}, f,
                      indent=1)
        print(f"wrote {args.out}")
    if args.junit:
        write_junit(result, args.junit)
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
