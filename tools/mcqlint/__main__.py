import sys

from tools.mcqlint.core import main

sys.exit(main())
