"""mcqlint: repo-specific static analyzer for the MCPrioQ engine.

A general linter cannot know that ``self.stats`` is ``_stats_lock``-protected,
that ``wal.append`` must precede ``_apply_locked``, or that every kernel
dispatcher needs a bit-exact ref oracle.  mcqlint does: it parses the
declaration conventions of ``repro.analysis.invariants`` (``@requires_lock``,
``@kernel_op``, ``_MCQ_LOCK_ORDER``, ``_MCQ_LOCK_PROTECTS``) straight from the
AST — never importing the checked code — and enforces the invariant catalog
of DESIGN.md §11 (``tools/mcqlint/catalog.py``) across the tree.

Run as ``python -m tools.mcqlint src/``; exits nonzero on any finding.
The rules also absorb the two ruff checks CI used to want but cannot install
in-container (F401 unused imports, E741 ambiguous names).
"""

from tools.mcqlint.core import Finding, run_paths

__all__ = ["Finding", "run_paths"]
