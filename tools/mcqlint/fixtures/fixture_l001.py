"""Seeded violation for MCQ-L001: protected mutation without the lock."""
import threading


class BadStatsMutation:
    _MCQ_LOCK_ORDER = ("_stats_lock",)
    _MCQ_LOCK_PROTECTS = {"_stats_lock": ("stats",)}

    def __init__(self):
        self._stats_lock = threading.Lock()
        self.stats = {"calls": 0}

    def bump(self):
        self.stats["calls"] += 1  # VIOLATION: _stats_lock not held
