"""Seeded violation for MCQ-O001: apply before WAL append."""


class ApplyBeforeAppend:
    def __init__(self, wal, chain):
        self.wal = wal
        self.chain = chain

    def observe(self, src, dst, w):
        self._apply_locked(src, dst, w)  # VIOLATION: apply precedes append
        self.wal.append(src, dst, w)

    def _apply_locked(self, src, dst, w):
        self.chain.update(src, dst, w)
