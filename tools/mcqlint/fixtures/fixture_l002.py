"""Seeded violation for MCQ-L002: @requires_lock callee, lock not held."""
import threading

from repro.analysis.invariants import requires_lock


class BadRequiresCall:
    _MCQ_LOCK_ORDER = ("_lock",)

    def __init__(self):
        self._lock = threading.Lock()
        self.items = []

    @requires_lock("_lock")
    def _append_locked(self, x):
        self.items.append(x)

    def add(self, x):
        self._append_locked(x)  # VIOLATION: _lock not held
