"""Seeded violation for MCQ-C001: counter field nobody surfaces."""
import jax.numpy as jnp

_COUNTER_FIELDS = ("n_rows",)


def init(cls):
    # VIOLATION: dropped_rows is int32(0)-initialised but unsurfaced
    return cls(n_rows=jnp.int32(0), dropped_rows=jnp.int32(0))


def maintenance_stats(state):
    return {"n_rows": int(state.n_rows)}
