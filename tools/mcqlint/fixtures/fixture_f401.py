"""Seeded violation for MCQ-F401: unused import."""
import os  # VIOLATION: imported but unused


def nothing():
    return 0
