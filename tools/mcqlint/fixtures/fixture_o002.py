"""Seeded violation for MCQ-O002: payload write after the manifest rename."""
import json
import os

import numpy as np


def save(path, arrays, manifest):
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "manifest.json"))
    np.savez(os.path.join(path, "arrays.npz"), **arrays)  # VIOLATION
