"""Seeded violation for MCQ-L003: lock-order inversion."""
import threading


class BadLockOrder:
    _MCQ_LOCK_ORDER = ("_outer", "_inner")

    def __init__(self):
        self._outer = threading.Lock()
        self._inner = threading.Lock()

    def inverted(self):
        with self._inner:
            with self._outer:  # VIOLATION: inverts _MCQ_LOCK_ORDER
                pass
