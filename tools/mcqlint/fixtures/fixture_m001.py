"""Seeded MCQ-M001 violations: a recorder call whose metric name is not
declared in the module's METRIC_CATALOG, an orphan catalog entry nothing
records or references, and a recorder called with a computed name."""

METRIC_CATALOG = {
    "demo.recorded": ("counter", "a declared metric with a call site"),
    "demo.orphan": ("gauge", "an entry whose recorder was deleted"),
}


def counter_add(name, n=1):
    pass


def gauge_set(name, value):
    pass


def touch(suffix):
    counter_add("demo.recorded")
    counter_add("demo.unregistered")
    gauge_set("demo." + suffix, 1.0)
