"""Seeded violation for MCQ-P001: ref oracle that does not exist."""
from repro.analysis.invariants import kernel_op


@kernel_op(ref="missing_oracle")
def broken_op(x):  # VIOLATION: 'missing_oracle' resolves nowhere
    return x
