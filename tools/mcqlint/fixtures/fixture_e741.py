"""Seeded violation for MCQ-E741: ambiguous single-letter binding."""


def confusing(xs):
    l = len(xs)  # VIOLATION: ambiguous name
    return l
