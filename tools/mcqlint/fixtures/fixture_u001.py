"""Seeded violation for MCQ-U001: wall clock inside a jit body."""
import time

import jax


@jax.jit
def impure(x):
    return x * time.time()  # VIOLATION: trace-time nondeterminism
