"""Lint self-test corpus: one seeded violation per rule (never imported)."""
