"""Seeded MCQ-R001 violations: a failpoint site that is not registered in
the module's FAILPOINT_CATALOG, an orphan catalog entry with no call site,
and a site named by a computed (non-literal) string."""

FAILPOINT_CATALOG = {
    "demo.registered_but_orphaned": "an entry whose call site was deleted",
}


def failpoint(name, **ctx):
    pass


def risky_write(fh, name):
    failpoint("demo.unregistered_site", fh=fh)
    failpoint("demo." + name)
    fh.write(b"payload")
