"""Seeded violation for MCQ-L004: owned lock missing from the order."""
import threading


class UndeclaredLockOwner:
    _MCQ_LOCK_ORDER = ("_declared",)

    def __init__(self):
        self._declared = threading.Lock()
        self._stealth = threading.Lock()  # VIOLATION: unranked lock

    def use(self):
        with self._stealth:
            pass
