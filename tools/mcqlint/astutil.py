"""Shared AST helpers for mcqlint rules (declaration-convention parsing)."""

from __future__ import annotations

import ast
from typing import Dict, Optional, Tuple

REQUIRES_NAMES = ("requires_lock",)
KERNEL_OP_NAMES = ("kernel_op",)
LOCK_ORDER_ATTR = "_MCQ_LOCK_ORDER"
LOCK_PROTECTS_ATTR = "_MCQ_LOCK_PROTECTS"


def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain: ``self.wal.append`` -> the
    string, anything else (subscripts, calls in the chain) -> None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def str_tuple(node: ast.AST) -> Tuple[str, ...]:
    """Literal tuple/list of strings -> the strings (else empty)."""
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append(el.value)
        return tuple(out)
    return ()


def decorator_call(fn: ast.AST, names: Tuple[str, ...]
                   ) -> Optional[ast.Call]:
    """The ``@name(...)`` decorator Call when present (matches a bare name
    or the final attribute segment, so ``@invariants.requires_lock`` also
    counts)."""
    for dec in getattr(fn, "decorator_list", ()):
        if not isinstance(dec, ast.Call):
            continue
        chain = attr_chain(dec.func)
        if chain and chain.split(".")[-1] in names:
            return dec
    return None


def requires_locks(fn: ast.AST) -> Tuple[str, ...]:
    call = decorator_call(fn, REQUIRES_NAMES)
    if call is None:
        return ()
    return tuple(a.value for a in call.args
                 if isinstance(a, ast.Constant) and isinstance(a.value, str))


def kernel_op_decl(fn: ast.AST) -> Optional[Dict[str, object]]:
    call = decorator_call(fn, KERNEL_OP_NAMES)
    if call is None:
        return None
    out: Dict[str, object] = {"ref": None, "pallas": None, "composes": ()}
    for kw in call.keywords:
        if kw.arg == "composes":
            out["composes"] = str_tuple(kw.value)
        elif kw.arg in ("ref", "pallas"):
            if isinstance(kw.value, ast.Constant):
                out[kw.arg] = kw.value.value
    return out


def class_lock_decls(cls: ast.ClassDef):
    """(order, protects) parsed from the class-body literal assignments."""
    order: Tuple[str, ...] = ()
    protects: Dict[str, Tuple[str, ...]] = {}
    for stmt in cls.body:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            continue
        tgt = stmt.targets[0]
        if not isinstance(tgt, ast.Name):
            continue
        if tgt.id == LOCK_ORDER_ATTR:
            order = str_tuple(stmt.value)
        elif tgt.id == LOCK_PROTECTS_ATTR and isinstance(stmt.value,
                                                         ast.Dict):
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if (isinstance(k, ast.Constant)
                        and isinstance(k.value, str)):
                    protects[k.value] = str_tuple(v)
    return order, protects


def owned_locks(cls: ast.ClassDef) -> Dict[str, int]:
    """attr name -> lineno for every ``self.X = threading.Lock()`` (or
    RLock) assignment anywhere in the class body."""
    out: Dict[str, int] = {}
    for node in ast.walk(cls):
        if not (isinstance(node, ast.Assign) and isinstance(node.value,
                                                            ast.Call)):
            continue
        chain = attr_chain(node.value.func)
        if chain is None or chain.split(".")[-1] not in ("Lock", "RLock"):
            continue
        for tgt in node.targets:
            t = attr_chain(tgt)
            if t and t.startswith("self.") and t.count(".") == 1:
                out[t.split(".")[1]] = node.lineno
    return out


def methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {stmt.name: stmt for stmt in cls.body
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))}
