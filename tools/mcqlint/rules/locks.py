"""I-lock rules: lock-protected mutation, @requires_lock contracts,
lock-order inversion, undeclared locks (invariants I1/I2/I8).

Analysis model: per class, per method, a lexical held-lock set tracked
through ``with self.<lock>:`` blocks and seeded by ``@requires_lock``.  The
engine only ever acquires locks with ``with`` (never bare ``.acquire()``),
so the lexical set is exact.  Cross-method effects use an intra-class
call-graph fixpoint: ``acquires(m)`` = locks ``m`` may take directly or via
``self.`` calls, which is what lets the analyzer see that ``query()``
(holding ``_route_lock``) reaching a compile-cache helper acquires
``_compile_lock`` — and reject the inverted nesting.

A class opts in by declaring ``_MCQ_LOCK_ORDER`` / ``_MCQ_LOCK_PROTECTS``;
undeclared classes are not scanned (the convention is the contract).
``__init__`` is exempt from the mutation rule: the object is pre-publication
there, no other thread can hold a reference yet.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.mcqlint import astutil
from tools.mcqlint.core import Finding, Project, Rule, SourceFile

#: dict/list/set methods that mutate their receiver in place
_MUTATORS = frozenset({
    "update", "setdefault", "pop", "popitem", "clear", "append", "extend",
    "insert", "remove", "add", "discard", "__setitem__",
})

_NESTED_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


class _ClassInfo:
    def __init__(self, sf: SourceFile, cls: ast.ClassDef):
        self.sf = sf
        self.cls = cls
        self.order, self.protects = astutil.class_lock_decls(cls)
        self.owned = astutil.owned_locks(cls)
        self.methods = astutil.methods(cls)
        self.requires = {name: astutil.requires_locks(fn)
                         for name, fn in self.methods.items()}
        # resource -> guarding lock (reverse of protects)
        self.guard: Dict[str, str] = {}
        for lock, resources in self.protects.items():
            for res in resources:
                self.guard[res] = lock
        self.lock_names: Set[str] = (set(self.order)
                                     | set(self.protects)
                                     | set(self.owned))
        self.acquires = self._acquires_fixpoint()

    def rank(self, lock: str) -> Optional[int]:
        try:
            return self.order.index(lock)
        except ValueError:
            return None

    def lock_of(self, expr: ast.AST) -> Optional[str]:
        """Lock attr name when ``expr`` is ``self.<known lock>``."""
        chain = astutil.attr_chain(expr)
        if (chain and chain.startswith("self.") and chain.count(".") == 1
                and chain[5:] in self.lock_names):
            return chain[5:]
        return None

    def _direct(self, fn: ast.AST) -> Tuple[Set[str], Set[str]]:
        """(locks acquired via ``with self.X``, self-methods called),
        anywhere in the method including nested defs (a callback that
        takes a lock still contributes to the caller's footprint)."""
        locks: Set[str] = set()
        calls: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.With):
                for item in node.items:
                    lock = self.lock_of(item.context_expr)
                    if lock is not None:
                        locks.add(lock)
            elif isinstance(node, ast.Call):
                chain = astutil.attr_chain(node.func)
                if (chain and chain.startswith("self.")
                        and chain.count(".") == 1
                        and chain[5:] in self.methods):
                    calls.add(chain[5:])
        return locks, calls

    def _acquires_fixpoint(self) -> Dict[str, Set[str]]:
        direct: Dict[str, Set[str]] = {}
        callees: Dict[str, Set[str]] = {}
        for name, fn in self.methods.items():
            direct[name], callees[name] = self._direct(fn)
        acq = {name: set(locks) for name, locks in direct.items()}
        changed = True
        while changed:
            changed = False
            for name in acq:
                for callee in callees[name]:
                    extra = acq.get(callee, set()) - acq[name]
                    if extra:
                        acq[name] |= extra
                        changed = True
        return acq


def _classes(project: Project):
    for sf in project.files:
        for node in sf.tree.body:
            if isinstance(node, ast.ClassDef):
                ci = _ClassInfo(sf, node)
                if ci.order or ci.protects:
                    yield ci


def _mutated_resources(node: ast.AST) -> List[str]:
    """Resources one node mutates, as dotted suffixes relative to self:
    ``self.stats[k] += 1`` -> ``stats``; ``del self._readers[v]`` ->
    ``_readers``; ``self.store.publish(x)`` -> ``store.publish`` (dotted
    call pattern) and nothing else (publish is not an in-place mutator of
    ``store``)."""
    out: List[str] = []

    def target_resource(t: ast.AST) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                target_resource(el)
            return
        if isinstance(t, (ast.Subscript, ast.Starred)):
            t = t.value
        chain = astutil.attr_chain(t)
        if chain and chain.startswith("self."):
            out.append(chain[5:].split(".")[0])

    if isinstance(node, ast.Assign):
        for tgt in node.targets:
            target_resource(tgt)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        target_resource(node.target)
    elif isinstance(node, ast.Delete):
        for tgt in node.targets:
            target_resource(tgt)
    elif isinstance(node, ast.Call):
        chain = astutil.attr_chain(node.func)
        if chain and chain.startswith("self."):
            suffix = chain[5:]
            # only dotted patterns match calls: "store.publish" is a
            # protected operation, but calling a bare attribute like
            # self._update() is a READ of the attribute (the route-pair
            # mutation is its assignment, checked above)
            if "." in suffix:
                out.append(suffix)
            parts = suffix.split(".")
            if len(parts) == 2 and parts[1] in _MUTATORS:
                out.append(parts[0])  # self.stats.update -> mutates stats
    return out


class _MethodScan:
    """One pass over one method, carrying the lexical held-lock list."""

    def __init__(self, ci: _ClassInfo, name: str, out: List[Finding]):
        self.ci = ci
        self.name = name
        self.fn = ci.methods[name]
        self.out = out
        self.is_init = name == "__init__"

    def run(self) -> None:
        self._walk_body(self.fn.body, list(self.ci.requires[self.name]))

    # -- statement traversal (held set is per lexical position) ---------
    def _walk_body(self, body, held) -> None:
        for stmt in body or []:
            self._walk_stmt(stmt, held)

    def _walk_stmt(self, node, held) -> None:
        if isinstance(node, ast.With):
            new_held = list(held)
            for item in node.items:
                lock = self.ci.lock_of(item.context_expr)
                if lock is not None:
                    self._check_acquire(node, lock, new_held)
                    new_held = new_held + [lock]
                else:
                    self._check_expr(item.context_expr, held)
            self._walk_body(node.body, new_held)
        elif isinstance(node, _NESTED_SCOPES):
            pass  # deferred execution: checked under its own contract
        elif isinstance(node, (ast.If, ast.While)):
            self._check_expr(node.test, held)
            self._walk_body(node.body, held)
            self._walk_body(node.orelse, held)
        elif isinstance(node, ast.For):
            self._check_expr(node.iter, held)
            self._walk_body(node.body, held)
            self._walk_body(node.orelse, held)
        elif isinstance(node, ast.Try):
            self._walk_body(node.body, held)
            for handler in node.handlers:
                self._walk_body(handler.body, held)
            self._walk_body(node.orelse, held)
            self._walk_body(node.finalbody, held)
        else:
            # simple statement: the whole subtree is expressions
            self._check_expr(node, held)

    # -- checks ---------------------------------------------------------
    def _check_acquire(self, node, lock: str, held) -> None:
        ci = self.ci
        if lock in held:
            self.out.append(Finding(
                LockOrderInversion.id, ci.sf.path, node.lineno,
                f"{ci.cls.name}.{self.name} re-acquires {lock} while "
                f"already holding it (threading.Lock self-deadlock)"))
            return
        r_new = ci.rank(lock)
        for h in held:
            r_h = ci.rank(h)
            if r_new is not None and r_h is not None and r_new < r_h:
                self.out.append(Finding(
                    LockOrderInversion.id, ci.sf.path, node.lineno,
                    f"{ci.cls.name}.{self.name} acquires {lock} while "
                    f"holding {h}: inverts _MCQ_LOCK_ORDER {ci.order}"))

    def _check_expr(self, node, held) -> None:
        ci = self.ci
        for sub in ast.walk(node):
            if isinstance(sub, _NESTED_SCOPES):
                continue  # (walk still descends; accepted imprecision)
            if not self.is_init:
                for res in _mutated_resources(sub):
                    lock = ci.guard.get(res)
                    if lock is not None and lock not in held:
                        self.out.append(Finding(
                            LockProtectedMutation.id, ci.sf.path,
                            sub.lineno,
                            f"{ci.cls.name}.{self.name} mutates '{res}' "
                            f"without holding {lock} "
                            f"(_MCQ_LOCK_PROTECTS)"))
            if isinstance(sub, ast.Call):
                self._check_call(sub, held)

    def _check_call(self, call: ast.Call, held) -> None:
        ci = self.ci
        chain = astutil.attr_chain(call.func)
        if not (chain and chain.startswith("self.")
                and chain.count(".") == 1):
            return
        callee = chain[5:]
        for need in ci.requires.get(callee, ()):
            if need not in held:
                self.out.append(Finding(
                    RequiresLockCallSites.id, ci.sf.path, call.lineno,
                    f"{ci.cls.name}.{self.name} calls {callee}() without "
                    f"holding {need} (@requires_lock)"))
        # cross-method lock-order: the callee's transitive acquisitions
        # must all rank after every lock currently held
        for acq in ci.acquires.get(callee, ()):
            if acq in held:
                continue  # guarded-variant call; @requires_lock covers it
            r_a = ci.rank(acq)
            for h in held:
                r_h = ci.rank(h)
                if r_a is not None and r_h is not None and r_a < r_h:
                    self.out.append(Finding(
                        LockOrderInversion.id, ci.sf.path, call.lineno,
                        f"{ci.cls.name}.{self.name} holds {h} while "
                        f"calling {callee}(), which may acquire {acq}: "
                        f"inverts _MCQ_LOCK_ORDER {ci.order}"))


def _scan(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for ci in _classes(project):
        for name in ci.methods:
            _MethodScan(ci, name, out).run()
    return out


class LockProtectedMutation(Rule):
    id = "MCQ-L001"
    summary = ("mutations of _MCQ_LOCK_PROTECTS resources require the "
               "declared lock (lexically or via @requires_lock)")

    def check(self, project: Project) -> List[Finding]:
        return [f for f in _scan(project) if f.rule == self.id]


class RequiresLockCallSites(Rule):
    id = "MCQ-L002"
    summary = "@requires_lock methods are only called with the lock held"

    def check(self, project: Project) -> List[Finding]:
        return [f for f in _scan(project) if f.rule == self.id]


class LockOrderInversion(Rule):
    id = "MCQ-L003"
    summary = ("lock acquisition (direct or via self-calls) never inverts "
               "_MCQ_LOCK_ORDER; no self-deadlock re-acquisition")

    def check(self, project: Project) -> List[Finding]:
        return [f for f in _scan(project) if f.rule == self.id]


class UndeclaredLock(Rule):
    id = "MCQ-L004"
    summary = ("every threading.Lock a declaring class owns appears in "
               "_MCQ_LOCK_ORDER")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for ci in _classes(project):
            if not ci.order:
                continue
            for lock, lineno in sorted(ci.owned.items()):
                if lock not in ci.order:
                    out.append(Finding(
                        self.id, ci.sf.path, lineno,
                        f"{ci.cls.name} owns lock '{lock}' but "
                        f"_MCQ_LOCK_ORDER {ci.order} does not rank it"))
        return out


RULES = [LockProtectedMutation(), RequiresLockCallSites(),
         LockOrderInversion(), UndeclaredLock()]
