"""I-counter rule: every MCState counter is surfaced (invariant I6).

Applies to modules that declare ``_COUNTER_FIELDS`` (i.e. ``core/mcprioq``
and any future sibling).  Two directions:

* every field initialised to ``int32(0)`` in ``init()`` must be listed in
  ``_COUNTER_FIELDS`` or read by ``maintenance_stats`` (a counter nobody
  can observe is a silent drop — A4/A6/A10 all rest on *counted* drops),
* every ``_COUNTER_FIELDS`` entry must be such an init field (a typo'd
  name would make ``counter_stats`` raise only at runtime).
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from tools.mcqlint import astutil
from tools.mcqlint.core import Finding, Project, Rule


def _zero_init_fields(init_fn: ast.AST) -> dict:
    """keyword args of any call in ``init`` whose value is ``*.int32(0)``
    (or plain ``int32(0)``): name -> lineno."""
    out = {}
    for node in ast.walk(init_fn):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg is None or not isinstance(kw.value, ast.Call):
                continue
            chain = astutil.attr_chain(kw.value.func)
            if not (chain and chain.split(".")[-1] == "int32"):
                continue
            args = kw.value.args
            if (len(args) == 1 and isinstance(args[0], ast.Constant)
                    and args[0].value == 0):
                out[kw.arg] = kw.value.lineno
    return out


def _read_attrs(fn: ast.AST) -> Set[str]:
    return {node.attr for node in ast.walk(fn)
            if isinstance(node, ast.Attribute)}


class CounterSurfaced(Rule):
    id = "MCQ-C001"
    summary = ("every int32(0)-initialised MCState counter appears in "
               "_COUNTER_FIELDS or maintenance_stats (and vice versa)")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            fields: Optional[tuple] = None
            fields_line = 0
            init_fn = None
            maint_fn = None
            for node in sf.tree.body:
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == "_COUNTER_FIELDS"):
                    fields = astutil.str_tuple(node.value)
                    fields_line = node.lineno
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    if node.name == "init":
                        init_fn = node
                    elif node.name == "maintenance_stats":
                        maint_fn = node
            if fields is None or init_fn is None:
                continue
            zero = _zero_init_fields(init_fn)
            maint = _read_attrs(maint_fn) if maint_fn is not None else set()
            for name, lineno in sorted(zero.items()):
                if name not in fields and name not in maint:
                    out.append(Finding(
                        self.id, sf.path, lineno,
                        f"counter field '{name}' (int32(0) in init) is "
                        f"surfaced by neither _COUNTER_FIELDS nor "
                        f"maintenance_stats"))
            for name in fields:
                if name not in zero:
                    out.append(Finding(
                        self.id, sf.path, fields_line,
                        f"_COUNTER_FIELDS entry '{name}' is not an "
                        f"int32(0)-initialised field of init() — "
                        f"counter_stats would fail on it"))
        return out


RULES = [CounterSurfaced()]
