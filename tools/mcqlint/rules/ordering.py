"""I-order rules: statement-ordering invariants of the durability layer
(invariants I3/I4).

Both rules are per-function, line-position checks over call sites — the
ordering that matters is program order inside one function body (the WAL
append and the apply happen in ``observe``; the payload writes and the
manifest rename happen in ``save``/``work``), so a lexical check is exact
for the shapes the code actually uses.
"""

from __future__ import annotations

import ast
from typing import List, Tuple

from tools.mcqlint import astutil
from tools.mcqlint.core import Finding, Project, Rule

#: call-chain suffixes meaning "append the batch to the WAL"
_APPEND_SUFFIXES = ("wal.append",)
#: callee names meaning "apply the batch to the chain"
_APPLY_NAMES = ("_apply_locked", "apply_batch")
#: callee names/suffixes that write snapshot payload (sidecar, arrays,
#: manifest body) — all must precede the commit rename
_PAYLOAD_NAMES = ("savez", "savez_compressed", "_write_meta", "dump")


def _functions(tree: ast.Module):
    """Every def in the module, at any nesting (methods, local workers)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _calls(fn: ast.AST) -> List[Tuple[int, str]]:
    """(lineno, dotted chain) for every call in ``fn`` body, in source
    order; calls inside nested defs are attributed to the nested def by
    the caller iterating ``_functions`` (so skip them here)."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Call):
            chain = astutil.attr_chain(node.func)
            if chain:
                out.append((node.lineno, chain))
    return sorted(out)


class WalAppendBeforeApply(Rule):
    id = "MCQ-O001"
    summary = ("in any function doing both, wal.append precedes the "
               "apply call (write-AHEAD)")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            for fn in _functions(sf.tree):
                calls = _calls(fn)
                appends = [ln for ln, c in calls
                           if any(c.endswith(s) for s in _APPEND_SUFFIXES)]
                applies = [ln for ln, c in calls
                           if c.split(".")[-1] in _APPLY_NAMES]
                if appends and applies and min(applies) < min(appends):
                    out.append(Finding(
                        self.id, sf.path, min(applies),
                        f"{fn.name}: batch applied (line {min(applies)}) "
                        f"before WAL append (line {min(appends)}) — "
                        f"violates write-ahead ordering"))
        return out


class PayloadBeforeManifestRename(Rule):
    id = "MCQ-O002"
    summary = ("nothing is written after the manifest os.replace — the "
               "rename is the snapshot commit point")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            for fn in _functions(sf.tree):
                calls = _calls(fn)
                renames = [ln for ln, c in calls if c == "os.replace"]
                if not renames:
                    continue
                commit = max(renames)
                for ln, c in calls:
                    if (ln > commit
                            and c.split(".")[-1] in _PAYLOAD_NAMES):
                        out.append(Finding(
                            self.id, sf.path, ln,
                            f"{fn.name}: payload write {c}() at line "
                            f"{ln} after the manifest rename (line "
                            f"{commit}) — the rename must be the last "
                            f"write (commit point)"))
        return out


RULES = [WalAppendBeforeApply(), PayloadBeforeManifestRename()]
