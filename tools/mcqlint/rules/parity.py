"""I-parity rule: every kernel dispatcher registers its bit-exact oracle
(invariant I5).

The registration is the ``@kernel_op(ref=..., pallas=..., composes=...)``
decorator in ``kernels/ops.py``; this rule checks — statically, across the
whole scanned tree — that the declarations are complete and that nothing
escapes them:

* a module that registers any op registers every public def it exposes
  (a new dispatcher cannot be added without declaring parity),
* every declared ``ref``/``pallas`` name resolves to a def somewhere in
  the scanned tree, and ``composes`` entries are registered ops,
* every public ``*_pallas`` kernel def is reachable from some
  registration (no unregistered TPU kernel),
* when a test tree was scanned, every registered op name is mentioned by
  it (the equivalence test exists).
"""

from __future__ import annotations

import ast
from typing import Dict, List

from tools.mcqlint import astutil
from tools.mcqlint.core import Finding, Project, Rule


class KernelParityRegistry(Rule):
    id = "MCQ-P001"
    summary = ("every kernel dispatcher has @kernel_op with a resolvable "
               "ref oracle; every *_pallas def is registered; every op "
               "has an equivalence test")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        # registry: op name -> (decl, sf, node); plus all top-level defs
        registry: Dict[str, tuple] = {}
        all_defs: Dict[str, List] = {}
        for sf in project.files:
            for node in sf.tree.body:
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    all_defs.setdefault(node.name, []).append((sf, node))
                    decl = astutil.kernel_op_decl(node)
                    if decl is not None:
                        registry[node.name] = (decl, sf, node)
                elif (isinstance(node, ast.Assign)
                        and isinstance(node.value, (ast.Name,
                                                    ast.Attribute))):
                    # top-level aliases (dh_find_ref = probe_find_ref)
                    # count as defs for name resolution
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            all_defs.setdefault(tgt.id, []).append(
                                (sf, node))

        # (a) registering modules register everything public
        for sf in project.files:
            module_ops = [n for n in sf.tree.body
                          if isinstance(n, (ast.FunctionDef,
                                            ast.AsyncFunctionDef))
                          and astutil.kernel_op_decl(n) is not None]
            if not module_ops:
                continue
            for node in sf.tree.body:
                if (isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                        and not node.name.startswith("_")
                        and astutil.kernel_op_decl(node) is None):
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"public def {node.name} in a kernel-op module "
                        f"has no @kernel_op registration"))

        # (b) declared names resolve
        pallas_referenced = set()
        for op, (decl, sf, node) in sorted(registry.items()):
            ref, pallas = decl["ref"], decl["pallas"]
            composes = decl["composes"]
            if ref is None and not composes:
                out.append(Finding(
                    self.id, sf.path, node.lineno,
                    f"{op}: @kernel_op declares neither a ref oracle "
                    f"nor a composes list"))
            if ref is not None and ref not in all_defs:
                out.append(Finding(
                    self.id, sf.path, node.lineno,
                    f"{op}: ref oracle '{ref}' not found in the "
                    f"scanned tree"))
            if pallas is not None:
                pallas_referenced.add(pallas)
                if pallas not in all_defs:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"{op}: pallas kernel '{pallas}' not found in "
                        f"the scanned tree"))
            for comp in composes:
                if comp not in registry:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"{op}: composes '{comp}' which is not a "
                        f"registered kernel op"))

        # (c) every public *_pallas def is reachable from a registration
        for name, sites in sorted(all_defs.items()):
            if (name.endswith("_pallas") and not name.startswith("_")
                    and name not in pallas_referenced):
                for sf, node in sites:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"pallas kernel {name} is not referenced by any "
                        f"@kernel_op registration"))

        # (d) every op is named by an equivalence test (when scanned)
        if project.tests_text is not None:
            for op, (decl, sf, node) in sorted(registry.items()):
                if op not in project.tests_text:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"{op}: no test mentions this kernel op "
                        f"(equivalence test required)"))
        return out


RULES = [KernelParityRegistry()]
