"""Hygiene rules absorbed from ruff (invariant I9): the container cannot
install ruff, so the two checks CI wants from it live here.

* MCQ-F401 — unused imports, mirroring the repo's pyproject config:
  ``**/__init__.py`` is exempt (re-export surface), ``from __future__``
  never counts, and a name listed in ``__all__`` counts as used.
* MCQ-E741 — ambiguous single-letter bindings ``l``/``O``/``I`` (as
  assignment targets, function/lambda args, def names, for/with/except
  targets), unreadable in most fonts.
"""

from __future__ import annotations

import ast
from typing import List, Set

from tools.mcqlint.core import Finding, Project, Rule

_AMBIGUOUS = ("l", "O", "I")


class UnusedImport(Rule):
    id = "MCQ-F401"
    summary = "no unused imports (ruff F401; __init__.py exempt)"

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            if sf.name == "__init__.py":
                continue
            imported = {}  # bound name -> (lineno, display)
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        bound = alias.asname or alias.name.split(".")[0]
                        imported[bound] = (node.lineno, alias.name)
                elif isinstance(node, ast.ImportFrom):
                    if node.module == "__future__":
                        continue
                    for alias in node.names:
                        if alias.name == "*":
                            continue
                        bound = alias.asname or alias.name
                        imported[bound] = (node.lineno, alias.name)
            used: Set[str] = set()
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Name):
                    used.add(node.id)
                elif (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)):
                    pass  # string annotations don't occur (future import)
            # __all__ re-exports count as usage
            for node in sf.tree.body:
                if (isinstance(node, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "__all__"
                                for t in node.targets)
                        and isinstance(node.value, (ast.List, ast.Tuple))):
                    for el in node.value.elts:
                        if (isinstance(el, ast.Constant)
                                and isinstance(el.value, str)):
                            used.add(el.value)
            for bound, (lineno, display) in sorted(imported.items(),
                                                   key=lambda kv: kv[1]):
                if bound not in used:
                    out.append(Finding(
                        self.id, sf.path, lineno,
                        f"'{display}' imported but unused"))
        return out


class AmbiguousName(Rule):
    id = "MCQ-E741"
    summary = "no ambiguous l/O/I bindings (ruff E741)"

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            for node in ast.walk(sf.tree):
                bad = []
                if isinstance(node, ast.Name) and isinstance(
                        node.ctx, ast.Store) and node.id in _AMBIGUOUS:
                    bad.append(node.id)
                elif isinstance(node, ast.arg) and node.arg in _AMBIGUOUS:
                    bad.append(node.arg)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)) \
                        and node.name in _AMBIGUOUS:
                    bad.append(node.name)
                elif (isinstance(node, ast.ExceptHandler)
                        and node.name in _AMBIGUOUS):
                    bad.append(node.name)
                for name in bad:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"ambiguous variable name '{name}'"))
        return out


RULES = [UnusedImport(), AmbiguousName()]
