"""I-metric rule: the metric-name surface is closed (invariant I11).

Telemetry only stays trustworthy if the name space is a closed diagonal
(the MCQ-R001 shape, applied to ``METRIC_CATALOG``): a recorder call with
a name the catalog does not declare is a series that silently never shows
up typed/documented on the exposition surface, and a catalog entry nothing
records is a dashboard lying about coverage.  Statically, across the
scanned tree:

* every recorder call (``counter_add`` / ``gauge_set`` / ``hist_record`` /
  ``vector_add`` / ``span``) passes a literal string name — a computed
  name cannot be audited against the catalog,
* every recorded name appears in a ``METRIC_CATALOG`` literal found in the
  scanned tree (an undeclared name has no HELP/TYPE metadata and no
  schema),
* every catalog entry is referenced somewhere outside the catalog itself —
  as a recorder call site or a string constant (counter names flow through
  dict-key stats plumbing, not only direct calls),
* catalog keys are literal strings mapping to ``(kind, help)`` pairs.

Files under an ``obs/`` package are exempt from the call-site checks: the
registry's own recorders forward caller-supplied (non-literal) names by
construction.  Their string constants still count for the orphan check.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Set, Tuple

from tools.mcqlint.core import Finding, Project, Rule

#: registry methods whose first argument is a metric name
RECORDERS = ("counter_add", "gauge_set", "hist_record", "vector_add",
             "span")

_OBS_SEG = os.sep + "obs" + os.sep


def _catalog_entries(sf) -> Tuple[List[Tuple[str, ast.AST]], List[ast.AST],
                                  Set[int]]:
    """Literal entries of a module-level ``METRIC_CATALOG = {...}`` dict:
    returns ``(named_keys, bad_nodes, member_node_ids)`` where ``bad_nodes``
    are non-literal keys or malformed ``(kind, help)`` values and
    ``member_node_ids`` covers every AST node inside the catalog literal
    (so the orphan check can ignore the declaration itself)."""
    named: List[Tuple[str, ast.AST]] = []
    bad: List[ast.AST] = []
    members: Set[int] = set()
    for node in sf.tree.body:
        # both plain and annotated assignment declare the catalog
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name)]
        elif (isinstance(node, ast.AnnAssign)
                and isinstance(node.target, ast.Name)
                and node.value is not None):
            targets = [node.target.id]
        else:
            continue
        if "METRIC_CATALOG" not in targets:
            continue
        if not isinstance(node.value, ast.Dict):
            bad.append(node)
            continue
        members.update(id(sub) for sub in ast.walk(node.value))
        for key, value in zip(node.value.keys, node.value.values):
            if not (isinstance(key, ast.Constant)
                    and isinstance(key.value, str)):
                bad.append(key if key is not None else node)
                continue
            ok = (isinstance(value, ast.Tuple) and len(value.elts) == 2
                  and all(isinstance(e, ast.Constant)
                          and isinstance(e.value, str)
                          for e in value.elts))
            if not ok:
                bad.append(value)
                continue
            named.append((key.value, key))
    return named, bad, members


def _recorder_call(node: ast.Call) -> bool:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id in RECORDERS
    if isinstance(fn, ast.Attribute):
        return fn.attr in RECORDERS
    return False


class MetricCatalogClosure(Rule):
    id = "MCQ-M001"
    summary = ("every recorder call uses a literal name declared in "
               "METRIC_CATALOG; every catalog entry is recorded or "
               "referenced somewhere in the scanned tree")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        catalog: Dict[str, tuple] = {}
        sites: Dict[str, List[tuple]] = {}
        mentions: Set[str] = set()
        for sf in project.files:
            named, bad, members = _catalog_entries(sf)
            for name, node in named:
                catalog.setdefault(name, (sf, node))
            for node in bad:
                out.append(self.finding(
                    sf, node,
                    "METRIC_CATALOG entries must be literal "
                    "'name': ('kind', 'help') pairs (the surface is "
                    "audited statically)"))
            exempt = _OBS_SEG in sf.path
            for node in ast.walk(sf.tree):
                if (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)
                        and id(node) not in members):
                    mentions.add(node.value)
                if not (isinstance(node, ast.Call)
                        and _recorder_call(node)):
                    continue
                literal = (node.args
                           and isinstance(node.args[0], ast.Constant)
                           and isinstance(node.args[0].value, str))
                if literal:
                    sites.setdefault(node.args[0].value,
                                     []).append((sf, node))
                elif not exempt:
                    fn = node.func
                    called = (fn.attr if isinstance(fn, ast.Attribute)
                              else fn.id)
                    out.append(self.finding(
                        sf, node,
                        f"{called}() metric name must be a literal "
                        f"string (names are audited against "
                        f"METRIC_CATALOG)"))
        if not catalog and not sites:
            return out   # tree has no metric surface at all

        for name, hits in sorted(sites.items()):
            if catalog and name not in catalog:
                for sf, node in hits:
                    if _OBS_SEG in sf.path:
                        continue
                    out.append(self.finding(
                        sf, node,
                        f"metric '{name}' is recorded but not declared "
                        f"in METRIC_CATALOG (untyped: no HELP/TYPE, no "
                        f"schema)"))
        for name, (sf, node) in sorted(catalog.items()):
            if name not in sites and name not in mentions:
                out.append(self.finding(
                    sf, node,
                    f"METRIC_CATALOG entry '{name}' is never recorded "
                    f"or referenced in the scanned tree (a dashboard "
                    f"series that can only flatline)"))
        return out


RULES = [MetricCatalogClosure()]
