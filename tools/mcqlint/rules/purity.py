"""I-purity rule: jit/shard_map bodies are pure (invariant I7).

Bit-exact WAL replay (DESIGN.md §10) and kernel parity both assume traced
computations are functions of their inputs alone.  This rule finds defs
that are jit/shard_map-wrapped — ``@jax.jit``, ``@functools.partial(
jax.jit, ...)``, ``@functools.partial(compat.shard_map, ...)``, or a plain
``jax.jit(fn)``/``shard_map(fn)`` call on a local def — and flags, in
their *own* bodies (helpers called from them are not chased):

* wall-clock / host-RNG / environment calls (``time.*``, ``datetime.now``,
  ``random.*``, ``np.random.*``, ``os.environ``, ``os.urandom``, ``open``,
  ``input``) — trace-time nondeterminism baked into the program,
* ``global`` / ``nonlocal`` statements — captured mutable Python state,
* assignments to ``self.*`` — mutation escaping the trace.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from tools.mcqlint import astutil
from tools.mcqlint.core import Finding, Project, Rule

_JIT_TAILS = ("jit", "shard_map", "pmap")
#: forbidden dotted-call prefixes/exacts inside traced bodies
_FORBIDDEN_PREFIXES = ("time.", "random.", "np.random.", "numpy.random.")
_FORBIDDEN_EXACT = ("open", "input", "os.urandom", "os.getenv")
_FORBIDDEN_TAILS = ("now", "utcnow", "monotonic", "perf_counter")


def _is_jit_decorator(dec: ast.AST) -> bool:
    chain = astutil.attr_chain(dec)
    if chain and chain.split(".")[-1] in _JIT_TAILS:
        return True
    if isinstance(dec, ast.Call):
        func_chain = astutil.attr_chain(dec.func)
        if func_chain and func_chain.split(".")[-1] in _JIT_TAILS:
            return True
        if (func_chain and func_chain.split(".")[-1] == "partial"
                and dec.args):
            first = astutil.attr_chain(dec.args[0])
            if first and first.split(".")[-1] in _JIT_TAILS:
                return True
    return False


def _jitted_defs(tree: ast.Module) -> Iterator[Tuple[ast.AST, str]]:
    # decorated defs, at any nesting
    jit_wrapped = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = astutil.attr_chain(node.func)
            if (chain and chain.split(".")[-1] in _JIT_TAILS
                    and node.args and isinstance(node.args[0], ast.Name)):
                jit_wrapped.add(node.args[0].id)  # jax.jit(fn) on a name
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        how = None
        if any(_is_jit_decorator(d) for d in node.decorator_list):
            how = "decorated"
        elif node.name in jit_wrapped:
            how = "wrapped"
        if how:
            yield node, how


class JitBodyPurity(Rule):
    id = "MCQ-U001"
    summary = ("jit/shard_map bodies: no wall-clock/RNG/env calls, no "
               "global/nonlocal, no self mutation")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        for sf in project.files:
            for fn, how in _jitted_defs(sf.tree):
                for node in ast.walk(fn):
                    if isinstance(node, (ast.Global, ast.Nonlocal)):
                        out.append(Finding(
                            self.id, sf.path, node.lineno,
                            f"{fn.name} ({how} jit scope) uses "
                            f"{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                            f" — captured mutable Python state"))
                    elif isinstance(node, (ast.Assign, ast.AugAssign)):
                        targets = (node.targets
                                   if isinstance(node, ast.Assign)
                                   else [node.target])
                        for tgt in targets:
                            chain = astutil.attr_chain(tgt)
                            if chain and chain.startswith("self."):
                                out.append(Finding(
                                    self.id, sf.path, node.lineno,
                                    f"{fn.name} ({how} jit scope) "
                                    f"assigns {chain} — mutation "
                                    f"escaping the trace"))
                    elif isinstance(node, ast.Call):
                        chain = astutil.attr_chain(node.func)
                        if chain and self._forbidden(chain):
                            out.append(Finding(
                                self.id, sf.path, node.lineno,
                                f"{fn.name} ({how} jit scope) calls "
                                f"{chain}() — trace-time "
                                f"nondeterminism"))
                    elif (isinstance(node, ast.Subscript)
                          and astutil.attr_chain(node.value)
                          == "os.environ"):
                        out.append(Finding(
                            self.id, sf.path, node.lineno,
                            f"{fn.name} ({how} jit scope) reads "
                            f"os.environ — trace-time nondeterminism"))
        return out

    @staticmethod
    def _forbidden(chain: str) -> bool:
        if chain in _FORBIDDEN_EXACT:
            return True
        if any(chain.startswith(p) for p in _FORBIDDEN_PREFIXES):
            return True
        head, _, tail = chain.rpartition(".")
        if tail in _FORBIDDEN_TAILS and head in ("time", "datetime",
                                                 "datetime.datetime"):
            return True
        if chain.startswith("os.environ"):
            return True
        return False


RULES = [JitBodyPurity()]
