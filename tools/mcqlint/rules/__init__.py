"""Rule modules; each exposes ``RULES = [...]``."""
