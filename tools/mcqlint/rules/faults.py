"""I-fault rule: the failpoint surface is closed and fully exercised
(invariant I10).

Failpoints only earn their keep if every site is (a) registered — arming
validates names against ``FAILPOINT_CATALOG``, so a typo'd site would be
armable never and hit always — and (b) actually injected by the fault
matrix, otherwise an IO edge's failure path ships untested.  Statically,
across the scanned tree:

* every ``failpoint("name")`` call passes a literal string (sites must be
  statically enumerable; a computed name cannot be audited),
* every site name appears in a ``FAILPOINT_CATALOG`` literal found in the
  scanned tree (unknown names are dead switches: disarmed forever),
* every catalog entry has at least one call site (an orphan entry is a
  fault edge that silently lost its instrumentation),
* when a test tree was scanned, every site name is mentioned by it — the
  fault-matrix table in ``tests/test_faults.py`` must inject each one.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from tools.mcqlint.core import Finding, Project, Rule


def _catalog_names(sf) -> List[Tuple[str, ast.AST]]:
    """``FAILPOINT_CATALOG = {"name": ..., ...}`` literal entries, if the
    module declares one."""
    out: List[Tuple[str, ast.AST]] = []
    for node in sf.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if "FAILPOINT_CATALOG" not in targets:
            continue
        if isinstance(node.value, (ast.Dict, ast.Set)):
            keys = (node.value.keys if isinstance(node.value, ast.Dict)
                    else node.value.elts)
            for key in keys:
                if (isinstance(key, ast.Constant)
                        and isinstance(key.value, str)):
                    out.append((key.value, key))
    return out


def _is_failpoint_call(node: ast.Call) -> bool:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id == "failpoint"
    if isinstance(fn, ast.Attribute):
        return fn.attr == "failpoint"
    return False


class FailpointCoverage(Rule):
    id = "MCQ-R001"
    summary = ("every failpoint() site uses a literal name registered in "
               "FAILPOINT_CATALOG; every catalog entry has a site; every "
               "site is injected by the fault-matrix tests")

    def check(self, project: Project) -> List[Finding]:
        out: List[Finding] = []
        catalog: Dict[str, tuple] = {}
        sites: Dict[str, List[tuple]] = {}
        for sf in project.files:
            for name, node in _catalog_names(sf):
                catalog.setdefault(name, (sf, node))
            for node in ast.walk(sf.tree):
                if not (isinstance(node, ast.Call)
                        and _is_failpoint_call(node)):
                    continue
                if not node.args or not (
                        isinstance(node.args[0], ast.Constant)
                        and isinstance(node.args[0].value, str)):
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        "failpoint() site name must be a literal string "
                        "(sites are audited statically)"))
                    continue
                sites.setdefault(node.args[0].value, []).append((sf, node))
        if not catalog and not sites:
            return out   # tree has no failpoint surface at all

        for name, hits in sorted(sites.items()):
            if catalog and name not in catalog:
                for sf, node in hits:
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"failpoint site '{name}' is not registered in "
                        f"FAILPOINT_CATALOG (unarmable: a dead switch)"))
        for name, (sf, node) in sorted(catalog.items()):
            if name not in sites:
                out.append(Finding(
                    self.id, sf.path, node.lineno,
                    f"FAILPOINT_CATALOG entry '{name}' has no "
                    f"failpoint() call site in the scanned tree"))
        # fault-matrix coverage: each site injected by at least one test
        if project.tests_text is not None:
            for name, hits in sorted(sites.items()):
                if name not in project.tests_text:
                    sf, node = hits[0]
                    out.append(Finding(
                        self.id, sf.path, node.lineno,
                        f"failpoint site '{name}' is not exercised by "
                        f"the fault-matrix tests (inject it in "
                        f"tests/test_faults.py)"))
        return out


RULES = [FailpointCoverage()]
