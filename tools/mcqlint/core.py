"""mcqlint runner: file discovery, rule dispatch, findings, junit, CLI.

Rules never import the analyzed code — everything is AST-level, so linting
``src/`` costs milliseconds and cannot be perturbed by import-time effects
(jax initialisation, device discovery).  A rule sees the whole
:class:`Project` (every parsed file plus, optionally, the raw text of the
test tree) so cross-file invariants (kernel parity) are first-class.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import sys
from typing import Dict, List, Optional, Sequence
from xml.sax.saxutils import escape

from tools.mcqlint import catalog


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class SourceFile:
    path: str       # as given (repo-relative in CI)
    text: str
    tree: ast.Module

    @property
    def name(self) -> str:
        return os.path.basename(self.path)


class Project:
    """Everything a rule may look at."""

    def __init__(self, files: Sequence[SourceFile],
                 tests_text: Optional[str] = None):
        self.files = list(files)
        #: concatenated text of tests/*.py when a test tree was scanned,
        #: None when not (fixture runs) — rules must skip test-mention
        #: checks in that case rather than flagging everything.
        self.tests_text = tests_text


class Rule:
    """One invariant check.  Subclasses set ``id``/``summary`` and
    implement :meth:`check`."""

    id: str = ""
    summary: str = ""

    def check(self, project: Project) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, sf: SourceFile, node, message: str) -> Finding:
        return Finding(self.id, sf.path, getattr(node, "lineno", 0), message)


def all_rules() -> List[Rule]:
    from tools.mcqlint.rules import (counters, faults, locks, metrics,
                                     ordering, parity, purity, ruffish)
    rules: List[Rule] = []
    for mod in (locks, ordering, parity, counters, purity, ruffish, faults,
                metrics):
        rules.extend(mod.RULES)
    ids = [r.id for r in rules]
    assert len(ids) == len(set(ids)), f"duplicate rule ids: {ids}"
    known = catalog.by_rule()
    missing = [i for i in ids if i not in known]
    assert not missing, f"rules missing from the catalog: {missing}"
    return rules


# ---------------------------------------------------------------------------
# discovery + run
# ---------------------------------------------------------------------------


def _iter_py(paths: Sequence[str]):
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for n in sorted(names):
                    if n.endswith(".py"):
                        yield os.path.join(root, n)


def load_project(paths: Sequence[str],
                 tests_dir: Optional[str] = None) -> Project:
    files: List[SourceFile] = []
    for path in _iter_py(paths):
        with open(path, "r") as f:
            text = f.read()
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            raise SystemExit(f"mcqlint: cannot parse {path}: {e}")
        files.append(SourceFile(path=path, text=text, tree=tree))
    tests_text = None
    if tests_dir and os.path.isdir(tests_dir):
        chunks = []
        for path in _iter_py([tests_dir]):
            with open(path, "r") as f:
                chunks.append(f.read())
        tests_text = "\n".join(chunks)
    return Project(files, tests_text=tests_text)


def run_paths(paths: Sequence[str], select: Optional[Sequence[str]] = None,
              tests_dir: Optional[str] = None) -> List[Finding]:
    """Lint ``paths``; returns findings sorted by (path, line, rule).

    ``select`` restricts to the given rule ids (fixture self-tests);
    ``tests_dir`` enables the test-mention half of the parity rule.
    """
    project = load_project(paths, tests_dir=tests_dir)
    findings: List[Finding] = []
    for rule in all_rules():
        if select and rule.id not in select:
            continue
        findings.extend(rule.check(project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


# ---------------------------------------------------------------------------
# junit + CLI
# ---------------------------------------------------------------------------


def write_junit(findings: List[Finding], rules: List[Rule],
                path: str) -> None:
    """One junit testcase per rule; a rule with findings fails with every
    finding in its message (CI surfaces the XML as an artifact)."""
    by_rule: Dict[str, List[Finding]] = {r.id: [] for r in rules}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    cases = []
    for rule in rules:
        got = by_rule.get(rule.id, [])
        body = ""
        if got:
            text = escape("\n".join(f.render() for f in got))
            body = (f'<failure message="{len(got)} finding(s)">'
                    f"{text}</failure>")
        cases.append(f'<testcase classname="mcqlint" name="{rule.id}">'
                     f"{body}</testcase>")
    xml = ('<?xml version="1.0" encoding="utf-8"?>\n'
           f'<testsuite name="mcqlint" tests="{len(rules)}" '
           f'failures="{sum(1 for c in cases if "<failure" in c)}">'
           + "".join(cases) + "</testsuite>\n")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(xml)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.mcqlint",
        description="invariant-enforcing static analyzer (DESIGN.md §11)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only these rule ids")
    ap.add_argument("--junit", default=None, metavar="FILE",
                    help="write a junit XML report")
    ap.add_argument("--tests-dir", default="tests",
                    help="test tree for the parity test-mention check "
                         "(default: tests; pass '' to disable)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--catalog", action="store_true",
                    help="print the invariant catalog table and exit")
    args = ap.parse_args(argv)

    if args.catalog:
        print(catalog.render_table())
        return 0
    rules = all_rules()
    if args.list_rules:
        inv = catalog.by_rule()
        for r in rules:
            print(f"{r.id}  [{inv[r.id].id}/{inv[r.id].key}]  {r.summary}")
        return 0

    paths = args.paths or ["src"]
    tests_dir = args.tests_dir or None
    findings = run_paths(paths, select=args.select, tests_dir=tests_dir)
    for f in findings:
        print(f.render())
    if args.junit:
        write_junit(findings, rules, args.junit)
    if findings:
        print(f"mcqlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0
