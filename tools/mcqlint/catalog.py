"""The typed invariant catalog (DESIGN.md §11).

Each entry is one machine-checked invariant of the engine, cross-referenced
to the DESIGN.md assumption log (A1-A12) it underwrites and to the mcqlint
rule ids (and/or explorer scenarios) that enforce it.  DESIGN.md §11 renders
this table in prose; ``python -m tools.mcqlint --catalog`` prints it; the
test suite asserts every rule id maps back to exactly one invariant.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class Invariant:
    id: str                    # I1..In
    key: str                   # short family key (I-lock, I-order, ...)
    statement: str             # one-sentence normative statement
    assumptions: Tuple[str, ...]   # A1..A12 entries it underwrites
    rules: Tuple[str, ...]         # mcqlint rule ids enforcing it
    dynamic: Tuple[str, ...] = ()  # explorer scenarios exercising it


CATALOG: Tuple[Invariant, ...] = (
    Invariant(
        id="I1", key="I-lock",
        statement=(
            "State a class declares lock-protected (_MCQ_LOCK_PROTECTS) — "
            "EpochStore-published snapshots, Engine stats dicts, the WAL "
            "seq — is mutated only with the declared lock held, either "
            "lexically (with self.lock:) or by contract (@requires_lock)."),
        assumptions=("A2", "A11"),
        rules=("MCQ-L001", "MCQ-L002"),
        dynamic=("stats_lost_update",),
    ),
    Invariant(
        id="I2", key="I-lock",
        statement=(
            "Locks of one class are acquired only in the declared total "
            "order (_MCQ_LOCK_ORDER, outermost first); every lock the "
            "class owns appears in the order."),
        assumptions=("A2",),
        rules=("MCQ-L003", "MCQ-L004"),
    ),
    Invariant(
        id="I3", key="I-order",
        statement=(
            "A batch is WAL-appended strictly before it is applied to the "
            "chain (write-AHEAD: a torn append is a batch that never "
            "happened)."),
        assumptions=("A11",),
        rules=("MCQ-O001",),
        dynamic=("wal_double_replay",),
    ),
    Invariant(
        id="I4", key="I-order",
        statement=(
            "Snapshot payload (chain.json sidecar, arrays.npz) is written "
            "strictly before the manifest rename; nothing is written after "
            "the rename — the rename IS the commit."),
        assumptions=("A11",),
        rules=("MCQ-O002",),
    ),
    Invariant(
        id="I5", key="I-parity",
        statement=(
            "Every kernel dispatcher registers (@kernel_op) a bit-exact ref "
            "oracle or a composition of registered ops; every *_pallas "
            "kernel is reachable from a registration; every op is named by "
            "an equivalence test."),
        assumptions=("A9", "A2"),
        rules=("MCQ-P001",),
    ),
    Invariant(
        id="I6", key="I-counter",
        statement=(
            "Every MCState counter field initialised to int32(0) is "
            "surfaced through mcprioq.counter_stats (_COUNTER_FIELDS) or "
            "maintenance_stats — no silent drops."),
        assumptions=("A4", "A6", "A10"),
        rules=("MCQ-C001",),
        dynamic=("counter_conservation",),
    ),
    Invariant(
        id="I7", key="I-purity",
        statement=(
            "jit/shard_map bodies are pure: no wall-clock or host RNG "
            "calls, no global/nonlocal writes, no mutation of self — "
            "replay determinism (bit-exact recovery) depends on it."),
        assumptions=("A9", "A12"),
        rules=("MCQ-U001",),
    ),
    Invariant(
        id="I8", key="I-route",
        statement=(
            "A routing program is only ever paired with the snapshot it "
            "was compiled against: _rebind swaps (cfg, _update, _maintain) "
            "and publishes under _route_lock; readers fetch the pair under "
            "the same lock."),
        assumptions=("A6", "A10", "A12"),
        rules=("MCQ-L001", "MCQ-L002"),
        dynamic=("route_snapshot_mispairing",),
    ),
    Invariant(
        id="I10", key="I-fault",
        statement=(
            "The failpoint surface is closed and exercised: every "
            "failpoint() site uses a literal name registered in "
            "FAILPOINT_CATALOG, every catalog entry keeps a call site, "
            "and the fault-matrix tests inject every site (retry, "
            "escalation or degraded-mode behaviour asserted)."),
        assumptions=("A13", "A14"),
        rules=("MCQ-R001",),
    ),
    Invariant(
        id="I11", key="I-metric",
        statement=(
            "The metric-name surface is closed: every recorder call "
            "(counter_add/gauge_set/hist_record/vector_add/span) uses a "
            "literal name declared in METRIC_CATALOG, and every catalog "
            "entry is recorded or referenced somewhere in src — no "
            "untyped series, no flatlined dashboard entries."),
        assumptions=("A16",),
        rules=("MCQ-M001",),
    ),
    Invariant(
        id="I9", key="I-hygiene",
        statement=(
            "Tree hygiene mcqlint absorbs from ruff (uninstallable "
            "in-container): no unused imports (F401, __init__.py exempt), "
            "no ambiguous l/O/I names (E741)."),
        assumptions=(),
        rules=("MCQ-F401", "MCQ-E741"),
    ),
)


def by_rule() -> Dict[str, Invariant]:
    """rule id -> invariant (first catalog entry naming the rule wins for
    display; rules may underwrite several invariants)."""
    out: Dict[str, Invariant] = {}
    for inv in CATALOG:
        for rule in inv.rules:
            out.setdefault(rule, inv)
    return out


def render_table() -> str:
    lines = ["| Id | Family | Invariant | Assumptions | Enforced by |",
             "|----|--------|-----------|-------------|-------------|"]
    for inv in CATALOG:
        enforced = list(inv.rules) + [f"explorer:{s}" for s in inv.dynamic]
        lines.append("| {} | {} | {} | {} | {} |".format(
            inv.id, inv.key, inv.statement.replace("|", "\\|"),
            " ".join(inv.assumptions) or "—", ", ".join(enforced)))
    return "\n".join(lines)
