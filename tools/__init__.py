"""Repo tooling namespace (not shipped with ``repro``)."""
