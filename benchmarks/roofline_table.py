"""Render the §Roofline / §Dry-run tables in EXPERIMENTS.md from the
results/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_table [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(d: str, tag: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{tag}.json"))):
        rows.append(json.load(open(f)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order[r["shape"]]))
    return rows


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(rows):
    print("| arch | shape | t_compute | t_memory | t_coll | bottleneck | "
          "useful-FLOPs | mem-vs-floor | roofline-frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                  f"(full attention needs O(S) KV at 500k) | — | — | — |")
            continue
        x = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(x['t_compute_s'])} "
              f"| {fmt_s(x['t_memory_s'])} | {fmt_s(x['t_collective_s'])} "
              f"| {x['bottleneck']} | {x['useful_flops_ratio']:.2f} "
              f"| {x.get('memory_vs_floor', 0):.0f}x "
              f"| {x['roofline_fraction']*100:.2f}% |")


def dryrun_table(rows):
    print("| arch | shape | mesh | compile | args/dev | peak/dev | "
          "coll bytes/dev | top collective |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | skipped | — | — | — | — |")
            continue
        m = r["memory"]
        x = r["roofline"]
        top = max(x["collective_by_class"].items(),
                  key=lambda kv: kv[1])[0] if x["collective_by_class"] else "-"
        print(f"| {r['arch']} | {r['shape']} | {'x'.join(map(str, r['mesh']))} "
              f"| {r['compile_s']:.0f}s | {m['argument_bytes']/1e9:.2f}GB "
              f"| {m['peak_estimate_bytes']/1e9:.2f}GB "
              f"| {x['collective_bytes_per_device']/1e9:.1f}GB | {top} |")


def compare_table(base_rows, opt_rows):
    """Paper-faithful baseline vs beyond-paper optimized, per cell."""
    opt = {(r["arch"], r["shape"]): r for r in opt_rows}
    print("| arch | shape | baseline dom. term | optimized dom. term | "
          "speedup | frac before | frac after | variant |")
    print("|---|---|---|---|---|---|---|---|")
    for r in base_rows:
        key = (r["arch"], r["shape"])
        o = opt.get(key)
        if r["status"] == "skipped" or o is None or o["status"] != "ok":
            continue
        rb, ro = r["roofline"], o["roofline"]
        dom_b = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
        dom_o = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(dom_b)} "
              f"({rb['bottleneck'][:4]}) | {fmt_s(dom_o)} "
              f"({ro['bottleneck'][:4]}) | {dom_b/max(dom_o,1e-12):.1f}x "
              f"| {rb['roofline_fraction']*100:.2f}% "
              f"| {ro['roofline_fraction']*100:.2f}% "
              f"| {o.get('variant','-')} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--opt-dir", default="results/dryrun_opt")
    ap.add_argument("--table",
                    choices=["roofline", "dryrun", "compare", "all"],
                    default="all")
    args = ap.parse_args()
    single = load(args.dir, "singlepod")
    multi = load(args.dir, "multipod")
    if args.table in ("roofline", "all"):
        print("\n### Roofline, paper-faithful baseline "
              "(single-pod 16x16 = 256 chips)\n")
        roofline_table(single)
    if args.table in ("compare", "all") and os.path.isdir(args.opt_dir):
        opt_single = load(args.opt_dir, "singlepod")
        print("\n### Baseline vs optimized (single-pod)\n")
        compare_table(single, opt_single)
        print("\n### Roofline, optimized (single-pod)\n")
        roofline_table(opt_single)
    if args.table in ("dryrun", "all"):
        print("\n### Dry-run, single-pod (16x16)\n")
        dryrun_table(single)
        print("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
        dryrun_table(multi)


if __name__ == "__main__":
    main()
