"""Inject generated dry-run/roofline/compare tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.update_experiments
"""

from __future__ import annotations

import contextlib
import io
import re

from benchmarks import roofline_table as rt

MARKERS = {
    "<!-- DRYRUN-TABLES -->": ("dryrun",),
    "<!-- ROOFLINE-TABLE -->": ("roofline",),
    "<!-- PERF-FINAL -->": ("compare",),
}


def render(kind: str) -> str:
    single = rt.load("results/dryrun", "singlepod")
    multi = rt.load("results/dryrun", "multipod")
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        if kind == "dryrun":
            print("### Dry-run, single-pod (16x16 = 256 chips)\n")
            rt.dryrun_table(single)
            print("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
            rt.dryrun_table(multi)
        elif kind == "roofline":
            rt.roofline_table(single)
        elif kind == "compare":
            opt_single = rt.load("results/dryrun_opt", "singlepod")
            print("### Baseline vs optimized, single-pod "
                  "(dominant roofline term per step)\n")
            rt.compare_table(single, opt_single)
            print("\n### Roofline, optimized configuration (single-pod)\n")
            rt.roofline_table(opt_single)
    return buf.getvalue()


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    for marker, (kind,) in MARKERS.items():
        block = (f"{marker}\n\n" + render(kind)).rstrip() + "\n"
        # replace marker and any previously generated block up to next header
        pat = re.escape(marker) + r"(?:.*?)(?=\n## |\Z)"
        text = re.sub(pat, block + "\n", text, flags=re.S)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
