"""Benchmark harness: one benchmark per paper claim.

The paper (MCPrioQ) is evaluated on complexity/throughput, not accuracy; it
has no numbered tables, so each benchmark validates one stated claim:

  B1 update_throughput   O(1) amortised updates (§II.A) — edges/sec flat in
                         graph size
  B2 query_cdf           O(CDF^-1(t)) inference (§II.B) — items touched vs
                         threshold, per Zipf exponent
  B3 sortedness          approximate order under continuous updates (§II.2)
  B4 decay               §II.C decay cost + eviction behaviour
  B5 hash_vs_scan        dst hash-table vs slab scan (§II.2 "may not be that
                         obvious")
  B6 drafter             serving feature: n-gram drafter acceptance rate
  B7 sharded_routing     all_to_all node-sharded scaling (8 fake devices)

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and writes
``BENCH_<bench>.json`` next to this file with the same rows in machine-
readable form, so successive PRs can diff perf runs.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core import speculative as spec
from repro.data.synthetic import MarkovGraphSampler

_HERE = os.path.dirname(os.path.abspath(__file__))


class Recorder:
    """Collects (name, us_per_call, derived, extras) rows per benchmark and
    mirrors every CSV line into ``BENCH_<bench>.json``."""

    def __init__(self):
        self.rows = {}

    def emit(self, bench: str, name: str, us: float, derived: str, **extra):
        # small values (per-query latencies, ratios) keep their decimals
        print(f"{name},{us:.2f},{derived}" if us < 100 else
              f"{name},{us:.1f},{derived}")
        self.rows.setdefault(bench, []).append(
            {"name": name, "us_per_call": round(us, 3), "derived": derived,
             **extra})

    def write(self, bench: str):
        path = os.path.join(_HERE, f"BENCH_{bench}.json")
        with open(path, "w") as f:
            json.dump({"bench": bench, "rows": self.rows.get(bench, [])},
                      f, indent=1)
        return path


REC = Recorder()


def _time(fn, *args, n=10, warmup=2):
    """Median per-call latency in us (robust to CPU scheduling noise)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)) * 1e6  # us


def bench_update_throughput():
    """B1: edges/sec for batched updates; flat across graph sizes = O(1),
    plus a new-edge-fraction sweep of the fused pipeline vs the seed path."""
    batch = 1024
    rows = []
    for num_nodes in (256, 1024, 4096):
        cfg = mc.MCConfig(num_rows=num_nodes, capacity=64, sort_passes=1)
        graph = MarkovGraphSampler(num_nodes=num_nodes, out_degree=32, seed=0)
        state = mc.init(cfg)
        # warm the graph so updates take the fast path (paper's normal case)
        for _ in range(4):
            s, d = graph.sample_transitions(batch)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        s, d = graph.sample_transitions(batch)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=5)
        eps = batch / (us / 1e6)
        rows.append((num_nodes, us, eps))
        REC.emit("update", f"B1_update_throughput[nodes={num_nodes}]", us,
                 f"{eps:.0f} edges/s", nodes=num_nodes,
                 edges_per_s=round(eps))
    # O(1) check: us/edge varies < 3x across 16x graph growth
    per_edge = [r[1] / batch for r in rows]
    REC.emit("update", "B1_o1_ratio", max(per_edge) / min(per_edge),
             "us/edge ratio across 16x graph sizes")

    # new-edge-fraction sweep: fused pipeline (bounded slow path, kernel
    # dispatch) vs the seed implementation (O(B) sequential scan per batch).
    # Injected new edges reuse warmed srcs, so num_rows stays at graph scale.
    num_nodes = 1024
    cfg = mc.MCConfig(num_rows=num_nodes, capacity=64, sort_passes=1,
                      max_new_per_batch=128)
    graph = MarkovGraphSampler(num_nodes=num_nodes, out_degree=32, seed=0)
    state = mc.init(cfg)
    # warm with the FULL edge list, uncapped, so every graph edge is live
    # and frac exactly controls the new-edge count (paper's steady state);
    # warming through the capped config would silently defer most edges
    warm_cfg = dataclasses.replace(cfg, max_new_per_batch=0)
    all_src = np.repeat(np.arange(num_nodes, dtype=np.int32),
                        graph.out_degree)
    all_dst = graph.dsts.reshape(-1).astype(np.int32)
    for i in range(0, all_src.size, batch):
        state = mc.update_batch(state, jnp.asarray(all_src[i:i + batch]),
                                jnp.asarray(all_dst[i:i + batch]),
                                cfg=warm_cfg)
    for frac in (0.0, 0.01, 0.1, 0.5):
        s, d = graph.sample_transitions_mixed(batch, frac)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us_new = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=15)
        us_ref = _time(
            lambda: mc.update_batch_reference(state, s, d, cfg=cfg), n=15)
        speedup = us_ref / us_new
        # work parity check: edges the capped path defers but the seed
        # path applies (0 while round(frac * batch) <= max_new_per_batch)
        deferred = int(mc.update_batch(state, s, d, cfg=cfg).deferred_new
                       - state.deferred_new)
        REC.emit("update", f"B1_new_edge_sweep[frac={frac}]", us_new,
                 f"{speedup:.1f}x vs seed path ({us_ref:.0f} us, "
                 f"deferred={deferred})",
                 new_edge_fraction=frac, batch=batch,
                 us_per_call_seed=round(us_ref, 3),
                 speedup_vs_seed=round(speedup, 2),
                 deferred_new=deferred,
                 max_new_per_batch=cfg.max_new_per_batch)
    REC.write("update")


def bench_query_cdf():
    """B2: items touched (CDF^-1) and latency vs threshold and Zipf s."""
    cfg = mc.MCConfig(num_rows=2048, capacity=64, sort_passes=2)
    for zipf_s in (1.2, 1.5, 2.0):
        graph = MarkovGraphSampler(num_nodes=2048, out_degree=48,
                                   zipf_s=zipf_s, seed=1)
        state = mc.init(cfg)
        for _ in range(30):
            s, d = graph.sample_transitions(2048)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        srcs = jnp.arange(512, dtype=jnp.int32)
        for t in (0.5, 0.9, 0.99):
            us = _time(lambda: mc.query_threshold(
                state, srcs, t, cfg=cfg, max_items=48), n=5)
            _, _, n_needed = mc.query_threshold(state, srcs, t, cfg=cfg,
                                                max_items=48)
            mean_items = float(jnp.mean(n_needed.astype(jnp.float32)))
            REC.emit("query_cdf", f"B2_query_cdf[s={zipf_s};t={t}]", us / 512,
                     f"{mean_items:.2f} items touched (CDF^-1)",
                     zipf_s=zipf_s, threshold=t,
                     mean_items=round(mean_items, 3))
    REC.write("query_cdf")


def bench_sortedness():
    """B3: order quality after each update batch, by sort passes."""
    from repro.core import slab as sl
    for passes in (0, 1, 2, 4):
        cfg = mc.MCConfig(num_rows=512, capacity=64, sort_passes=passes)
        graph = MarkovGraphSampler(num_nodes=512, out_degree=48, seed=2)
        state = mc.init(cfg)
        fracs = []
        for _ in range(20):
            s, d = graph.sample_transitions(1024)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
            fracs.append(float(sl.sorted_fraction(state.slabs.cnt,
                                                  state.slabs.order)))
        REC.emit("sortedness", f"B3_sortedness[passes={passes}]", 0.0,
                 f"{np.mean(fracs[5:]):.4f} sorted fraction steady state",
                 passes=passes, sorted_fraction=round(float(np.mean(fracs[5:])), 5))
    REC.write("sortedness")


def bench_decay():
    """B4: decay latency and eviction count on a loaded graph."""
    cfg = mc.MCConfig(num_rows=4096, capacity=64, sort_passes=1)
    graph = MarkovGraphSampler(num_nodes=4096, out_degree=32, seed=3)
    state = mc.init(cfg)
    for _ in range(20):
        s, d = graph.sample_transitions(4096)
        state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                cfg=cfg)
    live_before = int(jnp.sum(state.slabs.cnt > 0))
    us = _time(lambda: mc.decay(state, cfg=cfg), n=5)
    state2 = mc.decay(state, cfg=cfg)
    live_after = int(jnp.sum(state2.slabs.cnt > 0))
    REC.emit("decay", "B4_decay", us,
             f"evicted {live_before - live_after} of {live_before} edges",
             evicted=live_before - live_after, live_before=live_before)
    REC.write("decay")


def bench_hash_vs_scan():
    """B5: dst lookup via per-row hash table vs C-lane slab scan."""
    for use_hash, label in ((False, "scan"), (True, "hash")):
        cfg = mc.MCConfig(num_rows=1024, capacity=64, sort_passes=1,
                          use_dst_hash=use_hash)
        graph = MarkovGraphSampler(num_nodes=1024, out_degree=48, seed=4)
        state = mc.init(cfg)
        for _ in range(4):
            s, d = graph.sample_transitions(1024)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        s, d = graph.sample_transitions(1024)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=5)
        REC.emit("hash_vs_scan", f"B5_dst_lookup[{label}]", us,
                 "update batch 1024", lookup=label)
    REC.write("hash_vs_scan")


def bench_drafter():
    """B6: n-gram drafter acceptance on a structured stream."""
    ncfg = spec.NGramConfig(order=2, mc=mc.MCConfig(num_rows=4096,
                                                    capacity=32,
                                                    sort_passes=1))
    st = spec.init(ncfg)
    rng = np.random.default_rng(5)
    # 80% deterministic successor process
    succ = rng.integers(0, 512, (512,)).astype(np.int32)
    toks = np.empty((8, 512), np.int32)
    toks[:, 0] = rng.integers(0, 512, 8)
    for t in range(1, 512):
        follow = succ[toks[:, t - 1]]
        noise = rng.integers(0, 512, 8)
        toks[:, t] = np.where(rng.random(8) < 0.8, follow, noise)
    t0 = time.perf_counter()
    st = spec.observe(st, jnp.asarray(toks), cfg=ncfg)
    jax.block_until_ready(st.chain.slabs.cnt)
    us = (time.perf_counter() - t0) * 1e6
    # drafts where the chain knows the successor
    ctx = jnp.asarray(toks[:, 100:102])
    draft, ok = spec.draft(st, ctx, cfg=ncfg, k=1)
    okm = np.asarray(ok)[:, 0]
    want = succ[np.asarray(ctx)[:, -1]]
    acc = float(np.mean((np.asarray(draft)[:, 0] == want)[okm])) if okm.any() else 0.0
    REC.emit("drafter", "B6_drafter", us,
             f"top-1 draft matches true successor {acc:.0%} of ok-drafts",
             acceptance=round(acc, 4))
    REC.write("drafter")


def bench_sharded_routing():
    """B7: node-sharded update/query on 8 fake host devices (subprocess)."""
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os, time
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.core import mcprioq as mc, sharded as sh
        mesh = compat.make_mesh((8,), ("shard",))
        scfg = sh.ShardedConfig(base=mc.MCConfig(num_rows=2048, capacity=32,
                                                 sort_passes=1),
                                num_shards=8, bucket_factor=2.0)
        state = sh.init_sharded(scfg, mesh)
        upd = sh.make_update_fn(scfg, mesh)
        rng = np.random.default_rng(0)
        src = jnp.asarray(rng.integers(0, 8192, 4096).astype(np.int32))
        dst = jnp.asarray(rng.integers(0, 512, 4096).astype(np.int32))
        w = jnp.ones((4096,), jnp.int32)
        state = upd(state, src, dst, w)  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            state = upd(state, src, dst, w)
        jax.block_until_ready(state.slabs.cnt)
        us = (time.perf_counter() - t0) / 5 * 1e6
        print(f"B7_sharded_routing,{us:.0f},4096 edges over 8 shards "
              f"(dropped={int(jnp.sum(state.dropped_probes))})")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    # stdout may carry stray warnings: keep the last well-formed B7_ line
    lines = [ln for ln in out.stdout.splitlines()
             if ln.startswith("B7_") and ln.count(",") >= 2]
    if lines:
        name, us, derived = lines[-1].split(",", 2)
        REC.emit("sharded_routing", name, float(us), derived)
    else:  # keep the grep-able FAILED sentinel in CSV and JSON
        REC.emit("sharded_routing", "B7_sharded_routing", -1.0,
                 f"FAILED {out.stderr[-200:]}", failed=True)
    REC.write("sharded_routing")


def main() -> None:
    print("name,us_per_call,derived")
    bench_update_throughput()
    bench_query_cdf()
    bench_sortedness()
    bench_decay()
    bench_hash_vs_scan()
    bench_drafter()
    bench_sharded_routing()


if __name__ == "__main__":
    main()
