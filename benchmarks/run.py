"""Benchmark harness: one benchmark per paper claim.

The paper (MCPrioQ) is evaluated on complexity/throughput, not accuracy; it
has no numbered tables, so each benchmark validates one stated claim:

  B1 update_throughput   O(1) amortised updates (§II.A) — edges/sec flat in
                         graph size
  B2 query_cdf           O(CDF^-1(t)) inference (§II.B) — items touched vs
                         threshold, per Zipf exponent
  B3 sortedness          approximate order under continuous updates (§II.2)
  B4 decay               §II.C maintenance: stop-the-world vs rolling decay
                         (per-call cost must scale with decay_block_rows,
                         not num_rows), dst-hash repair on/off
  B5 hash_vs_scan        dst hash-table vs slab scan (§II.2 "may not be that
                         obvious")
  B6 drafter             serving feature: n-gram drafter acceptance rate
  B7 sharded_routing     all_to_all node-sharded scaling (8 fake devices)
  B8 persist             durability subsystem (DESIGN.md §10): snapshot
                         save/restore, WAL append per fsync policy + replay
                         throughput, N -> M elastic reshard (8 fake devices)
  B9 faults              crash soak (DESIGN.md §12): SIGKILL a serving
                         worker in a loop (externally and from inside the
                         persistence failpoints), assert bit-exact recovery
                         vs the deterministic-replay oracle, record
                         recovery time per kill (tools/chaos/soak.py)
  B10 obs                telemetry overhead (DESIGN.md §13): armed vs
                         disarmed on the observe/query hot paths plus the
                         disarmed gate cost in isolation — disarmed must
                         be ~free, armed must stay within budget

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and writes
``BENCH_<bench>.json`` next to this file with the same rows in machine-
readable form, so successive PRs can diff perf runs.

``--smoke`` shrinks every benchmark to CI scale (same recorders, same JSON
schema, minutes not hours); ``--validate`` checks every ``BENCH_*.json`` on
disk against the recorder schema and exits non-zero on stale files.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import mcprioq as mc
from repro.core import speculative as spec
from repro.data.synthetic import MarkovGraphSampler

_HERE = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(_HERE)

SMOKE = False  # set by --smoke: CI-scale sizes, full recorder coverage


class Recorder:
    """Collects (name, us_per_call, derived, extras) rows per benchmark and
    mirrors every CSV line into ``BENCH_<bench>.json``."""

    def __init__(self):
        self.rows = {}

    def emit(self, bench: str, name: str, us: float, derived: str, **extra):
        # small values (per-query latencies, ratios) keep their decimals
        print(f"{name},{us:.2f},{derived}" if us < 100 else
              f"{name},{us:.1f},{derived}")
        self.rows.setdefault(bench, []).append(
            {"name": name, "us_per_call": round(us, 3), "derived": derived,
             **extra})

    def write(self, bench: str):
        path = os.path.join(_HERE, f"BENCH_{bench}.json")
        with open(path, "w") as f:
            json.dump({"bench": bench, "rows": self.rows.get(bench, [])},
                      f, indent=1)
        return path


REC = Recorder()


def _time(fn, *args, n=10, warmup=2):
    """Median per-call latency in us (robust to CPU scheduling noise)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)) * 1e6  # us


def _time_paired(fns, n=20, warmup=2):
    """Min per-call latency in us for several candidates, sampled in
    alternation so slow drift (thermal, background load) hits every
    candidate equally — the right design for A-vs-B sweeps where the
    quantity of interest is the ratio."""
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn())
    samples = [[] for _ in fns]
    for _ in range(n):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples[i].append(time.perf_counter() - t0)
    return [float(np.min(s)) * 1e6 for s in samples]


def bench_update_throughput():
    """B1: edges/sec for batched updates; flat across graph sizes = O(1),
    plus a new-edge-fraction sweep of the fused pipeline vs the seed path."""
    batch = 256 if SMOKE else 1024
    rows = []
    for num_nodes in (256, 1024) if SMOKE else (256, 1024, 4096):
        cfg = mc.MCConfig(num_rows=num_nodes, capacity=64, sort_passes=1)
        graph = MarkovGraphSampler(num_nodes=num_nodes, out_degree=32, seed=0)
        state = mc.init(cfg)
        # warm the graph so updates take the fast path (paper's normal case)
        for _ in range(4):
            s, d = graph.sample_transitions(batch)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        s, d = graph.sample_transitions(batch)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=5)
        eps = batch / (us / 1e6)
        rows.append((num_nodes, us, eps))
        REC.emit("update", f"B1_update_throughput[nodes={num_nodes}]", us,
                 f"{eps:.0f} edges/s", nodes=num_nodes,
                 edges_per_s=round(eps))
    # O(1) check: us/edge varies < 3x across 16x graph growth
    per_edge = [r[1] / batch for r in rows]
    REC.emit("update", "B1_o1_ratio", max(per_edge) / min(per_edge),
             "us/edge ratio across 16x graph sizes")

    # new-edge-fraction sweep: fused pipeline (bounded slow path, kernel
    # dispatch) vs the seed implementation (O(B) sequential scan per batch).
    # Injected new edges reuse warmed srcs, so num_rows stays at graph scale.
    num_nodes = 512 if SMOKE else 1024
    cfg = mc.MCConfig(num_rows=num_nodes, capacity=64, sort_passes=1,
                      max_new_per_batch=128)
    graph = MarkovGraphSampler(num_nodes=num_nodes, out_degree=32, seed=0)
    state = mc.init(cfg)
    # warm with the FULL edge list, uncapped, so every graph edge is live
    # and frac exactly controls the new-edge count (paper's steady state);
    # warming through the capped config would silently defer most edges
    warm_cfg = dataclasses.replace(cfg, max_new_per_batch=0)
    all_src = np.repeat(np.arange(num_nodes, dtype=np.int32),
                        graph.out_degree)
    all_dst = graph.dsts.reshape(-1).astype(np.int32)
    for i in range(0, all_src.size, batch):
        state = mc.update_batch(state, jnp.asarray(all_src[i:i + batch]),
                                jnp.asarray(all_dst[i:i + batch]),
                                cfg=warm_cfg)
    for frac in (0.0, 0.1) if SMOKE else (0.0, 0.01, 0.1, 0.5):
        s, d = graph.sample_transitions_mixed(batch, frac)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us_new = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=15)
        us_ref = _time(
            lambda: mc.update_batch_reference(state, s, d, cfg=cfg), n=15)
        speedup = us_ref / us_new
        # work parity check: edges the capped path defers but the seed
        # path applies (0 while round(frac * batch) <= max_new_per_batch)
        deferred = int(mc.update_batch(state, s, d, cfg=cfg).deferred_new
                       - state.deferred_new)
        REC.emit("update", f"B1_new_edge_sweep[frac={frac}]", us_new,
                 f"{speedup:.1f}x vs seed path ({us_ref:.0f} us, "
                 f"deferred={deferred})",
                 new_edge_fraction=frac, batch=batch,
                 us_per_call_seed=round(us_ref, 3),
                 speedup_vs_seed=round(speedup, 2),
                 deferred_new=deferred,
                 max_new_per_batch=cfg.max_new_per_batch)
    REC.write("update")


def bench_query_cdf():
    """B2: items touched (CDF^-1) and latency vs threshold and Zipf s, plus
    the DESIGN.md §8 read-side sweeps: fused vs unfused gather by batch
    size, and chunked early-exit cost vs mean_items (must track CDF^-1(t),
    not C)."""
    n = 512 if SMOKE else 2048
    cfg = mc.MCConfig(num_rows=n, capacity=64, sort_passes=2)
    fused_speedups = []   # (B >= 1024 rows) -> B2_fused_check aggregate
    for zipf_s in (1.5,) if SMOKE else (1.2, 1.5, 2.0):
        graph = MarkovGraphSampler(num_nodes=n, out_degree=48,
                                   zipf_s=zipf_s, seed=1)
        state = mc.init(cfg)
        for _ in range(10 if SMOKE else 30):
            s, d = graph.sample_transitions(n)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        srcs = jnp.arange(512, dtype=jnp.int32)
        for t in (0.5, 0.9, 0.99):
            us = _time(lambda: mc.query_threshold(
                state, srcs, t, cfg=cfg, max_items=48), n=5)
            _, _, n_needed = mc.query_threshold(state, srcs, t, cfg=cfg,
                                                max_items=48)
            mean_items = float(jnp.mean(n_needed.astype(jnp.float32)))
            REC.emit("query_cdf", f"B2_query_cdf[s={zipf_s};t={t}]", us / 512,
                     f"{mean_items:.2f} items touched (CDF^-1)",
                     zipf_s=zipf_s, threshold=t,
                     mean_items=round(mean_items, 3))

        # fused vs unfused gather: the in-kernel row gather must beat the
        # host-side O(B*C) _ordered_rows pipeline as B grows
        for batch in (128, 256) if SMOKE else (256, 1024, 4096):
            srcs_b = jnp.asarray(
                np.arange(batch, dtype=np.int32) % n)

            def q(fused):
                cfg_f = dataclasses.replace(cfg, fused_query=fused)
                return lambda: mc.query_threshold(
                    state, srcs_b, 0.9, cfg=cfg_f, max_items=16)

            us_unf, us_fus = _time_paired([q(False), q(True)],
                                          n=8 if SMOKE else 30)
            res = {False: us_unf, True: us_fus}
            speedup = res[False] / res[True]
            if batch >= (256 if SMOKE else 1024):
                fused_speedups.append(speedup)
            for fused in (False, True):
                REC.emit("query_cdf",
                         f"B2_fused_sweep[s={zipf_s};B={batch};"
                         f"fused={fused}]", res[fused],
                         f"{speedup:.2f}x fused/unfused at B={batch}",
                         zipf_s=zipf_s, batch=batch, fused=fused,
                         threshold=0.9,
                         speedup_fused=round(speedup, 3))
    if fused_speedups:
        # single-row CPU timings are noisy; the aggregate is the claim
        geo = float(np.exp(np.mean(np.log(fused_speedups))))
        REC.emit("query_cdf", "B2_fused_check", geo,
                 f"geomean fused speedup over {len(fused_speedups)} "
                 f"B>=1024 rows",
                 geomean_speedup=round(geo, 3),
                 rows_aggregated=len(fused_speedups))
    # chunked early-exit sweep (pallas kernel, big C): per-call cost must
    # grow with mean_items (CDF^-1(t)), not capacity — later chunks of
    # satisfied blocks are predicated off with @pl.when.  Rows carry a
    # near-uniform live prefix so CDF^-1(t) ~ t * live actually spans the
    # chunks (a steep zipf row saturates inside chunk 0 at every t), and
    # the kernel is timed directly on pre-ordered rows so the probe/gather
    # stages don't mask the walk.
    from repro.kernels import ops as kops
    cap = 256
    bq = 128 if SMOKE else 512
    rng = np.random.default_rng(2)
    live = cap - 32
    c_np = np.zeros((bq, cap), np.int32)
    c_np[:, :live] = rng.integers(90, 110, (bq, live))
    c_np = np.sort(c_np, axis=1)[:, ::-1].copy()
    c_ord = jnp.asarray(c_np)
    d_ord = jnp.asarray(rng.integers(0, 10_000, (bq, cap)).astype(np.int32))
    tot = jnp.asarray(c_np.sum(1).astype(np.int32))
    for t in (0.25, 0.5, 0.97):
        _, _, n_needed = kops.cdf_query(c_ord, d_ord, tot, t, max_items=16)
        mean_items = float(jnp.mean(n_needed.astype(jnp.float32)))
        for chunks in (1, 2) if SMOKE else (1, 2, 4):
            us = _time(lambda: kops.cdf_query(
                c_ord, d_ord, tot, t, max_items=16, chunks=chunks,
                impl="pallas"), n=5 if SMOKE else 15)
            REC.emit("query_cdf",
                     f"B2_chunk_sweep[t={t};chunks={chunks}]", us,
                     f"{mean_items:.1f} mean_items (CDF^-1), C={cap}",
                     threshold=t, chunks=chunks, capacity=cap,
                     mean_items=round(mean_items, 3))
    REC.write("query_cdf")


def bench_sortedness():
    """B3: order quality after each update batch, by sort passes."""
    from repro.core import slab as sl
    for passes in (0, 2) if SMOKE else (0, 1, 2, 4):
        cfg = mc.MCConfig(num_rows=512, capacity=64, sort_passes=passes)
        graph = MarkovGraphSampler(num_nodes=512, out_degree=48, seed=2)
        state = mc.init(cfg)
        fracs = []
        for _ in range(10 if SMOKE else 20):
            s, d = graph.sample_transitions(1024)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
            fracs.append(float(sl.sorted_fraction(state.slabs.cnt,
                                                  state.slabs.order)))
        REC.emit("sortedness", f"B3_sortedness[passes={passes}]", 0.0,
                 f"{np.mean(fracs[5:]):.4f} sorted fraction steady state",
                 passes=passes, sorted_fraction=round(float(np.mean(fracs[5:])), 5))
    REC.write("sortedness")


def bench_decay():
    """B4: §II.C maintenance-mode sweep (stop-the-world vs rolling decay,
    dst-hash repair on vs off).

    Two claims recorded: rolling per-call cost is *bounded* — it scales with
    ``decay_block_rows``, not ``num_rows`` (``B4_bounded_check``) — and a
    full rolling sweep costs about the same total work as one stop-the-world
    call, just amortised across ``n_blocks`` calls.
    """
    sizes = (512, 1024) if SMOKE else (1024, 4096)
    block = 128 if SMOKE else 256   # fixed block: per-call cost must be flat
    warm_iters = 6 if SMOKE else 20
    rolling_us = {}
    stw_us = {}
    for num_rows in sizes:
        graph = MarkovGraphSampler(num_nodes=num_rows, out_degree=32, seed=3)
        for use_hash in (False, True):
            warm_cfg = mc.MCConfig(num_rows=num_rows, capacity=64,
                                   sort_passes=1, use_dst_hash=use_hash)
            state = mc.init(warm_cfg)
            for _ in range(warm_iters):
                s, d = graph.sample_transitions(num_rows)
                state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                        cfg=warm_cfg)
            live_before = int(jnp.sum(state.slabs.cnt > 0))
            for block_rows in (0, block):
                cfg = dataclasses.replace(warm_cfg,
                                          decay_block_rows=block_rows)
                us = _time(lambda: mc.decay(state, cfg=cfg), n=5)
                mode = "stw" if block_rows == 0 else "rolling"
                hl = "hash" if use_hash else "scan"
                if block_rows == 0:
                    state2 = mc.decay(state, cfg=cfg)
                    live_after = int(jnp.sum(state2.slabs.cnt > 0))
                    derived = (f"evicted {live_before - live_after} of "
                               f"{live_before} edges")
                    stw_us[(num_rows, use_hash)] = us
                else:
                    n_blocks = -(-num_rows // block_rows)
                    derived = (f"1/{n_blocks} of rows per call "
                               f"(block={block_rows})")
                    rolling_us[(num_rows, use_hash)] = us
                REC.emit("decay",
                         f"B4_decay[rows={num_rows};mode={mode};{hl}]", us,
                         derived, num_rows=num_rows, mode=mode,
                         use_dst_hash=use_hash, decay_block_rows=block_rows,
                         live_edges=live_before)
    # bounded-cost check: at a fixed block size, rolling per-call cost must
    # stay ~flat while stop-the-world grows with num_rows
    lo, hi = sizes[0], sizes[-1]
    for use_hash in (False, True):
        roll_ratio = rolling_us[(hi, use_hash)] / rolling_us[(lo, use_hash)]
        stw_ratio = stw_us[(hi, use_hash)] / stw_us[(lo, use_hash)]
        hl = "hash" if use_hash else "scan"
        REC.emit("decay", f"B4_bounded_check[{hl}]", roll_ratio,
                 f"rolling per-call ratio across {hi // lo}x rows "
                 f"(stop-the-world ratio {stw_ratio:.2f})",
                 rolling_ratio=round(roll_ratio, 3),
                 stw_ratio=round(stw_ratio, 3),
                 rows_factor=hi // lo, decay_block_rows=block)
    REC.write("decay")


def bench_hash_vs_scan():
    """B5: dst lookup via per-row hash table vs C-lane slab scan."""
    n = 512 if SMOKE else 1024
    for use_hash, label in ((False, "scan"), (True, "hash")):
        cfg = mc.MCConfig(num_rows=n, capacity=64, sort_passes=1,
                          use_dst_hash=use_hash)
        graph = MarkovGraphSampler(num_nodes=n, out_degree=48, seed=4)
        state = mc.init(cfg)
        for _ in range(4):
            s, d = graph.sample_transitions(n)
            state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                    cfg=cfg)
        s, d = graph.sample_transitions(n)
        s, d = jnp.asarray(s), jnp.asarray(d)
        us = _time(lambda: mc.update_batch(state, s, d, cfg=cfg), n=5)
        REC.emit("hash_vs_scan", f"B5_dst_lookup[{label}]", us,
                 f"update batch {n}", lookup=label)
    REC.write("hash_vs_scan")


def bench_drafter():
    """B6: n-gram drafter acceptance on a structured stream."""
    ncfg = spec.NGramConfig(order=2, mc=mc.MCConfig(num_rows=4096,
                                                    capacity=32,
                                                    sort_passes=1))
    st = spec.init(ncfg)
    rng = np.random.default_rng(5)
    # 80% deterministic successor process
    succ = rng.integers(0, 512, (512,)).astype(np.int32)
    toks = np.empty((8, 512), np.int32)
    toks[:, 0] = rng.integers(0, 512, 8)
    for t in range(1, 512):
        follow = succ[toks[:, t - 1]]
        noise = rng.integers(0, 512, 8)
        toks[:, t] = np.where(rng.random(8) < 0.8, follow, noise)
    toks_j = jnp.asarray(toks)
    st = spec.observe(st, toks_j, cfg=ncfg)   # learn once (and compile)
    # steady-state observe cost, same warmup+median contract as every other
    # recorder (one-shot timing was jit-compile-dominated and run-to-run
    # noise published false regressions in the committed JSON)
    us = _time(lambda: spec.observe(st, toks_j, cfg=ncfg), n=5)
    # drafts where the chain knows the successor
    ctx = jnp.asarray(toks[:, 100:102])
    draft, ok = spec.draft(st, ctx, cfg=ncfg, k=1)
    okm = np.asarray(ok)[:, 0]
    want = succ[np.asarray(ctx)[:, -1]]
    acc = float(np.mean((np.asarray(draft)[:, 0] == want)[okm])) if okm.any() else 0.0
    REC.emit("drafter", "B6_drafter", us,
             f"top-1 draft matches true successor {acc:.0%} of ok-drafts",
             acceptance=round(acc, 4))

    # us_per_draft: the one-dispatch walk kernel (DESIGN.md §8) vs the
    # k-dispatch scan oracle, per draft() call at serving batch size
    k = 4
    ctx_b = jnp.asarray(toks[:, 200:202])
    us_walk, us_scan = _time_paired(
        [lambda: spec.draft(st, ctx_b, cfg=ncfg, k=k),
         lambda: spec.draft_reference(st, ctx_b, cfg=ncfg, k=k)], n=20)
    for name, us_d in (("walk", us_walk), ("scan", us_scan)):
        REC.emit("drafter", f"B6_draft_us[{name}]", us_d,
                 f"k={k} draft per call ({name} path)",
                 us_per_draft=round(us_d, 3), k=k,
                 batch=int(ctx_b.shape[0]), path=name)
    REC.write("drafter")


def bench_sharded_routing():
    """B7: shard-count × batch sweep of the kernel-routed all_to_all path.

    One subprocess per shard count (the fake host device count is fixed at
    first jax init), each sweeping batch sizes: per row the routed-update
    latency (edges/s), the routed threshold-query latency, the drop counters
    — the fixed-capacity approximation must be *measurably* zero at the
    default bucket factor — plus one cross-shard top-n merge timing per
    shard count (``B7_topn``).  Written to ``BENCH_sharded_routing.json``.
    """
    import subprocess
    import textwrap
    shard_counts = (1, 4) if SMOKE else (1, 4, 8)
    batches = (512, 2048) if SMOKE else (2048, 8192)
    rows = 512 if SMOKE else 2048
    iters = 3 if SMOKE else 5
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    for shards in shard_counts:
        script = textwrap.dedent(f"""
            import json, os, time
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count={shards}")
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.core import mcprioq as mc, sharded as sh

            def timeit(fn, n):
                jax.block_until_ready(fn())
                t0 = time.perf_counter()
                for _ in range(n):
                    out = fn()
                jax.block_until_ready(out)
                return (time.perf_counter() - t0) / n * 1e6

            mesh = compat.make_mesh(({shards},), ("shard",))
            scfg = sh.ShardedConfig(
                base=mc.MCConfig(num_rows={rows}, capacity=32, sort_passes=1),
                num_shards={shards}, bucket_factor=2.0)
            rng = np.random.default_rng(0)
            for batch in {batches}:
                state = sh.init_sharded(scfg, mesh)
                upd = sh.make_update_fn(scfg, mesh)
                qry = sh.make_query_fn(scfg, mesh, threshold=0.9,
                                       max_items=8)
                src = jnp.asarray(
                    rng.integers(0, 8192, batch).astype(np.int32))
                dst = jnp.asarray(
                    rng.integers(0, 512, batch).astype(np.int32))
                w = jnp.ones((batch,), jnp.int32)
                state = upd(state, src, dst, w)   # warm + compile
                us = timeit(lambda: upd(state, src, dst, w), {iters})
                q_us = timeit(lambda: qry(state, src), {iters})
                _, _, _, qdrop = qry(state, src)
                print("ROW " + json.dumps({{
                    "name": f"B7_shard_sweep[shards={shards};B={{batch}}]",
                    "us": us,
                    "derived": f"{{batch / (us / 1e6):.0f}} edges/s over "
                               f"{shards} shards (query {{q_us:.0f}} us)",
                    "shards": {shards}, "batch": batch,
                    "edges_per_s": round(batch / (us / 1e6)),
                    "query_us": round(q_us, 1),
                    "dropped": int(jnp.sum(state.route_dropped))
                    + int(jnp.sum(qdrop)),
                }}))
            topn = sh.make_topn_fn(scfg, mesh, 16)
            t_us = timeit(lambda: topn(state), {iters})
            _, _, probs, tdrop = topn(state)
            desc = bool(np.all(np.diff(np.asarray(probs)) <= 0))
            print("ROW " + json.dumps({{
                "name": f"B7_topn[shards={shards}]",
                "us": t_us,
                "derived": f"global top-16 merge, descending={{desc}} "
                           f"(unexposed={{int(tdrop)}})",
                "shards": {shards}, "n": 16,
            }}))
        """)
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, timeout=900)
        rows_out = [ln[4:] for ln in out.stdout.splitlines()
                    if ln.startswith("ROW ")]
        if not rows_out:  # keep the grep-able FAILED sentinel in CSV + JSON
            REC.emit("sharded_routing", f"B7_shard_sweep[shards={shards};B=0]",
                     -1.0, f"FAILED {out.stderr[-200:]}", failed=True,
                     shards=shards, batch=0, edges_per_s=-1, dropped=-1)
            continue
        for ln in rows_out:
            row = json.loads(ln)
            us = row.pop("us")
            REC.emit("sharded_routing", row.pop("name"), us,
                     row.pop("derived"), **row)
    REC.write("sharded_routing")


def bench_persist():
    """B8: durability & elasticity (DESIGN.md §10).

    Three recorders: snapshot save/restore latency at chain scale, WAL
    append cost per fsync policy plus full-replay throughput (recovery
    speed), and the N -> M elastic reshard — snapshot at 4 shards, restore
    at 2 and 8, recording re-ingestion edges/s (subprocess with 8 fake
    devices, same pattern as B7).
    """
    import shutil
    import subprocess
    import tempfile
    import textwrap
    from repro.persist import snapshot as snap_io
    from repro.persist.wal import WriteAheadLog

    rows = 512 if SMOKE else 4096
    batch = 256 if SMOKE else 1024
    n_batches = 6 if SMOKE else 20
    cfg = mc.MCConfig(num_rows=rows, capacity=64, sort_passes=1)
    graph = MarkovGraphSampler(num_nodes=rows, out_degree=32, seed=7)
    state = mc.init(cfg)
    batches = []
    for _ in range(n_batches):
        s, d = graph.sample_transitions(batch)
        batches.append((s.astype(np.int32), d.astype(np.int32)))
        state = mc.update_batch(state, jnp.asarray(s), jnp.asarray(d),
                                cfg=cfg)
    live = int(jnp.sum(state.slabs.cnt > 0))

    snap_dir = tempfile.mkdtemp()
    meta = {"wal_seq": n_batches - 1}
    us_save = _time(lambda: snap_io.save_snapshot(state, snap_dir, 0, meta),
                    n=5)
    like = mc.init(cfg)   # template built once: time the restore alone
    us_restore = _time(
        lambda: snap_io.restore_snapshot(like, snap_dir, 0), n=5)
    REC.emit("persist", f"B8_snapshot[rows={rows}]", us_save,
             f"{live} live edges (restore {us_restore:.0f} us)",
             num_rows=rows, live_edges=live,
             restore_us=round(us_restore, 1))
    shutil.rmtree(snap_dir)

    for fsync in ("always", "rotate", "never"):
        wal_dir = tempfile.mkdtemp()
        wal = WriteAheadLog(wal_dir, segment_records=64, fsync=fsync)
        t0 = time.perf_counter()
        for s, d in batches:
            wal.append(s, d)
        wal.close()
        us_append = (time.perf_counter() - t0) / n_batches * 1e6
        # recovery speed: replay every durable batch through update_batch
        replayed = mc.init(cfg)
        n_edges = 0
        t0 = time.perf_counter()
        for _seq, s, d, w in WriteAheadLog(wal_dir).replay():
            replayed = mc.update_batch(replayed, jnp.asarray(s),
                                       jnp.asarray(d), jnp.asarray(w),
                                       cfg=cfg)
            n_edges += s.size
        jax.block_until_ready(replayed.slabs.cnt)
        eps = n_edges / (time.perf_counter() - t0)
        REC.emit("persist", f"B8_wal[fsync={fsync}]", us_append,
                 f"append/batch; replay {eps:.0f} edges/s",
                 fsync=fsync, batches=n_batches,
                 replay_edges_per_s=round(eps))
        shutil.rmtree(wal_dir)

    # N -> M elastic reshard (fake-device subprocess; see B7)
    rows_sub = 256 if SMOKE else 1024
    warm = 4 if SMOKE else 12
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    script = textwrap.dedent(f"""
        import json, os, tempfile, time
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8")
        import numpy as np
        from repro.core import mcprioq as mc, sharded as sh
        from repro.data.synthetic import MarkovGraphSampler
        from repro.serve.engine import ShardedEngine, ShardedServeConfig

        snap_dir = tempfile.mkdtemp()
        base = mc.MCConfig(num_rows={rows_sub}, capacity=32, sort_passes=1)

        def eng(n):
            return ShardedEngine(ShardedServeConfig(
                sharded=sh.ShardedConfig(base=base, num_shards=n,
                                         bucket_factor=2.0),
                decay_threshold=1 << 30, snapshot_dir=snap_dir))

        g = MarkovGraphSampler(num_nodes={rows_sub}, out_degree=16, seed=0)
        e4 = eng(4)
        for _ in range({warm}):
            s, d = g.sample_transitions({batch})
            e4.observe(s, d)
        e4.checkpoint()
        snap = e4.store.acquire()
        try:
            edges = int(np.sum(np.asarray(snap.state.slabs.cnt) > 0))
        finally:
            e4.store.release(snap)
        for m in (2, 8):
            em = eng(m)
            t0 = time.perf_counter()
            info = em.restore()
            dt = time.perf_counter() - t0
            print("ROW " + json.dumps({{
                "name": f"B8_reshard[N=4;M={{m}}]",
                "us": dt * 1e6,
                "derived": f"{{edges / dt:.0f}} edges/s re-ingested "
                           f"(mode={{info['mode']}})",
                "from_shards": 4, "to_shards": m, "edges": edges,
                "edges_per_s": round(edges / dt),
            }}))
    """)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    rows_out = [ln[4:] for ln in out.stdout.splitlines()
                if ln.startswith("ROW ")]
    if not rows_out:  # keep the grep-able FAILED sentinel in CSV + JSON
        REC.emit("persist", "B8_reshard[N=4;M=0]", -1.0,
                 f"FAILED {out.stderr[-200:]}", failed=True, from_shards=4,
                 to_shards=0, edges=-1, edges_per_s=-1)
    for ln in rows_out:
        row = json.loads(ln)
        us = row.pop("us")
        REC.emit("persist", row.pop("name"), us, row.pop("derived"), **row)
    REC.write("persist")


def bench_faults():
    """B9: crash soak — kill/recover/verify loop from tools/chaos/soak.py
    (external SIGKILLs interleaved with kills armed inside the persistence
    failpoints), re-emitted through the recorder so the rows land in the
    shared CSV + ``BENCH_faults.json`` schema."""
    if REPO_ROOT not in sys.path:  # tools/ lives at the repo root
        sys.path.insert(0, REPO_ROOT)
    from tools.chaos.soak import run_soak
    result = run_soak(6 if SMOKE else 20)
    for row in result["rows"]:
        extra = {k: v for k, v in row.items()
                 if k not in ("name", "us_per_call", "derived")}
        REC.emit("faults", row["name"], row["us_per_call"], row["derived"],
                 **extra)
    REC.write("faults")
    if not result["ok"]:
        print("B9_crash_soak: DIVERGED (see rows)", file=sys.stderr)


def bench_obs():
    """B10: telemetry overhead (DESIGN.md §13).

    Armed-vs-disarmed A/B on the serving hot paths (``_time_paired`` so
    drift hits both arms equally): the armed delta buys spans, histograms
    and traffic vectors; the disarmed path must cost one bool gate.  The
    disarmed gate is also timed in isolation (a tight span+hist loop) so
    the "disarmed is ~free" claim is a measured number, not an inference
    from two large nearly-equal latencies.
    """
    from repro.core import sharded as sh
    from repro.obs import metrics as obs
    from repro.serve.engine import ShardedEngine, ShardedServeConfig

    rows = 512 if SMOKE else 2048
    batch = 256 if SMOKE else 1024
    scfg = sh.ShardedConfig(
        base=mc.MCConfig(num_rows=rows, capacity=32, sort_passes=1),
        num_shards=1, bucket_factor=2.0)
    eng = ShardedEngine(ShardedServeConfig(sharded=scfg,
                                           decay_threshold=1 << 30))
    graph = MarkovGraphSampler(num_nodes=rows, out_degree=16, seed=11)
    s, d = graph.sample_transitions(batch)
    q = (np.arange(256, dtype=np.int32) % rows).astype(np.int32)
    eng.observe(s, d)   # compile both paths before timing
    eng.query(q)

    def observe_with(armed):
        def fn():
            (obs.arm if armed else obs.disarm)()
            eng.observe(s, d)
        return fn

    def query_with(armed):
        def fn():
            (obs.arm if armed else obs.disarm)()
            return eng.query(q)
        return fn

    n = 10 if SMOKE else 40
    try:
        for path, maker in (("observe", observe_with), ("query", query_with)):
            us_dis, us_arm = _time_paired([maker(False), maker(True)], n=n)
            pct = (us_arm - us_dis) / us_dis * 100.0
            REC.emit("obs", f"B10_{path}", us_arm,
                     f"armed {us_arm:.0f} us vs disarmed {us_dis:.0f} us "
                     f"({pct:+.1f}%)",
                     us_armed=round(us_arm, 3), us_disarmed=round(us_dis, 3),
                     overhead_pct=round(pct, 2), batch=batch)

        # the disarmed gate in isolation: per-record cost of a span + a
        # histogram sample while disarmed (both exit on the module bool)
        obs.disarm()
        reg = eng.metrics
        loops = 2000

        def gate():
            for _ in range(loops):
                reg.span("engine.observe")
                reg.hist_record("engine.observe", 0.0)

        us_loop = _time(gate, n=5)
        ns_per_record = us_loop * 1e3 / (2 * loops)
        # an observe() crosses the gate a handful of times (span, traffic
        # check, gauge); express that against the disarmed hot-path cost
        ops_per_observe = 4
        us_dis_obs = _time_paired([observe_with(False)], n=n)[0]
        gate_pct = (ops_per_observe * ns_per_record / 1e3) / us_dis_obs * 100
        REC.emit("obs", "B10_disarmed_gate", us_loop,
                 f"{ns_per_record:.0f} ns/record disarmed -> "
                 f"{gate_pct:.4f}% of a disarmed observe()",
                 ns_per_record=round(ns_per_record, 2),
                 overhead_pct=round(gate_pct, 4))
    finally:
        obs.disarm()
    REC.write("obs")


# ---------------------------------------------------------------------------
# schema validation (CI: BENCH_*.json must stay generatable + well-formed)
# ---------------------------------------------------------------------------

REQUIRED_ROW_KEYS = ("name", "us_per_call", "derived")

# per-bench schema: rows whose name starts with <prefix> must carry these
# extra keys, and each bench must contain at least one row per prefix — so a
# stale pre-sweep BENCH file fails --validate instead of passing vacuously
BENCH_ROW_SCHEMAS = {
    "query_cdf": {
        "B2_query_cdf": ("zipf_s", "threshold", "mean_items"),
        "B2_fused_sweep": ("batch", "fused", "speedup_fused"),
        "B2_fused_check": ("geomean_speedup",),
        "B2_chunk_sweep": ("threshold", "chunks", "capacity", "mean_items"),
    },
    "drafter": {
        "B6_drafter": ("acceptance",),
        "B6_draft_us": ("us_per_draft", "k", "path"),
    },
    "sharded_routing": {
        "B7_shard_sweep": ("shards", "batch", "edges_per_s", "dropped"),
        "B7_topn": ("shards", "n"),
    },
    "persist": {
        "B8_snapshot": ("num_rows", "live_edges", "restore_us"),
        "B8_wal": ("fsync", "batches", "replay_edges_per_s"),
        "B8_reshard": ("from_shards", "to_shards", "edges", "edges_per_s"),
    },
    "faults": {
        "B9_crash_soak": ("kill_mode", "steps", "replayed", "bitexact"),
        "B9_recovery_summary": ("kills", "mean_recovery_us",
                                "max_recovery_us", "bitexact"),
    },
    "obs": {
        "B10_observe": ("us_armed", "us_disarmed", "overhead_pct"),
        "B10_query": ("us_armed", "us_disarmed", "overhead_pct"),
        "B10_disarmed_gate": ("ns_per_record", "overhead_pct"),
    },
}


def validate_bench_files() -> int:
    """Check every BENCH_*.json against the Recorder schema (and the
    per-bench row schemas in ``BENCH_ROW_SCHEMAS``).

    Returns the number of problems found (0 = all good); prints one line per
    problem so CI logs point at the stale file directly.
    """
    problems = []
    paths = sorted(glob.glob(os.path.join(_HERE, "BENCH_*.json")))
    if not paths:
        problems.append("no BENCH_*.json files found (run benchmarks first)")
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{name}: unreadable ({e})")
            continue
        if not isinstance(data.get("bench"), str) or not isinstance(
                data.get("rows"), list):
            problems.append(f"{name}: missing 'bench'/'rows' envelope")
            continue
        if not data["rows"]:
            problems.append(f"{name}: empty rows")
            continue
        for i, row in enumerate(data["rows"]):
            missing = [k for k in REQUIRED_ROW_KEYS if k not in row]
            if missing:
                problems.append(
                    f"{name}: row {i} ({row.get('name', '?')}) "
                    f"missing {missing}")
        row_schemas = BENCH_ROW_SCHEMAS.get(data["bench"], {})
        for prefix, extra_keys in row_schemas.items():
            rows = [r for r in data["rows"]
                    if str(r.get("name", "")).startswith(prefix)]
            if not rows:
                problems.append(f"{name}: no '{prefix}*' rows (stale file — "
                                f"re-run benchmarks)")
                continue
            for row in rows:
                missing = [k for k in extra_keys if k not in row]
                if missing:
                    problems.append(f"{name}: row {row['name']} missing "
                                    f"{missing}")
    for p in problems:
        print(f"SCHEMA: {p}")
    if not problems:
        print(f"validated {len(paths)} BENCH_*.json files")
    return len(problems)


BENCHES = (
    ("update", bench_update_throughput),
    ("query_cdf", bench_query_cdf),
    ("sortedness", bench_sortedness),
    ("decay", bench_decay),
    ("hash_vs_scan", bench_hash_vs_scan),
    ("drafter", bench_drafter),
    ("sharded_routing", bench_sharded_routing),
    ("persist", bench_persist),
    ("faults", bench_faults),
    ("obs", bench_obs),
)


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale sizes; same recorders and JSON schema")
    ap.add_argument("--validate", action="store_true",
                    help="only validate existing BENCH_*.json schemas")
    ap.add_argument("--only", default="",
                    help="comma-separated bench-name substrings to run "
                         "(e.g. --only sharded_routing); default all")
    args = ap.parse_args()
    if args.validate:
        sys.exit(1 if validate_bench_files() else 0)
    SMOKE = args.smoke
    picks = [s.strip() for s in args.only.split(",") if s.strip()]
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if not picks or any(p in name for p in picks):
            fn()


if __name__ == "__main__":
    main()
