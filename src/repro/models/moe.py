"""Mixture-of-Experts block: shared + fine-grained routed experts (top-k).

DeepSeekMoE / Moonlight style: ``num_shared_experts`` always-on experts (fused
into one wider MLP — mathematically identical for gated MLPs) plus
``num_experts`` routed experts with top-k gating.

Dispatch is **sort-based with fixed capacity** (MaxText "dropping" strategy):
argsort tokens by expert id, gather into an (E, C, D) tile, grouped einsum,
weighted scatter-combine.  No one-hot dispatch einsum — HLO FLOPs stay equal
to useful expert FLOPs, which keeps §Roofline's MODEL_FLOPS/HLO_FLOPs honest.
Expert-parallel: the E dim of the expert tiles shards over the ``model`` mesh
axis (64 experts / 16 = 4 per shard); XLA inserts the dispatch/combine
all-to-alls from the sharding constraints.

The router's expert-choice histogram is also an MCPrioQ customer: the serving
engine tracks online expert popularity with the paper's structure
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init, pdtype_of
from repro.models.mlp import apply_mlp, make_mlp
from repro.sharding.specs import BATCH, DATA, MODEL, constrain


def make_moe(cfg: ModelConfig, key) -> Dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 5)
    out_scale = 1.0 / math.sqrt(f * 2 * cfg.num_layers)
    p = {
        "router": dense_init(ks[0], (d, e), pd, scale=0.02),
        "we1": dense_init(ks[1], (e, d, f), pd),
        "we2": dense_init(ks[2], (e, f, d), pd, scale=out_scale),
    }
    if cfg.gated_mlp:
        p["weg"] = dense_init(ks[3], (e, d, f), pd)
    if cfg.num_shared_experts:
        shared_cfg = cfg  # same act/gating; width = n_shared * expert width
        p["shared"] = make_mlp(shared_cfg, ks[4],
                               d_ff=cfg.num_shared_experts * f)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    fair = tokens * cfg.experts_per_token / cfg.num_experts
    cap = int(math.ceil(fair * cfg.capacity_factor / 128.0)) * 128
    return max(cap, 128)


def apply_moe_ep(p: Dict, x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expert-parallel MoE with explicit all_to_all dispatch (shard_map).

    §Perf hillclimb variant: the dense-pjit path's scatter-add combine
    compiles to TB-scale dense all-reduces; here tokens are *routed* to the
    expert-owning shards with the same fixed-capacity bucket + all_to_all
    pattern as the paper's node-sharded MCPrioQ (core/sharded.py), computed
    locally, and routed back — collective volume is O(tokens·D) instead of
    O(tokens·D·model_axis).  Exact same math as apply_moe up to capacity
    drop boundaries.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.sharded import _build_buckets
    from repro.sharding.specs import batch_axes, current_mesh

    mesh = current_mesh()
    assert mesh is not None, "apply_moe_ep needs an active mesh"
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m_ax = sizes.get("model", 1)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // m_ax
    dpa = tuple(a for a in batch_axes(mesh) if a != "model")
    dp_size = 1
    for a in dpa:
        dp_size *= sizes[a]
    bspec = dpa if (b % dp_size == 0 and dp_size > 1) else None
    sspec = "model" if s % m_ax == 0 else None
    x_spec = P(bspec, sspec, None)
    t_loc = (b // (dp_size if bspec else 1)) * (s // (m_ax if sspec else 1))
    cap = max(64, int(math.ceil(cfg.capacity_factor * t_loc * k / m_ax
                                / 8.0)) * 8)
    cap2 = max(64, int(math.ceil(cfg.capacity_factor * m_ax * cap / e_loc
                                 / 8.0)) * 8)

    def local_fn(xc, router_w, we1, weg, we2):
        bl, sl, _ = xc.shape
        n = bl * sl
        xt = xc.reshape(n, d)
        logits = jnp.einsum("td,de->te", xt,
                            router_w.astype(xc.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        flat_e = top_e.reshape(-1).astype(jnp.int32)
        flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
        flat_p = top_p.reshape(-1)
        owner = flat_e // e_loc
        (b_t, b_e, b_p), pair_pos, dropped = _build_buckets(
            [flat_t, flat_e, flat_p.astype(jnp.float32)], owner, m_ax, cap)
        send_x = xt[jnp.clip(b_t, 0, n - 1)] * (b_t >= 0)[..., None]
        # --- route to expert owners ------------------------------------
        recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(b_e, "model", 0, 0, tiled=True)
        shard = jax.lax.axis_index("model")
        local_e = jnp.where(recv_e >= 0, recv_e - shard * e_loc, -1)
        # --- group by local expert and run the grouped MLP -------------
        rx = recv_x.reshape(-1, d)
        re = local_e.reshape(-1)
        (g_i,), g_pos, dropped2 = _build_buckets(
            [jnp.arange(rx.shape[0], dtype=jnp.int32)],
            jnp.where(re >= 0, re, e_loc), e_loc + 1, cap2)
        g_i = g_i[:e_loc]                                   # drop junk row
        xe = rx[jnp.clip(g_i, 0, rx.shape[0] - 1)] * (g_i >= 0)[..., None]
        act = activation(cfg.act)
        hh = jnp.einsum("ecd,edf->ecf", xe, we1.astype(xc.dtype))
        if cfg.gated_mlp:
            gg = jnp.einsum("ecd,edf->ecf", xe, weg.astype(xc.dtype))
            hh = act(gg) * hh
        else:
            hh = act(hh)
        ye = jnp.einsum("ecf,efd->ecd", hh, we2.astype(xc.dtype))
        # scatter grouped outputs back to recv-slot order (local)
        back = jnp.zeros((rx.shape[0], d), ye.dtype)
        ok_g = (g_i >= 0)
        back = back.at[jnp.clip(g_i, 0, rx.shape[0] - 1).reshape(-1)].add(
            (ye * ok_g[..., None]).reshape(-1, d))
        back = back.reshape(m_ax, cap, d)
        # --- route results home + weighted combine (all local) ---------
        home = jax.lax.all_to_all(back, "model", 0, 0, tiled=True)
        ok_pair = pair_pos < cap
        gi = jnp.clip(pair_pos, 0, cap - 1)
        vals = home[owner, gi]                              # [n*k, d]
        wgt = jnp.where(ok_pair, flat_p, 0.0).astype(ye.dtype)
        out = jnp.sum((vals * wgt[:, None]).reshape(n, k, d), axis=1)
        # --- aux (reduced over every sharded axis) ----------------------
        red = (dpa + ("model",)) if bspec else ("model",)
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (n * k)
        lb = e * jnp.sum(jax.lax.pmean(me, red) * jax.lax.pmean(ce, red))
        z = jax.lax.pmean(
            jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))), red)
        drop_tot = jax.lax.psum(dropped + dropped2, red)
        cnt = jax.lax.psum(
            jnp.zeros((e,), jnp.int32).at[flat_e].add(1), red)
        return out.reshape(bl, sl, d).astype(xc.dtype), lb, z, drop_tot, cnt

    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(x_spec, P(), P(), P(), P()))
    weg = p.get("weg", p["we1"])  # placeholder when ungated (unused)
    out, lb, z, drop_tot, cnt = fn(x, p["router"], p["we1"], weg, p["we2"])
    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg)
    aux = {"moe_lb_loss": lb, "moe_z_loss": z, "moe_dropped": drop_tot,
           "moe_expert_counts": cnt}
    return out, aux


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D] -> (out [B, S, D], aux metrics incl. load-balance loss)."""
    if cfg.moe_impl == "ep":
        from repro.sharding.specs import current_mesh
        if current_mesh() is not None:
            return apply_moe_ep(p, x, cfg)
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n = b * s
    xt = x.reshape(n, d)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # [n, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalise

    # ---- sort-based dispatch with fixed capacity ----------------------
    cap = _capacity(n, cfg)
    flat_e = top_e.reshape(-1)                                  # [n*k]
    flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)      # token ids
    flat_p = top_p.reshape(-1)
    sort_idx = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[sort_idx], flat_t[sort_idx], flat_p[sort_idx]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[se]       # slot in expert
    keep = pos < cap

    # gather tokens into expert tiles [E, C, D] (dropped slots read token 0
    # and are zero-masked)
    tok_at = jnp.zeros((e, cap), jnp.int32).at[se, pos].set(
        st, mode="drop")
    gate_at = jnp.zeros((e, cap), jnp.float32).at[se, pos].set(
        jnp.where(keep, sp, 0.0), mode="drop")
    xe = xt[tok_at]                                             # [E, C, D]
    xe = xe * (gate_at[..., None] > 0)
    # EP over experts AND capacity over the data axis: the (E, C, D) dispatch
    # buffer never exists unsharded (2 GB/chip otherwise at 1M tokens)
    xe = constrain(xe, MODEL, DATA, None)

    # ---- grouped expert MLP -------------------------------------------
    act = activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", xe, p["we1"].astype(x.dtype))
    if cfg.gated_mlp:
        g = jnp.einsum("ecd,edf->ecf", xe, p["weg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, MODEL, DATA, None)
    ye = jnp.einsum("ecf,efd->ecd", h, p["we2"].astype(x.dtype))

    # ---- weighted combine back to tokens ------------------------------
    if cfg.moe_combine == "gather":
        # invert the sort: each (token, k) pair gathers its expert output —
        # no scatter-add, so SPMD reshards only the gathered values instead
        # of all-reducing a dense (n, d) buffer (§Perf hillclimb variant)
        pos_u = jnp.zeros((n * k,), jnp.int32).at[sort_idx].set(pos)
        keep_u = pos_u < cap
        slot = jnp.clip(pos_u, 0, cap - 1)
        vals = ye[flat_e, slot]                         # [n*k, d]
        wgt = jnp.where(keep_u, flat_p, 0.0).astype(ye.dtype)
        out = jnp.sum((vals * wgt[:, None]).reshape(n, k, d), axis=1)
    else:
        yw = ye * gate_at[..., None].astype(ye.dtype)
        out = jnp.zeros((n, d), ye.dtype).at[tok_at.reshape(-1)].add(
            yw.reshape(-1, d))
    out = constrain(out, BATCH, None)

    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg).reshape(n, d)

    # ---- aux: load balance + router z-loss ----------------------------
    me = jnp.mean(probs, axis=0)                                # [e]
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (n * k)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = jnp.sum((~keep).astype(jnp.int32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_dropped": dropped,
           "moe_expert_counts": jnp.zeros((e,), jnp.int32).at[flat_e].add(1)}
    return out.reshape(b, s, d).astype(x.dtype), aux
