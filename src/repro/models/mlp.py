"""Dense MLP: gated (SwiGLU/GeGLU) or plain 4x (GELU) variants."""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init, pdtype_of
from repro.sharding.specs import BATCH, MODEL, constrain


def make_mlp(cfg: ModelConfig, key, d_ff: int = 0) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(f * 2 * cfg.num_layers)
    p = {
        "w1": dense_init(ks[0], (d, f), pd),
        "w2": dense_init(ks[1], (f, d), pd, scale=out_scale),
    }
    if cfg.gated_mlp:
        p["wg"] = dense_init(ks[2], (d, f), pd)
    return p


def apply_mlp(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = activation(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype))
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, BATCH, None, MODEL)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(x.dtype))
