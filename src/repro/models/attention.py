"""GQA attention: chunked flash-style forward, KV caches, RoPE, local window.

Pure-XLA (jnp + lax.scan) by design: dense matmul attention is already
MXU-optimal under XLA fusion, and keeping it out of Pallas keeps
``compiled.cost_analysis()`` FLOPs faithful for §Roofline (DESIGN.md §4).

Three entry points:
  * ``attend``       — full-sequence forward (train / prefill), online-softmax
                       scan over KV chunks so the (S, T) score matrix never
                       materialises beyond a chunk.
  * ``decode_attend`` — single-token decode against a preallocated cache.
  * caches           — ``init_cache`` (linear, global attention) and
                       ``init_ring_cache`` (fixed window W, O(W) memory for
                       500k-token contexts).
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.common import dense_init, pdtype_of
from repro.sharding.specs import BATCH, MODEL, constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def make_attention(cfg: ModelConfig, key) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), pd),
        "wk": dense_init(ks[1], (d, kv, hd), pd),
        "wv": dense_init(ks[2], (d, kv, hd), pd),
        "wo": dense_init(ks[3], (h, hd, d), pd,
                         scale=1.0 / math.sqrt(h * hd * 2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), pd)
        p["bk"] = jnp.zeros((kv, hd), pd)
        p["bv"] = jnp.zeros((kv, hd), pd)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh], positions: [B, S] (absolute)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------


def project_qkv(p: Dict, x: jax.Array, cfg: ModelConfig,
                positions: Optional[jax.Array]) -> Tuple[jax.Array, ...]:
    """x: [B, S, D] -> q [B,S,H,Dh], k,v [B,S,KV,Dh] (roped if configured)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dke->bske", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q, BATCH, None, MODEL, None)
    k = constrain(k, BATCH, None, MODEL, None)
    v = constrain(v, BATCH, None, MODEL, None)
    if cfg.use_rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def project_out(p: Dict, o: jax.Array, x_dtype) -> jax.Array:
    """o: [B, S, H, Dh] -> [B, S, D]."""
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x_dtype))


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------


def _flash_stats(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: int,
                 q_positions: jax.Array, kv_positions: jax.Array,
                 kv_valid_len: Optional[jax.Array], kv_chunk: int):
    """Online-softmax statistics (m, lsum, acc) — acc is the un-normalised
    numerator, so partial results combine exactly across KV shards
    (sequence-parallel attention)."""
    b, sq, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, sq, kvh, g, hd) * scale
    if sq == 1:
        # single-token decode: the (Sq, ck) score tile is tiny regardless of
        # chunking, and the reshape/swapaxes below would COPY the whole KV
        # cache every step (2x decode HBM traffic, §Perf) — use one chunk
        kv_chunk = t
    n_chunks = max(1, t // kv_chunk)
    assert t % n_chunks == 0, (t, kv_chunk)
    ck = kv_chunk if t >= kv_chunk else t

    if n_chunks == 1:
        ks = k[None]
        vs = v[None]
        ps = kv_positions[None]
    else:
        ks = k.reshape(b, n_chunks, ck, kvh, hd).swapaxes(0, 1)
        vs = v.reshape(b, n_chunks, ck, kvh, hd).swapaxes(0, 1)
        ps = kv_positions.reshape(b, n_chunks, ck).swapaxes(0, 1)

    def step(carry, inp):
        m, lsum, acc = carry
        kc, vc, pc = inp  # [B, ck, KV, Dh], [B, ck]
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kc).astype(jnp.float32)
        mask = jnp.ones((b, sq, ck), bool)
        if causal:
            mask &= pc[:, None, :] <= q_positions[:, :, None]
        if window > 0:
            mask &= pc[:, None, :] > q_positions[:, :, None] - window
        if kv_valid_len is not None:
            mask &= pc < kv_valid_len[:, None]
        mask &= pc[:, None, :] >= 0
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lsum = lsum * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, lsum, acc), None

    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    if n_chunks == 1:
        (m, lsum, acc), _ = step((m0, l0, a0), (ks[0], vs[0], ps[0]))
    else:
        (m, lsum, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, ps))
    return m, lsum, acc


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           causal: bool = True, window: int = 0,
           q_positions: Optional[jax.Array] = None,
           kv_positions: Optional[jax.Array] = None,
           kv_valid_len: Optional[jax.Array] = None,
           kv_chunk: int = 1024) -> jax.Array:
    """Memory-efficient attention.

    q: [B, Sq, H, Dh]; k, v: [B, T, KV, Dh]; H = KV * G.
    q_positions/kv_positions: absolute positions [B, Sq] / [B, T] (default
    aranges).  window > 0 masks kv_pos <= q_pos - window (sliding window).
    kv_valid_len: [B] — cache fill level for decode.
    Returns [B, Sq, H, Dh].
    """
    b, sq, h, hd = q.shape
    t = k.shape[1]
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    m, lsum, acc = _flash_stats(
        q, k, v, causal=causal, window=window, q_positions=q_positions,
        kv_positions=kv_positions, kv_valid_len=kv_valid_len,
        kv_chunk=kv_chunk)
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array          # [B, T, KV, Dh]
    v: jax.Array          # [B, T, KV, Dh]
    positions: jax.Array  # [B, T] absolute positions held per slot (-1 empty)
    ring: bool            # static-ish flag array (bool[]) — ring vs linear


def init_cache(b: int, t: int, kvh: int, hd: int, dtype,
               ring: bool = False) -> KVCache:
    return KVCache(
        k=jnp.zeros((b, t, kvh, hd), dtype),
        v=jnp.zeros((b, t, kvh, hd), dtype),
        positions=jnp.full((b, t), -1, jnp.int32),
        ring=jnp.asarray(ring),
    )


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 positions: jax.Array) -> KVCache:
    """Write S new entries. positions: [B, S] absolute token positions.
    Linear cache: slot == position.  Ring cache: slot == position % W."""
    t = cache.k.shape[1]
    slots = jnp.where(cache.ring, positions % t, positions)
    b_idx = jnp.arange(k_new.shape[0])[:, None]
    k = cache.k.at[b_idx, slots].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[b_idx, slots].set(v_new.astype(cache.v.dtype))
    pos = cache.positions.at[b_idx, slots].set(positions)
    return KVCache(k, v, pos, cache.ring)


def decode_attend(q: jax.Array, cache: KVCache, *, window: int = 0,
                  q_positions: jax.Array, kv_chunk: int = 1024) -> jax.Array:
    """q: [B, 1, H, Dh] against the cache; positions make masking exact for
    both linear and ring layouts (empty slots carry position -1)."""
    return attend(
        q, cache.k, cache.v, causal=True, window=window,
        q_positions=q_positions, kv_positions=cache.positions,
        kv_chunk=min(kv_chunk, cache.k.shape[1]))


def sp_insert_attend(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     cache: KVCache, *, window: int = 0,
                     q_positions: jax.Array, mesh, kv_chunk: int = 1024
                     ) -> Tuple[jax.Array, KVCache]:
    """Sequence-parallel cache insert + decode attention (beyond-paper).

    The KV cache's seq dim is sharded over the ``model`` axis (the GQA
    kv_heads < model-axis case).  Both halves of the step stay local:

      * insert — only the shard owning slot ``pos % T`` (ring) / ``pos``
        writes; a plain pjit scatter onto a seq-sharded cache makes GSPMD
        all-gather the whole cache (the 30 GB/step + 50 GB peak observed on
        qwen2 decode_32k, §Perf).
      * attend — each shard runs flash over its local KV slice; the exact
        softmax is reassembled from (m, lsum, acc) partials with a psum: an
        O(B·H·Dh) collective instead of an O(B·T·KV·Dh) gather.
    """
    from jax.sharding import PartitionSpec as P

    b = q.shape[0]
    data_ax = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    m_ax = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    dp = "data" if (b % data_ax == 0 and data_ax > 1) else None
    t_global = cache.k.shape[1]
    t_loc = t_global // m_ax

    def local_fn(qc, knc, vnc, kc, vc, pc, ring, qp):
        shard = jax.lax.axis_index("model")
        offset = shard * t_loc
        # --- owner-local insert ---------------------------------------
        slots = jnp.where(ring, qp % t_global, qp)      # [B, S_new] global
        mine = (slots >= offset) & (slots < offset + t_loc)
        li = jnp.clip(slots - offset, 0, t_loc - 1)
        b_idx = jnp.arange(qc.shape[0])[:, None]
        kc = kc.at[b_idx, li].set(
            jnp.where(mine[..., None, None], knc.astype(kc.dtype),
                      kc[b_idx, li]))
        vc = vc.at[b_idx, li].set(
            jnp.where(mine[..., None, None], vnc.astype(vc.dtype),
                      vc[b_idx, li]))
        pc = pc.at[b_idx, li].set(jnp.where(mine, qp, pc[b_idx, li]))
        # --- local flash + exact LSE combine ---------------------------
        m, lsum, acc = _flash_stats(
            qc, kc, vc, causal=True, window=window, q_positions=qp,
            kv_positions=pc, kv_valid_len=None,
            kv_chunk=min(kv_chunk, kc.shape[1]))
        gm = jax.lax.pmax(m, "model")
        scale = jnp.exp(m - gm)
        denom = jax.lax.psum(lsum * scale, "model")
        num = jax.lax.psum(acc * scale[..., None], "model")
        out = num / jnp.maximum(denom, 1e-30)[..., None]
        bq, sq = qc.shape[:2]
        out = out.reshape(bq, sq, qc.shape[2], qc.shape[3]).astype(qc.dtype)
        return out, kc, vc, pc

    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, None, None, None),
                  P(dp, None, None, None), P(dp, "model", None, None),
                  P(dp, "model", None, None), P(dp, "model"), P(),
                  P(dp, None)),
        out_specs=(P(dp, None, None, None), P(dp, "model", None, None),
                   P(dp, "model", None, None), P(dp, "model")))
    out, k2, v2, p2 = fn(q, k_new, v_new, cache.k, cache.v, cache.positions,
                         cache.ring, q_positions)
    return out, KVCache(k2, v2, p2, cache.ring)
