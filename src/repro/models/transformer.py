"""Block assembly: pre-norm residual blocks + scan-over-periods stacking.

A config's ``pattern`` (e.g. ("rglru", "rglru", "attn")) defines the cycled
layer kinds.  Params/caches are stacked with a leading ``num_periods`` dim and
iterated with ``lax.scan`` — essential to keep HLO size and compile time
bounded for 88-layer models on a 512-device dry-run.  Pattern remainders and
``first_dense_layers`` are unrolled.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, dtype_of, make_norm
from repro.sharding.specs import BATCH, MODEL, constrain

PyTree = Any


def cast_stacked_params(stack: PyTree, cfg: ModelConfig) -> PyTree:
    """Cast stacked (leading layer-dim) >=3-D fp32 weights to compute dtype
    BEFORE the layer scan: the FSDP weight all-gather then moves bf16, not
    fp32 master weights (GSPMD won't sink a post-gather convert; §Perf
    iter 5).  Stacked 2-D leaves (norm scales per layer) stay fp32."""
    dt = dtype_of(cfg)

    def one(a):
        if a.ndim >= 3 and a.dtype == jnp.float32:
            return a.astype(dt)
        return a

    return jax.tree_util.tree_map(one, stack)


def _cast_block_params(p: PyTree, cfg: ModelConfig) -> PyTree:
    """Cast >=2-D fp32 weights to the compute dtype ONCE at block entry.

    Numerically identical to the per-einsum ``astype`` (which becomes a
    no-op), but crucial under FSDP: XLA does not sink a post-gather convert,
    so fp32 master weights were all-gathered in fp32 — casting the sharded
    weight first halves every weight-gather (granite train: 26 f32 gathers
    -> bf16, §Perf iter 5).  1-D params (norm scales, A_log, biases) stay
    fp32 for numerics.
    """
    dt = dtype_of(cfg)

    def one(a):
        if a.ndim >= 2 and a.dtype == jnp.float32:
            return a.astype(dt)
        return a

    return jax.tree_util.tree_map(one, p)


# ---------------------------------------------------------------------------
# single-block param construction
# ---------------------------------------------------------------------------


def make_block(cfg: ModelConfig, kind: str, key) -> PyTree:
    ks = jax.random.split(key, 4)
    p: Dict[str, PyTree] = {"norm1": make_norm(cfg)}
    if kind in ("attn", "local_attn", "enc_attn", "cross"):
        p["attn"] = attn.make_attention(cfg, ks[0])
        p["norm2"] = make_norm(cfg)
        if kind == "cross":
            p["norm_x"] = make_norm(cfg)
            p["xattn"] = attn.make_attention(cfg, ks[2])
        if kind == "attn" and cfg.num_experts:
            p["moe"] = moe_mod.make_moe(cfg, ks[1])
        else:
            p["mlp"] = mlp_mod.make_mlp(cfg, ks[1])
    elif kind == "dense_mlp":  # deepseek first dense layer (attn + wide mlp)
        p["attn"] = attn.make_attention(cfg, ks[0])
        p["norm2"] = make_norm(cfg)
        p["mlp"] = mlp_mod.make_mlp(cfg, ks[1],
                                    d_ff=cfg.first_dense_d_ff or cfg.d_ff)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.make_ssm(cfg, ks[0])
    elif kind == "rglru":
        p["rglru"] = rglru_mod.make_rglru(cfg, ks[0])
        p["norm2"] = make_norm(cfg)
        p["mlp"] = mlp_mod.make_mlp(cfg, ks[1])
    else:
        raise ValueError(kind)
    return p


# ---------------------------------------------------------------------------
# forward (full sequence; train / prefill)
# ---------------------------------------------------------------------------


def block_forward(p: PyTree, x: jax.Array, cfg: ModelConfig, kind: str, *,
                  positions: jax.Array,
                  memory: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache: Optional[PyTree] = None):
    """Returns (x', aux, cache').  cache' is None unless ``cache`` given
    (prefill mode fills it)."""
    aux: Dict[str, jax.Array] = {}
    new_cache = cache
    p = _cast_block_params(p, cfg)
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "local_attn", "enc_attn", "cross", "dense_mlp"):
        causal = kind != "enc_attn"
        window = cfg.local_window if kind == "local_attn" else 0
        q, k, v = attn.project_qkv(p["attn"], h, cfg,
                                   positions if cfg.use_rope else None)
        if cache is not None:
            # cache path == *extension*: insert the new K/V then attend over
            # the whole cache (prior entries included; empty slots carry
            # position -1 and mask out).  A fresh cache reproduces plain
            # causal attention; a warm cache makes K-token speculative
            # verification exact.
            sc = cache["self"] if kind == "cross" else cache
            if kind == "local_attn":
                wlen = min(cfg.local_window, k.shape[1])
                sc = attn.cache_insert(sc, k[:, -wlen:], v[:, -wlen:],
                                       positions[:, -wlen:])
            else:
                sc = attn.cache_insert(sc, k, v, positions)
            new_cache = dict(cache, self=sc) if kind == "cross" else sc
            o = attn.decode_attend(q, sc, window=window,
                                   q_positions=positions)
        else:
            o = attn.attend(q, k, v, causal=causal, window=window,
                            q_positions=positions, kv_positions=positions,
                            kv_chunk=1024)
        x = x + attn.project_out(p["attn"], o, x.dtype)
        if kind == "cross":
            hx = apply_norm(p["norm_x"], x, cfg)
            qx, _, _ = attn.project_qkv(p["xattn"], hx, cfg, None)
            xp = p["xattn"]
            if memory is not None:
                # project the encoder memory into this layer's K/V space
                mk = jnp.einsum("btd,dke->btke", memory,
                                xp["wk"].astype(x.dtype))
                mv = jnp.einsum("btd,dke->btke", memory,
                                xp["wv"].astype(x.dtype))
                if cache is not None:
                    new_cache = dict(new_cache, mem_k=mk, mem_v=mv)
            else:  # extension: reuse the projected memory in the cache
                mk, mv = cache["mem_k"], cache["mem_v"]
            ox = attn.attend(qx, mk, mv, causal=False, q_positions=positions,
                             kv_chunk=1024)
            x = x + attn.project_out(p["xattn"], ox, x.dtype)
        h2 = apply_norm(p["norm2"], x, cfg)
        if "moe" in p:
            y, aux = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = mlp_mod.apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    elif kind == "ssm":
        if cache is not None:
            y, new_cache = ssm_mod.apply_ssm(p["ssm"], h, cfg,
                                             return_state=True,
                                             initial=cache)
        else:
            y = ssm_mod.apply_ssm(p["ssm"], h, cfg)
        x = x + y
    elif kind == "rglru":
        if cache is not None:
            y, new_cache = rglru_mod.apply_rglru(p["rglru"], h, cfg,
                                                 return_state=True,
                                                 initial=cache)
        else:
            y = rglru_mod.apply_rglru(p["rglru"], h, cfg)
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg)
        x = x + mlp_mod.apply_mlp(p["mlp"], h2, cfg)
    else:
        raise ValueError(kind)
    x = constrain(x, BATCH, MODEL, None)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# decode (single token against caches)
# ---------------------------------------------------------------------------


def _sp_mesh(cfg: ModelConfig, cache):
    """Mesh for sequence-parallel decode attention, or None for the plain
    path.  Engages only when the cache is actually seq-sharded (kv_heads do
    NOT divide the model axis — otherwise the cache shards on heads and the
    shard_map in_specs would force a gather+rescatter every layer, §Perf)."""
    if not cfg.sp_decode_attn:
        return None
    from repro.sharding.specs import current_mesh

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    model_ax = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if cfg.num_kv_heads % model_ax == 0:   # cache shards on kv-heads already
        return None
    if cache.k.shape[1] % model_ax != 0:
        return None
    return mesh


def block_decode(p: PyTree, x: jax.Array, cfg: ModelConfig, kind: str, *,
                 positions: jax.Array, cache: PyTree,
                 memory: Optional[Tuple[jax.Array, jax.Array]] = None):
    """x: [B, 1, D]; positions: [B, 1] absolute. Returns (x', cache')."""
    p = _cast_block_params(p, cfg)
    h = apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "local_attn", "cross", "dense_mlp"):
        window = cfg.local_window if kind == "local_attn" else 0
        q, k, v = attn.project_qkv(p["attn"], h, cfg,
                                   positions if cfg.use_rope else None)
        sc = cache["self"] if kind == "cross" else cache
        mesh = _sp_mesh(cfg, sc)
        if mesh is not None:
            o, sc = attn.sp_insert_attend(q, k, v, sc, window=window,
                                          q_positions=positions, mesh=mesh)
        else:
            sc = attn.cache_insert(sc, k, v, positions)
            o = attn.decode_attend(q, sc, window=window,
                                   q_positions=positions)
        x = x + attn.project_out(p["attn"], o, x.dtype)
        new_cache = dict(cache, self=sc) if kind == "cross" else sc
        if kind == "cross":
            hx = apply_norm(p["norm_x"], x, cfg)
            qx, _, _ = attn.project_qkv(p["xattn"], hx, cfg, None)
            mk, mv = memory if memory is not None else (
                cache["mem_k"], cache["mem_v"])
            ox = attn.attend(qx, mk, mv, causal=False, q_positions=positions,
                             kv_chunk=1024)
            x = x + attn.project_out(p["xattn"], ox, x.dtype)
        h2 = apply_norm(p["norm2"], x, cfg)
        if "moe" in p:
            y, _ = moe_mod.apply_moe(p["moe"], h2, cfg)
        else:
            y = mlp_mod.apply_mlp(p["mlp"], h2, cfg)
        x = x + y
    elif kind == "ssm":
        y, new_cache = ssm_mod.decode_ssm(p["ssm"], h, cache, cfg)
        x = x + y
    elif kind == "rglru":
        y, new_cache = rglru_mod.decode_rglru(p["rglru"], h, cache, cfg)
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg)
        x = x + mlp_mod.apply_mlp(p["mlp"], h2, cfg)
    else:
        raise ValueError(kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# stacked-period scan
# ---------------------------------------------------------------------------


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(f)


def stack_forward(stack_params: PyTree, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array, caches: Optional[PyTree] = None,
                  memory: Optional[jax.Array] = None,
                  kinds: Optional[Tuple[str, ...]] = None):
    """Scan over stacked periods. stack_params[f"pos{j}"] leaves have leading
    num_periods dim. Returns (x, aux_sums, caches')."""
    pattern = kinds or cfg.pattern

    def period(x, inp):
        params_i, cache_i = inp
        aux_tot = {}
        new_caches = {}
        for j, kind in enumerate(pattern):
            c = None if cache_i is None else cache_i[f"pos{j}"]
            x, aux, nc = block_forward(
                params_i[f"pos{j}"], x, cfg, kind,
                positions=positions, cache=c, memory=memory)
            new_caches[f"pos{j}"] = nc
            for k2, v in aux.items():
                if jnp.issubdtype(jnp.asarray(v).dtype, jnp.floating):
                    aux_tot[k2] = aux_tot.get(k2, 0.0) + v
        return x, (aux_tot, (new_caches if cache_i is not None else None))

    stack_params = cast_stacked_params(stack_params, cfg)
    if isinstance(caches, (list, tuple)):  # per-layer caches: unroll (see
        aux_all: dict = {}                 # stack_decode note on GSPMD)
        outs = []
        n_periods = len(caches)
        for i in range(n_periods):
            params_i = jax.tree_util.tree_map(lambda a: a[i], stack_params)
            x, (aux_i, nc) = period(x, (params_i, caches[i]))
            outs.append(nc)
            for k2, v in aux_i.items():
                aux_all[k2] = aux_all.get(k2, 0.0) + v
        return x, aux_all, outs

    body = _remat(period, cfg)
    x, (aux_stacked, caches_out) = jax.lax.scan(
        body, x, (stack_params, caches))
    aux = {k2: jnp.sum(v) for k2, v in aux_stacked.items()}
    return x, aux, caches_out


def stack_decode(stack_params: PyTree, x: jax.Array, cfg: ModelConfig, *,
                 positions: jax.Array, caches: PyTree,
                 kinds: Optional[Tuple[str, ...]] = None):
    pattern = kinds or cfg.pattern
    stack_params = cast_stacked_params(stack_params, cfg)

    # Unrolled path (sp_decode_attn; caches is a per-layer LIST): a lax.scan
    # would carry the *stacked* caches as xs and GSPMD reshards/replicates
    # the whole stack around the loop (2x15 GB/step gathers on qwen2
    # decode_32k, §Perf).  Decode bodies are small; unrolling with separate
    # per-layer cache leaves keeps every cache fully shard-local.
    if isinstance(caches, (list, tuple)):
        n_periods = len(caches)
        outs = []
        for i in range(n_periods):
            params_i = jax.tree_util.tree_map(lambda a: a[i], stack_params)
            cache_i = caches[i]
            new_caches = {}
            for j, kind in enumerate(pattern):
                x, nc = block_decode(params_i[f"pos{j}"], x, cfg, kind,
                                     positions=positions,
                                     cache=cache_i[f"pos{j}"])
                new_caches[f"pos{j}"] = nc
            outs.append(new_caches)
        return x, outs

    def period(x, inp):
        params_i, cache_i = inp
        new_caches = {}
        for j, kind in enumerate(pattern):
            x, nc = block_decode(params_i[f"pos{j}"], x, cfg, kind,
                                 positions=positions,
                                 cache=cache_i[f"pos{j}"])
            new_caches[f"pos{j}"] = nc
        return x, new_caches

    x, caches_out = jax.lax.scan(period, x, (stack_params, caches))
    return x, caches_out
