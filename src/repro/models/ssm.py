"""Mamba-2 SSD (state-space duality) layer: chunked train scan + O(1) decode.

Faithful to the SSD block decomposition (arXiv:2405.21060): intra-chunk
quadratic term + inter-chunk state recurrence.  The chunk length is the
TPU tiling knob (ssm_chunk, default 256 = two MXU tiles).  Decode carries a
(B, H, P, N) state and a depthwise-conv ring buffer — constant memory at
524k-token contexts (the long_500k cell).
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, pdtype_of
from repro.sharding.specs import BATCH, MODEL, constrain


class SSMCache(NamedTuple):
    state: jax.Array      # [B, H, P, N] running SSM state
    conv_buf: jax.Array   # [B, K-1, conv_dim] last inputs for the conv


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def make_ssm(cfg: ModelConfig, key) -> Dict:
    d, din, h = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    g, n, kk = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    in_dim = 2 * din + 2 * g * n + h   # z, x, B, C, dt
    dt = jnp.exp(jax.random.uniform(ks[2], (h,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), pd),
        "conv_w": dense_init(ks[1], (kk, conv_dim(cfg)), pd,
                             scale=1.0 / math.sqrt(kk)),
        "conv_b": jnp.zeros((conv_dim(cfg),), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "ssm_D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((din,), pd),
        "out_proj": dense_init(ks[3], (din, d), pd,
                               scale=1.0 / math.sqrt(din * 2 * cfg.num_layers)),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. xbc: [B, S, C], w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(p, x, cfg: ModelConfig):
    din, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    zxbcdt = constrain(zxbcdt, BATCH, None, MODEL)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : din + conv_dim(cfg)]
    dt = zxbcdt[..., din + conv_dim(cfg) :]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def apply_ssm(p: Dict, x: jax.Array, cfg: ModelConfig,
              return_state: bool = False,
              initial: "SSMCache | None" = None):
    """Full-sequence SSD forward. x: [B, S, D] -> [B, S, D]
    (plus an SSMCache when ``return_state`` — the prefill->decode handoff).

    ``initial`` threads a previous cache through: the conv sees the last
    K-1 pre-projection inputs and the state recurrence starts from
    ``initial.state`` — this is what makes K-token cache *extension* exact
    (speculative-decoding verification), and a zero cache reproduces the
    fresh prefill.
    """
    b, s, _ = x.shape
    din, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    h, pdim, q = cfg.ssm_heads, cfg.ssm_headdim, min(cfg.ssm_chunk, x.shape[1])
    assert s % q == 0, (s, q)
    nc = s // q

    z, xbc_new, dt = _split_proj(p, x, cfg)
    if initial is not None:
        xbc_raw = jnp.concatenate(
            [initial.conv_buf.astype(xbc_new.dtype), xbc_new], axis=1)
    else:
        xbc_raw = xbc_new
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    if initial is not None:
        xbc = xbc[:, cfg.ssm_conv - 1:, :]  # drop the context rows
    xs = xbc[..., :din].reshape(b, s, h, pdim)
    bmat = xbc[..., din : din + g * n].reshape(b, s, g, n)
    cmat = xbc[..., din + g * n :].reshape(b, s, g, n)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [b,s,h]
    a = -jnp.exp(p["A_log"])                                          # [h]
    da = dt * a                                                        # [b,s,h]

    # chunk views
    xs_c = xs.reshape(b, nc, q, h, pdim).astype(jnp.float32)
    b_c = bmat.reshape(b, nc, q, g, n).astype(jnp.float32)
    c_c = cmat.reshape(b, nc, q, g, n).astype(jnp.float32)
    dt_c = dt.reshape(b, nc, q, h)
    da_c = da.reshape(b, nc, q, h)
    da_cs = jnp.cumsum(da_c, axis=2)                                  # [b,nc,q,h]

    # intra-chunk (quadratic within chunk): L[i,j] = exp(da_cs[i]-da_cs[j]), i>=j
    li = da_cs[:, :, :, None, :]                                       # i
    lj = da_cs[:, :, None, :, :]                                       # j
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    decay = jnp.where(mask, jnp.exp(li - lj), 0.0)                    # [b,nc,q,q,h]
    # scores: C_i . B_j  (groups broadcast over heads: h = g * (h//g))
    hg = h // g
    c_h = jnp.repeat(c_c, hg, axis=3)                                 # [b,nc,q,h,n]
    b_h = jnp.repeat(b_c, hg, axis=3)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", c_h, b_h)                   # [b,nc,q,q,h]
    y_diag = jnp.einsum("bcijh,bcjh,bcjhp->bcihp",
                        cb * decay, dt_c, xs_c)

    # chunk states: S_c = sum_j exp(da_cs[last]-da_cs[j]) dt_j x_j B_j^T
    seg = jnp.exp(da_cs[:, :, -1:, :] - da_cs)                        # [b,nc,q,h]
    states = jnp.einsum("bcjh,bcjh,bcjhp,bcjhn->bchpn",
                        seg, dt_c, xs_c, b_h)
    # inter-chunk recurrence over running state
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                         # [b,nc,h]

    def step(carry, inp):
        s_c, dec = inp
        new = carry * dec[:, :, None, None] + s_c
        return new, carry  # emit state *entering* the chunk

    init = (initial.state if initial is not None
            else jnp.zeros((b, h, pdim, n), jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                          # [b,nc,h,p,n]

    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp",
                       c_h, prev_states, jnp.exp(da_cs))
    y = (y_diag + y_off).reshape(b, s, h, pdim)
    y = y + xs.astype(jnp.float32) * p["ssm_D"][None, None, :, None]
    y = y.reshape(b, s, din)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    if return_state:
        k = cfg.ssm_conv
        cache = SSMCache(state=final_state,
                         conv_buf=xbc_raw[:, xbc_raw.shape[1] - (k - 1):, :])
        return out, cache
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, b: int, dtype) -> SSMCache:
    return SSMCache(
        state=jnp.zeros((b, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                        jnp.float32),
        conv_buf=jnp.zeros((b, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
    )


def decode_ssm(p: Dict, x: jax.Array, cache: SSMCache, cfg: ModelConfig
               ) -> Tuple[jax.Array, SSMCache]:
    """Single-token step. x: [B, 1, D] -> ([B, 1, D], cache')."""
    b = x.shape[0]
    din, g, n, h, pdim = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                          cfg.ssm_heads, cfg.ssm_headdim)
    z, xbc, dt = _split_proj(p, x, cfg)
    # conv over ring buffer + current input
    window = jnp.concatenate([cache.conv_buf, xbc], axis=1)  # [B, K, C]
    w = p["conv_w"].astype(x.dtype)
    conv = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc1 = jax.nn.silu(conv)
    new_buf = window[:, 1:, :]

    xs = xbc1[:, :din].reshape(b, h, pdim).astype(jnp.float32)
    bm = xbc1[:, din : din + g * n].reshape(b, g, n).astype(jnp.float32)
    cm = xbc1[:, din + g * n :].reshape(b, g, n).astype(jnp.float32)
    hg = h // g
    bm = jnp.repeat(bm, hg, axis=1)                                   # [b,h,n]
    cm = jnp.repeat(cm, hg, axis=1)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,h]
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt1 * a)                                             # [b,h]
    state = (cache.state * da[:, :, None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt1, xs, bm))
    y = jnp.einsum("bhn,bhpn->bhp", cm, state)
    y = y + xs * p["ssm_D"][None, :, None]
    y = y.reshape(b, 1, din)
    y = _gated_norm(y, z, p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    return out, SSMCache(state=state, conv_buf=new_buf)
