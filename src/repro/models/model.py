"""Model facade: init / loss / prefill / decode for every assigned arch."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.common import (
    apply_norm,
    chunked_cross_entropy,
    dtype_of,
    embed_tokens,
    lm_logits,
    make_embeddings,
    make_norm,
    sinusoidal_positions,
)
from repro.sharding.specs import BATCH, constrain

PyTree = Any


def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


class Model:
    """Functional model bound to a ModelConfig. All methods are pure."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------

    def init(self, rng: jax.Array) -> PyTree:
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        params: Dict[str, PyTree] = {
            "emb": make_embeddings(cfg, keys[0]),
            "final_norm": make_norm(cfg),
        }
        # leading dense layers (deepseek)
        if cfg.first_dense_layers:
            params["pre"] = [
                tfm.make_block(cfg, "dense_mlp", jax.random.fold_in(keys[1], i))
                for i in range(cfg.first_dense_layers)
            ]
        # stacked periods
        np_ = cfg.num_periods()
        if np_:
            periods = []
            for i in range(np_):
                kp = jax.random.fold_in(keys[2], i)
                periods.append({
                    f"pos{j}": tfm.make_block(cfg, kind,
                                              jax.random.fold_in(kp, j))
                    for j, kind in enumerate(self._decoder_pattern())
                })
            params["stack"] = _stack_trees(periods)
        # remainder
        tail = cfg.tail_kinds()
        if tail:
            params["tail"] = [
                tfm.make_block(cfg, self._map_kind(kind),
                               jax.random.fold_in(keys[3], i))
                for i, kind in enumerate(tail)
            ]
        # encoder (whisper)
        if cfg.encoder_layers:
            enc_periods = [
                {"pos0": tfm.make_block(cfg, "enc_attn",
                                        jax.random.fold_in(keys[4], i))}
                for i in range(cfg.encoder_layers)
            ]
            params["enc_stack"] = _stack_trees(enc_periods)
            params["enc_norm"] = make_norm(cfg)
        return params

    def abstract_params(self) -> PyTree:
        return jax.eval_shape(self.init, jax.ShapeDtypeStruct((2,), jnp.uint32))

    def _map_kind(self, kind: str) -> str:
        return "cross" if (self.cfg.encoder_layers and kind == "attn") else kind

    def _decoder_pattern(self) -> Tuple[str, ...]:
        return tuple(self._map_kind(k) for k in self.cfg.pattern)

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------

    def _encode(self, params: PyTree, frames: jax.Array) -> jax.Array:
        """Whisper encoder over stub frame embeddings [B, T, D]."""
        cfg = self.cfg
        x = frames.astype(dtype_of(cfg))
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model
                                     ).astype(x.dtype)[None]
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x, _, _ = tfm.stack_forward(params["enc_stack"], x, cfg,
                                    positions=pos, kinds=("enc_attn",))
        return apply_norm(params["enc_norm"], x, cfg)

    def _embed_inputs(self, params, batch) -> Tuple[jax.Array, jax.Array, int]:
        """Returns (x, positions, n_prefix)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s_text = tokens.shape
        if cfg.frontend == "patch":
            prefix = batch["prefix_embeds"].astype(dtype_of(cfg))
            npre = prefix.shape[1]
            pos = jnp.broadcast_to(jnp.arange(npre + s_text),
                                   (b, npre + s_text))
            x_tok = embed_tokens(params["emb"], tokens, cfg,
                                 positions=pos[0, npre:])
            x = jnp.concatenate([prefix, x_tok], axis=1)
            return x, pos, npre
        pos = jnp.broadcast_to(jnp.arange(s_text), (b, s_text))
        x = embed_tokens(params["emb"], tokens, cfg, positions=pos[0])
        return x, pos, 0

    def _body(self, params, x, positions, caches=None, memory=None):
        """pre -> stack -> tail. Returns (x, aux, caches')."""
        cfg = self.cfg
        aux_all: Dict[str, jax.Array] = {}
        new_caches: Dict[str, PyTree] = {}
        c_pre = None if caches is None else caches.get("pre")
        if cfg.first_dense_layers:
            out_pre = []
            for i, bp in enumerate(params["pre"]):
                c = None if c_pre is None else c_pre[i]
                x, aux, nc = tfm.block_forward(
                    bp, x, cfg, "dense_mlp", positions=positions, cache=c,
                    memory=memory)
                out_pre.append(nc)
                aux_all.update(aux)
            new_caches["pre"] = out_pre
        if "stack" in params:
            c_stack = None if caches is None else caches.get("stack")
            x, aux, cs = tfm.stack_forward(
                params["stack"], x, cfg, positions=positions,
                caches=c_stack, memory=memory,
                kinds=self._decoder_pattern())
            for k2, v in aux.items():
                aux_all[k2] = aux_all.get(k2, 0.0) + v
            new_caches["stack"] = cs
        if "tail" in params:
            c_tail = None if caches is None else caches.get("tail")
            out_tail = []
            for i, (bp, kind) in enumerate(
                    zip(params["tail"], self.cfg.tail_kinds())):
                c = None if c_tail is None else c_tail[i]
                x, aux, nc = tfm.block_forward(
                    bp, x, cfg, self._map_kind(kind), positions=positions,
                    cache=c, memory=memory)
                out_tail.append(nc)
                aux_all.update(aux)
            new_caches["tail"] = out_tail
        x = apply_norm(params["final_norm"], x, cfg)
        return x, aux_all, (new_caches if caches is not None else None)

    # ------------------------------------------------------------------
    # training loss
    # ------------------------------------------------------------------

    def loss_fn(self, params: PyTree, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        memory = None
        if cfg.encoder_layers:
            memory = self._encode(params, batch["frames"])
        x, positions, npre = self._embed_inputs(params, batch)
        x = constrain(x, BATCH, None, None)
        x, aux, _ = self._body(params, x, positions, memory=memory)
        if npre:
            x = x[:, npre:]
        mask = batch.get("loss_mask",
                         jnp.ones_like(batch["targets"], jnp.float32))
        ce = chunked_cross_entropy(params["emb"], x, batch["targets"],
                                   mask.astype(jnp.float32), cfg)
        loss = ce
        if "moe_lb_loss" in aux:
            loss = loss + 1e-2 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
        metrics = {"ce": ce, "loss": loss}
        for k2 in ("moe_lb_loss", "moe_z_loss"):
            if k2 in aux:
                metrics[k2] = aux[k2]
        return loss, metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def init_caches(self, b: int, max_len: int, enc_len: int = 0) -> PyTree:
        cfg = self.cfg
        dt = dtype_of(cfg)
        kv, hd = cfg.num_kv_heads, cfg.head_dim

        def one(kind: str) -> PyTree:
            if kind in ("attn", "dense_mlp"):
                return attn_mod.init_cache(b, max_len, kv, hd, dt)
            if kind == "local_attn":
                return attn_mod.init_cache(
                    b, min(cfg.local_window, max_len), kv, hd, dt, ring=True)
            if kind == "cross":
                return {
                    "self": attn_mod.init_cache(
                        b, min(cfg.decoder_max_len, max_len), kv, hd, dt),
                    "mem_k": jnp.zeros((b, enc_len, kv, hd), dt),
                    "mem_v": jnp.zeros((b, enc_len, kv, hd), dt),
                }
            if kind == "ssm":
                return ssm_mod.init_ssm_cache(cfg, b, dt)
            if kind == "rglru":
                return rglru_mod.init_rglru_cache(cfg, b, dt)
            raise ValueError(kind)

        caches: Dict[str, PyTree] = {}
        if cfg.first_dense_layers:
            caches["pre"] = [one("dense_mlp")
                             for _ in range(cfg.first_dense_layers)]
        np_ = cfg.num_periods()
        if np_:
            period = {f"pos{j}": one(kind)
                      for j, kind in enumerate(self._decoder_pattern())}
            if cfg.sp_decode_attn:
                # per-layer list: stacking shard_map outputs forces a layout
                # change that GSPMD resolves by replicating the whole stacked
                # cache (2x15 GB/step gathers on qwen2 decode, §Perf) —
                # separate leaves keep every cache shard-local
                caches["stack"] = [
                    jax.tree_util.tree_map(jnp.copy, period)
                    for _ in range(np_)]
            else:
                caches["stack"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (np_,) + x.shape),
                    period)
        tail = cfg.tail_kinds()
        if tail:
            caches["tail"] = [one(self._map_kind(k)) for k in tail]
        return caches

    def prefill(self, params: PyTree, batch: Dict[str, jax.Array],
                max_len: int) -> Tuple[jax.Array, PyTree]:
        """Process the prompt; returns (last-token logits [B, V], caches)."""
        cfg = self.cfg
        memory = None
        enc_len = 0
        if cfg.encoder_layers:
            memory = self._encode(params, batch["frames"])
            enc_len = memory.shape[1]
        x, positions, npre = self._embed_inputs(params, batch)
        caches = self.init_caches(x.shape[0], max_len, enc_len)
        x, _, caches = self._body(params, x, positions, caches=caches,
                                  memory=memory)
        logits = lm_logits(params["emb"], x[:, -1], cfg)
        return logits, caches

    def extend_step(self, params: PyTree, caches: PyTree, tokens: jax.Array,
                    pos0: jax.Array) -> Tuple[jax.Array, PyTree]:
        """Extend warm caches by K tokens in ONE forward (speculative-decode
        verification).  tokens: [B, K]; pos0: [B] absolute position of
        tokens[:, 0].  Returns (logits [B, K, V], caches').

        Exact for every layer family: attention re-reads the whole cache
        (positions mask), SSM/RG-LRU thread initial recurrent state +
        conv left-context.  Rollback after partial acceptance is free —
        pytrees are immutable, the caller just keeps the pre-extend caches.
        """
        cfg = self.cfg
        b, k = tokens.shape
        positions = pos0[:, None] + jnp.arange(k)[None, :]
        x = embed_tokens(
            params["emb"], tokens, cfg,
            positions=None if cfg.use_rope else jnp.clip(
                positions, 0, cfg.max_position_actual() - 1))
        x = constrain(x, BATCH, None, None)
        x, _, new_caches = self._body(params, x, positions, caches=caches)
        logits = lm_logits(params["emb"], x, cfg)
        return logits, new_caches

    def decode_step(self, params: PyTree, caches: PyTree, tokens: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, PyTree]:
        """One token per sequence. tokens: [B, 1]; pos: [B] absolute position
        of that token. Returns (logits [B, V], caches')."""
        cfg = self.cfg
        positions = pos[:, None]
        x = embed_tokens(
            params["emb"], tokens, cfg,
            positions=None if cfg.use_rope else jnp.clip(
                positions, 0, cfg.max_position_actual() - 1))
        x = constrain(x, BATCH, None, None)

        new_caches: Dict[str, PyTree] = {}
        if cfg.first_dense_layers:
            out = []
            for i, bp in enumerate(params["pre"]):
                x, nc = tfm.block_decode(bp, x, cfg, "dense_mlp",
                                         positions=positions,
                                         cache=caches["pre"][i])
                out.append(nc)
            new_caches["pre"] = out
        if "stack" in params:
            x, cs = tfm.stack_decode(params["stack"], x, cfg,
                                     positions=positions,
                                     caches=caches["stack"],
                                     kinds=self._decoder_pattern())
            new_caches["stack"] = cs
        if "tail" in params:
            out = []
            for i, (bp, kind) in enumerate(
                    zip(params["tail"], cfg.tail_kinds())):
                x, nc = tfm.block_decode(bp, x, cfg, self._map_kind(kind),
                                         positions=positions,
                                         cache=caches["tail"][i])
                out.append(nc)
            new_caches["tail"] = out
        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params["emb"], x[:, -1], cfg)
        return logits, new_caches
