"""Common building blocks: norms, embeddings, init, chunked cross-entropy."""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (He-ish, stddev 1/sqrt(fan_in))."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def make_norm(cfg: ModelConfig, d: Optional[int] = None) -> PyTree:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), pdtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdtype_of(cfg))
    return p


def apply_norm(p: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------


def make_embeddings(cfg: ModelConfig, key) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": embed_init(k1, (cfg.vocab_size, cfg.d_model), pdtype_of(cfg))}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), pdtype_of(cfg))
    if not cfg.use_rope:
        p["pos"] = embed_init(k3, (cfg.max_position_actual(), cfg.d_model),
                              pdtype_of(cfg))
    return p


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig,
                 positions: Optional[jax.Array] = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype_of(cfg))
    if not cfg.use_rope:
        if positions is None:
            positions = jnp.arange(tokens.shape[-1])
        x = x + jnp.take(p["pos"], positions, axis=0).astype(dtype_of(cfg))
    return x


def lm_logits(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))


def sinusoidal_positions(length: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table [length, d] (float32)."""
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-math.log(10_000.0) * dim / (d // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# chunked cross-entropy: never materialise (B, S, V)
# ---------------------------------------------------------------------------


def chunked_cross_entropy(emb_params, x: jax.Array, targets: jax.Array,
                          mask: jax.Array, cfg: ModelConfig,
                          chunk: int = 512):
    """Mean CE over valid tokens, computing logits in sequence chunks.

    x: [B, S, D] final hidden states; targets/mask: [B, S].  The (B, S, V)
    logits tensor (2.1 GB/chip for recurrentgemma's 256k vocab at 4k seq)
    never exists: each scan step sees (B, chunk, V) and reduces immediately.
    """
    b, s, d = x.shape
    if s % chunk:
        chunk = s  # fallback for tiny smoke shapes
    n_chunks = s // chunk
    xs = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    ts = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint  # recompute chunk logits in backward: the (B, chunk, V)
    def step(carry, inp):  # tensor is never stored (8 chunks would be ~13 GB)
        tot_nll, tot_cnt = carry
        xc, tc, mc = inp
        logits = lm_logits(emb_params, xc, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (tot_nll + jnp.sum(nll), tot_cnt + jnp.sum(mc)), None

    (tot_nll, tot_cnt), _ = jax.lax.scan(
        step, (jnp.float32(0), jnp.float32(0)), (xs, ts, ms))
    return tot_nll / jnp.maximum(tot_cnt, 1.0)
