"""RG-LRU recurrence block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = gate branch (GeLU) x recurrence branch (conv4 -> RG-LRU) -> out proj.
RG-LRU: r_t = sigmoid(block-diag gate), i_t = sigmoid(block-diag gate),
a_t = a^{c r_t} with a = sigmoid(Lambda);
h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t).

Train: jax.lax.associative_scan over the linear recurrence (log-depth on
sequence — the sub-quadratic property that makes long_500k runnable).
Decode: one multiply-add — O(1) state.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, pdtype_of
from repro.sharding.specs import BATCH, MODEL, constrain


class RGLRUCache(NamedTuple):
    h: jax.Array          # [B, W] recurrent state
    conv_buf: jax.Array   # [B, K-1, W]


def make_rglru(cfg: ModelConfig, key) -> Dict:
    d, w, heads = cfg.d_model, cfg.rnn_width, cfg.num_heads
    bw = w // heads
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 6)
    # Lambda init so a in (0.9, 0.999): sigmoid^-1 over that range
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u) - jnp.log1p(-u)
    return {
        "wx": dense_init(ks[1], (d, w), pd),
        "wgate": dense_init(ks[2], (d, w), pd),
        "conv_w": dense_init(ks[3], (cfg.ssm_conv, w), pd,
                             scale=1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((w,), pd),
        "ga_w": dense_init(ks[4], (heads, bw, bw), pd),
        "ga_b": jnp.zeros((heads, bw), pd),
        "gi_w": dense_init(ks[5], (heads, bw, bw), pd),
        "gi_b": jnp.zeros((heads, bw), pd),
        "lambda_p": lam,
        "out_proj": dense_init(
            jax.random.fold_in(key, 7), (w, d), pd,
            scale=1.0 / math.sqrt(w * 2 * cfg.num_layers)),
    }


def _conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i : i + u.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _gates(p: Dict, u: jax.Array, cfg: ModelConfig):
    """Block-diagonal r/i gates + log recurrence weight. u: [B, S, W]."""
    b, s, w = u.shape
    heads = cfg.num_heads
    uh = u.reshape(b, s, heads, w // heads)
    r = jax.nn.sigmoid(jnp.einsum("bshi,hij->bshj", uh,
                                  p["ga_w"].astype(u.dtype)).astype(jnp.float32)
                       + p["ga_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bshi,hij->bshj", uh,
                                  p["gi_w"].astype(u.dtype)).astype(jnp.float32)
                       + p["gi_b"].astype(jnp.float32))
    r = r.reshape(b, s, w)
    i = i.reshape(b, s, w)
    log_a = -cfg.rglru_c * r * jax.nn.softplus(-p["lambda_p"])  # log sigmoid
    return i, log_a


def apply_rglru(p: Dict, x: jax.Array, cfg: ModelConfig,
                return_state: bool = False,
                initial: "RGLRUCache | None" = None):
    """Full-sequence forward. x: [B, S, D] -> [B, S, D]
    (plus an RGLRUCache when ``return_state``).

    ``initial`` threads a previous cache: conv left-context + recurrent h0
    (h_t = (prod a_1..t) h0 + scan_t), making K-token cache extension exact.
    A zero cache reproduces fresh prefill.
    """
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x,
                                  p["wgate"].astype(x.dtype)))
    u_new = jnp.einsum("bsd,dw->bsw", x, p["wx"].astype(x.dtype))
    u_new = constrain(u_new, BATCH, None, MODEL)
    if initial is not None:
        u_raw = jnp.concatenate(
            [initial.conv_buf.astype(u_new.dtype), u_new], axis=1)
    else:
        u_raw = u_new
    u = _conv(u_raw, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    if initial is not None:
        u = u[:, p["conv_w"].shape[0] - 1:, :]
    i, log_a = _gates(p, u, cfg)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = beta * (i * u.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, bt), axis=1)
    if initial is not None:
        h = h + a_cum * initial.h[:, None, :]
    y = h.astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        k = p["conv_w"].shape[0]
        cache = RGLRUCache(h=h[:, -1],
                           conv_buf=u_raw[:, u_raw.shape[1] - (k - 1):])
        return out, cache
    return out


def init_rglru_cache(cfg: ModelConfig, b: int, dtype) -> RGLRUCache:
    return RGLRUCache(
        h=jnp.zeros((b, cfg.rnn_width), jnp.float32),
        conv_buf=jnp.zeros((b, cfg.ssm_conv - 1, cfg.rnn_width), dtype),
    )


def decode_rglru(p: Dict, x: jax.Array, cache: RGLRUCache, cfg: ModelConfig
                 ) -> Tuple[jax.Array, RGLRUCache]:
    """Single-token step. x: [B, 1, D]."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x,
                                  p["wgate"].astype(x.dtype)))
    u = jnp.einsum("bsd,dw->bsw", x, p["wx"].astype(x.dtype))
    window = jnp.concatenate([cache.conv_buf, u], axis=1)   # [B, K, W]
    w = p["conv_w"].astype(x.dtype)
    u1 = (jnp.einsum("bkw,kw->bw", window, w)
          + p["conv_b"].astype(x.dtype))[:, None, :]
    i, log_a = _gates(p, u1, cfg)
    a = jnp.exp(log_a[:, 0])
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a[:, 0]), 1e-12))
    h = cache.h * a + beta * (i[:, 0] * u1[:, 0].astype(jnp.float32))
    y = h[:, None, :].astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"].astype(x.dtype))
    return out, RGLRUCache(h=h, conv_buf=window[:, 1:, :])
