"""Model zoo: 10 assigned architectures on shared substrates."""

from repro.models.model import Model  # noqa: F401
