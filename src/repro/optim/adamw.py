"""Hand-rolled AdamW (no optax in this container) with sharded states.

States mirror the parameter pytree, so the same partition specs apply —
ZeRO-style optimizer-state sharding falls out of the param sharding rules
(DESIGN.md §4).  Supports global-norm clipping and decoupled weight decay.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (1-D params)."""
    name = str(getattr(path[-1], "key", ""))
    return name not in ("scale", "bias", "conv_b", "ga_b", "gi_b",
                        "lambda_p", "A_log", "ssm_D", "dt_bias", "norm_scale")


def update(grads: PyTree, state: AdamWState, params: PyTree,
           cfg: AdamWConfig, lr_scale: jax.Array | float = 1.0
           ) -> Tuple[PyTree, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu2, nu2)

    out = jax.tree_util.tree_map_with_path(
        upd, params, grads, state.mu, state.nu)
    # tree of 3-tuples -> 3 trees
    treedef = jax.tree_util.tree_structure(params)
    flat = treedef.flatten_up_to(out)
    new_p = treedef.unflatten([t[0] for t in flat])
    new_mu = treedef.unflatten([t[1] for t in flat])
    new_nu = treedef.unflatten([t[2] for t in flat])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
    return new_p, AdamWState(step, new_mu, new_nu), metrics
