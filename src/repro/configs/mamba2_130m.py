"""mamba2-130m: 24L d=768, attention-free SSD, state=128, vocab=50280.

[arXiv:2405.21060].  d_inner = 2*768 = 1536, headdim 64 -> 24 ssm heads,
1 B/C group, conv4, chunked SSD scan.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=1,            # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=("ssm",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_expand=2,
    ssm_conv=4,
    ssm_groups=1,
    tie_embeddings=True,
)
