"""granite-34b-code: 88L d=6144 48H MQA(kv=1) d_ff=24576 vocab=49152.

[arXiv:2405.04324; hf].  GPT-BigCode-lineage code model: plain 4x GELU MLP,
MQA, RoPE, untied embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    gated_mlp=False,
    act="gelu",
    rope_theta=10_000.0,
)
