"""whisper-base: enc-dec, 6+6L d=512 8H MHA d_ff=2048 vocab=51865.

[arXiv:2212.04356].  Conv audio frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings [B, T, d].  Sinusoidal encoder positions,
learned decoder positions, LayerNorm, plain GELU MLP, no RoPE.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,            # decoder layers
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    use_rope=False,
    decoder_max_len=448,
    frontend="frames",
    tie_embeddings=True,
)
