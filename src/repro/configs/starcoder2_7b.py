"""starcoder2-7b: 32L d=4608 36H GQA(kv=4) d_ff=18432 vocab=49152.

[arXiv:2402.19173; hf].  GQA + RoPE, plain 4x GELU MLP.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    gated_mlp=False,
    act="gelu",
    norm="layernorm",
    rope_theta=100_000.0,
)
