"""Registry of the 10 assigned architectures (+ the paper's own config).

Each entry matches the public source cited in the brief; ``smoke_config``
derives a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.qwen2_7b import CONFIG as qwen2_7b
from repro.configs.starcoder2_3b import CONFIG as starcoder2_3b
from repro.configs.phi3_vision_4_2b import CONFIG as phi3_vision_4_2b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.moonshot_v1_16b_a3b import CONFIG as moonshot_v1_16b_a3b
from repro.configs.deepseek_moe_16b import CONFIG as deepseek_moe_16b
from repro.configs.base import ModelConfig

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        granite_34b, starcoder2_7b, qwen2_7b, starcoder2_3b,
        phi3_vision_4_2b, whisper_base, mamba2_130m, recurrentgemma_9b,
        moonshot_v1_16b_a3b, deepseek_moe_16b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: 2 pattern periods (+tail/pre), tiny dims."""
    cfg = get_config(name)
    period = len(cfg.pattern)
    layers = cfg.first_dense_layers + 2 * period + len(cfg.tail_kinds())
    changes = dict(
        num_layers=layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads
        < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        remat="none",
        scan_layers=True,
        local_window=min(cfg.local_window, 16) if cfg.local_window else 0,
        max_position=4096,
    )
    if cfg.num_experts:
        changes.update(num_experts=8, experts_per_token=2,
                       moe_d_ff=64, first_dense_d_ff=256)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, decoder_max_len=64)
    if cfg.rglru_width:
        changes.update(rglru_width=128)
    if cfg.frontend == "patch":
        changes.update(frontend_len=4)
    return dataclasses.replace(cfg, **changes)
