from repro.configs.base import ModelConfig  # noqa: F401
from repro.configs.registry import ARCHS, get_config, smoke_config  # noqa: F401
