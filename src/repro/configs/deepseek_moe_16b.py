"""deepseek-moe-16b: 28L d=2048 16H MHA, 64 routed top-6 + 2 shared experts,
expert d_ff=1408, first layer dense d_ff=10944, vocab=102400.

[arXiv:2401.06066; hf].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=102_400,
    gated_mlp=True,
    act="silu",
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    first_dense_d_ff=10944,
    rope_theta=10_000.0,
)
