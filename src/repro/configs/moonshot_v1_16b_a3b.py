"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 48L d=2048 16H MHA,
MoE 64 routed experts top-6 + 2 shared, expert d_ff=1408, vocab=163840.

[hf:moonshotai/Moonlight-16B-A3B].  DeepSeek-V3-style fine-grained MoE;
first layer dense (assumed dense d_ff = 8 * 1408 = 11264, per the
DeepSeek-family convention — noted in DESIGN.md).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    gated_mlp=True,
    act="silu",
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    first_dense_d_ff=11264,
    rope_theta=50_000.0,
)
