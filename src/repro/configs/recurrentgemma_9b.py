"""recurrentgemma-9b: 38L d=4096, RG-LRU + local attention 1:2, MQA(kv=1),
d_ff=12288, vocab=256000, window 2048.

[arXiv:2402.19427].  Pattern (rglru, rglru, local_attn): 12 full periods +
2-layer tail.  GeGLU MLP in every block; rnn width = d_model.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    gated_mlp=True,
    act="gelu",
    pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    rglru_width=4096,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
