"""Model configuration schema shared by all 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # attention
    qkv_bias: bool = False         # qwen2-style QKV bias
    rope_theta: float = 10_000.0
    use_rope: bool = True          # whisper uses absolute positions instead
    local_window: int = 0          # >0: sliding-window attention
    max_position: int = 1 << 20    # abs-pos table size when use_rope=False

    # MLP
    gated_mlp: bool = True         # SwiGLU/GeGLU vs plain 4x MLP
    act: str = "silu"              # silu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    first_dense_layers: int = 0    # deepseek: leading dense layer(s)
    first_dense_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (recurrentgemma): cycled per-layer kinds
    pattern: Tuple[str, ...] = ("attn",)   # attn | local_attn | rglru | ssm | moe
    rglru_width: int = 0           # 0 -> d_model
    rglru_c: float = 8.0

    # encoder-decoder
    encoder_layers: int = 0
    decoder_max_len: int = 448     # whisper decoder positions

    # modality frontend STUB (phi-3-vision patches, whisper frames)
    frontend: str = "none"         # none | patch | frames
    frontend_len: int = 0          # prefix embeddings per example (vlm)

    # assembly / numerics
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: str = "full"            # none | full | dots
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # performance variants (§Perf hillclimb; defaults = paper-faithful
    # baseline configuration)
    sp_decode_attn: bool = False   # shard_map LSE-combine decode attention
    moe_combine: str = "scatter"   # scatter | gather combine after experts
    moe_impl: str = "dense"        # dense (pjit) | ep (shard_map all_to_all)
    shard_strategy: str = "fsdp_tp"  # fsdp_tp | fsdp2d (activations never
                                     # model-sharded; weights 2D-sharded)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def rnn_width(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def num_periods(self) -> int:
        """How many full pattern periods fit in the (decoder) stack."""
        body = self.num_layers - self.first_dense_layers
        return body // len(self.pattern)

    def tail_kinds(self) -> Tuple[str, ...]:
        """Layer kinds after the last full period (unrolled)."""
        body = self.num_layers - self.first_dense_layers
        rem = body % len(self.pattern)
        return self.pattern[:rem]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline's
        MODEL_FLOPS = 6*N*D."""
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        if not self.use_rope:
            n += self.max_position_actual() * d
        for kind in self._all_kinds():
            n += self._block_params(kind)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE counts only routed-in experts)."""
        d, v = self.d_model, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)
        for kind in self._all_kinds():
            n += self._block_params(kind, active_only=True)
        n += d
        return n

    # -- helpers ---------------------------------------------------------
    def _all_kinds(self):
        kinds = ["dense_mlp"] * self.first_dense_layers
        body = self.num_layers - self.first_dense_layers
        for i in range(body):
            kinds.append(self.pattern[i % len(self.pattern)])
        if self.encoder_layers:
            kinds += ["enc_attn"] * self.encoder_layers
        return kinds

    def _block_params(self, kind: str, active_only: bool = False) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp_mult = 3 if self.gated_mlp else 2
        mlp = mlp_mult * d * self.d_ff
        if kind in ("attn", "local_attn"):
            if kind == "attn" and self.num_experts and not active_only:
                experts = self.num_experts + self.num_shared_experts
                moe = mlp_mult * d * self.moe_d_ff * experts + d * self.num_experts
                return attn + moe
            if kind == "attn" and self.num_experts and active_only:
                experts = self.experts_per_token + self.num_shared_experts
                moe = mlp_mult * d * self.moe_d_ff * experts + d * self.num_experts
                return attn + moe
            return attn + mlp
        if kind == "enc_attn":
            return attn + mlp
        if kind == "dense_mlp":
            return attn + mlp_mult * d * (self.first_dense_d_ff or self.d_ff)
        if kind == "ssm":
            din, ns, hs = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = din + 2 * self.ssm_groups * ns
            return (d * (2 * din + 2 * self.ssm_groups * ns + hs)
                    + self.ssm_conv * conv_dim + din * d + 2 * hs + din)
        if kind == "rglru":
            w = self.rnn_width
            return d * w * 2 + w * d + 4 * w + self.ssm_conv * w + mlp
        raise ValueError(kind)

    def max_position_actual(self) -> int:
        return self.decoder_max_len if self.encoder_layers else self.max_position
