"""phi-3-vision-4.2b: phi3-mini backbone (32L d=3072 32H MHA d_ff=8192
vocab=32064) + CLIP patch frontend as a STUB (precomputed patch embeddings
prepended to the text sequence).

[hf:microsoft/Phi-3-vision-128k-instruct].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    gated_mlp=True,
    act="silu",
    rope_theta=10_000.0,
    frontend="patch",
    frontend_len=256,   # stub: 256 patch embeddings per image
)
