"""Sharded checkpointing: save/restore with manifest, async save, elastic
re-mesh on restore (fault-tolerance substrate).

Layout:  <dir>/step_<n>/manifest.json + arrays.npz
Each leaf is keyed by its '/'-joined tree path.  ``restore`` re-shards every
leaf onto the *current* mesh/sharding — a checkpoint written on a 512-chip
mesh restores onto 256 chips (elastic scaling) because leaves are stored as
full logical arrays (single-process container) / per-shard files on real
multi-host pods (same manifest format, addressable-shard writes — the code
path difference is isolated in ``_gather``/``_put``).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.faults import failpoint

PyTree = Any


def _paths_and_leaves(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys, leaves = [], []
    for path, leaf in flat:
        keys.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path))
        leaves.append(leaf)
    return keys, leaves, treedef


def _gather(x: jax.Array) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def save(tree: PyTree, directory: str, step: int) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    keys, leaves, _ = _paths_and_leaves(tree)
    arrays = {f"a{i}": _gather(x) for i, x in enumerate(leaves)}
    failpoint("snapshot.arrays_write", path=path, step=step)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": [str(np.asarray(arrays[f"a{i}"]).dtype)
                   for i in range(len(keys))],
        "shapes": [list(arrays[f"a{i}"].shape) for i in range(len(keys))],
    }
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    failpoint("snapshot.manifest_commit", path=path, step=step)
    os.replace(tmp, os.path.join(path, "manifest.json"))  # atomic commit
    return path


def save_async(tree: PyTree, directory: str, step: int,
               on_complete: Optional[Any] = None,
               on_error: Optional[Any] = None) -> threading.Thread:
    """Non-blocking save: device->host copy happens on the caller thread
    (cheap, overlapped with the next step's compile/dispatch), file IO on a
    worker thread.  ``on_complete`` (a zero-arg callable) runs on the worker
    thread strictly after the manifest rename commits — the hook for actions
    that are only safe once the checkpoint is durable, e.g. WAL truncation.
    ``on_error`` receives any exception the worker hits (IO faults, a
    failing ``on_complete``); without it the exception propagates and the
    thread dies with a stderr traceback — a *silently* dead IO thread would
    leave an aborted step directory that looks like progress.

    The thread is deliberately NOT a daemon: interpreter shutdown must wait
    for the commit rather than abandoning a half-written step (the owner —
    ``ShardedEngine.close()`` — joins it)."""
    keys, leaves, _ = _paths_and_leaves(tree)
    host = [(k, _gather(x)) for k, x in zip(keys, leaves)]

    def work():
        try:
            failpoint("snapshot.io_thread", step=step)
            path = os.path.join(directory, f"step_{step:08d}")
            os.makedirs(path, exist_ok=True)
            failpoint("snapshot.arrays_write", path=path, step=step)
            np.savez(os.path.join(path, "arrays.npz"),
                     **{f"a{i}": a for i, (_, a) in enumerate(host)})
            manifest = {"step": step, "keys": [k for k, _ in host],
                        "dtypes": [str(a.dtype) for _, a in host],
                        "shapes": [list(a.shape) for _, a in host]}
            tmp = os.path.join(path, "manifest.json.tmp")
            with open(tmp, "w") as f:
                json.dump(manifest, f)
            failpoint("snapshot.manifest_commit", path=path, step=step)
            os.replace(tmp, os.path.join(path, "manifest.json"))
            if on_complete is not None:
                on_complete()
        except Exception as exc:
            if on_error is None:
                raise
            on_error(exc)

    t = threading.Thread(target=work, daemon=False)
    t.start()
    return t


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like: PyTree, directory: str, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``tree_like``; re-shard with
    ``shardings`` (elastic re-mesh) when given."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    failpoint("snapshot.restore_read", path=path, step=step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}

    keys, leaves, treedef = _paths_and_leaves(tree_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for k, like, sh in zip(keys, leaves, shard_leaves):
        if k not in by_key:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = by_key[k]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{k}: shape {arr.shape} != {like.shape}")
        # cast only within a kind (f64 ckpt -> f32 leaf, i64 -> i32): a
        # float array restoring into an integer leaf (or vice versa) means
        # the checkpoint and the template disagree about what the leaf IS,
        # and a silent astype would truncate/round values instead of
        # failing.  Explicit kind equality — np.can_cast('same_kind')
        # alone would still let int checkpoints round into float leaves.
        like_dtype = np.dtype(like.dtype)
        if arr.dtype != like_dtype and (
                arr.dtype.kind != like_dtype.kind
                or not np.can_cast(arr.dtype, like_dtype,
                                   casting="same_kind")):
            raise ValueError(
                f"{k}: checkpoint dtype {arr.dtype} cannot restore into "
                f"{like_dtype} without changing kind")
        arr = arr.astype(like_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
