"""Logical-axis sharding rules -> concrete PartitionSpecs.

Logical axes used throughout the model code:
  * BATCH — the data-parallel axes: ("pod", "data") on the multi-pod mesh,
            ("data",) on a single pod.
  * DATA  — the FSDP axis ("data"): weight shards that are all-gathered
            per layer (ZeRO-3).  Dropped in ``serve`` mode (pure TP keeps
            decode latency free of per-step weight gathers).
  * MODEL — the tensor-parallel axis ("model").

Divisibility-aware: a logical axis is silently dropped when the dim size
does not divide the mesh axis size *and* padding would waste > 25% (GSPMD can
pad, but for tiny dims like kv_heads=1 or ssm head vectors the padding waste
dwarfs the gain; §Roofline measures what padding remains).

A ``stage`` (pipeline) axis would compose here as an extra leading rule on the
stacked-layer dim; not enabled for the assigned 16x16 / 2x16x16 meshes
(DESIGN.md §7).
"""

from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH = "__batch__"
DATA = "__data__"
MODEL = "__model__"

# sharding strategy (§Perf hillclimb):
#   fsdp_tp (baseline): batch over (pod, data); activations model-sharded
#                       (Megatron-SP style TP on the model axis)
#   fsdp2d: batch over EVERY axis (pure data parallel, 1 seq/chip at 256);
#           activation constraints never mention the model axis, weights
#           stay 2D-sharded -> XLA gathers weights per layer (ZeRO-3 style)
_STRATEGY: contextvars.ContextVar[str] = contextvars.ContextVar(
    "shard_strategy", default="fsdp_tp")


@contextlib.contextmanager
def strategy(name: str):
    tok = _STRATEGY.set(name)
    try:
        yield
    finally:
        _STRATEGY.reset(tok)


def current_mesh() -> Optional[jax.sharding.Mesh]:
    """The mesh installed by ``with mesh:`` (None outside any mesh context)."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def batch_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    if _STRATEGY.get() == "fsdp2d":
        return tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def resolve(spec_entry, mesh: jax.sharding.Mesh):
    if spec_entry == BATCH:
        return batch_axes(mesh)
    if spec_entry == DATA:
        # FSDP spans pods: otherwise weights replicate across pods and
        # gradient sync becomes a full cross-pod fp32 all-reduce
        # (+49% collective on the granite 2-pod probe, EXPERIMENTS §Perf)
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if spec_entry == MODEL:
        return "model"
    return spec_entry


def _axis_size(mesh: jax.sharding.Mesh, entry) -> int:
    names = resolve(entry, mesh)
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape))[n]
    return size


def concretize(logical: Tuple, mesh: jax.sharding.Mesh,
               shape: Optional[Tuple[int, ...]] = None,
               strict: bool = False) -> P:
    """Logical tuple -> PartitionSpec.

    strict=True (jit in/out shardings): the runtime rejects non-divisible
    argument shardings, so such entries are dropped (replicated).
    strict=False (with_sharding_constraint on intermediates): GSPMD pads, so
    entries are kept while padding waste stays <= 50% (e.g. 36 heads over a
    16-way axis pad to 48; §Roofline's useful-FLOPs ratio measures the waste).
    """
    out = []
    for i, entry in enumerate(logical):
        if entry is None:
            out.append(None)
            continue
        ax = _axis_size(mesh, entry)
        if shape is not None and i < len(shape):
            dim = shape[i]
            if dim % ax != 0:
                if strict:
                    out.append(None)
                    continue
                padded = ((dim + ax - 1) // ax) * ax
                if (padded - dim) / padded > 0.5:
                    out.append(None)
                    continue
        out.append(resolve(entry, mesh))
    return P(*out)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint if a mesh context is active; no-op otherwise
    (keeps smoke tests mesh-free)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if _STRATEGY.get() == "fsdp2d":
        logical = tuple(None if e == MODEL else e for e in logical)
    spec = concretize(tuple(logical), mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter rules (matched against '/'-joined tree paths)
# ---------------------------------------------------------------------------

# order matters: first match wins
_PARAM_RULES = [
    (r"emb/tok$", (MODEL, DATA)),          # [V, D]
    (r"emb/head$", (DATA, MODEL)),         # [D, V]
    (r"emb/pos$", (None, None)),
    (r"(wq|bq)$", (DATA, MODEL, None)),    # [D, H, Dh] / [H, Dh]
    (r"(wk|wv|bk|bv)$", (DATA, MODEL, None)),
    (r"wo$", (MODEL, None, DATA)),         # [H, Dh, D]
    (r"(w1|wg)$", (DATA, MODEL)),          # [D, F]
    (r"w2$", (MODEL, DATA)),               # [F, D]
    (r"router$", (DATA, None)),            # [D, E]
    (r"(we1|weg)$", (MODEL, DATA, None)),  # [E, D, F]
    (r"we2$", (MODEL, None, DATA)),        # [E, F, D]
    (r"in_proj$", (DATA, MODEL)),
    (r"out_proj$", (MODEL, DATA)),
    (r"conv_w$", (None, MODEL)),           # [K, conv_dim]
    (r"conv_b$", (MODEL,)),
    (r"(A_log|ssm_D|dt_bias)$", (None,)),
    (r"(wx|wgate)$", (DATA, MODEL)),       # rglru projections [D, W]
    (r"(ga_w|gi_w)$", (MODEL, None, None)),  # [heads, W/h, W/h]
    (r"(ga_b|gi_b|lambda_p)$", (MODEL, None)),  # [heads, W/h] / [W]-ish
    (r"(scale|bias)$", None),              # norms: replicate
]


def _rule_for(path: str):
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path):
            return spec
    return None


def _bias_like(spec, ndim):
    """Trim a weight rule to a lower-rank param (biases etc.)."""
    if spec is None:
        return None
    return tuple(spec[-ndim:])


def partition_specs(params: Any, mesh: jax.sharding.Mesh, *,
                    mode: str = "train") -> Any:
    """Pytree of PartitionSpec matching ``params`` (arrays or
    ShapeDtypeStructs).

    mode='train': FSDP(data) x TP(model).  mode='serve': TP only (DATA->None).
    Params under a 'stack'/'enc_stack' subtree carry an extra leading
    (scan) dim that is never sharded.
    """

    def one(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        pstr = "/".join(str(k) for k in keys)
        stacked = any(str(k) in ("stack", "enc_stack") for k in keys)
        spec = _rule_for(pstr)
        shape = tuple(leaf.shape)
        ndim = len(shape) - (1 if stacked else 0)
        if spec is None:
            logical = (None,) * ndim
        else:
            logical = _bias_like(spec, ndim)
            logical = tuple(logical) + (None,) * (ndim - len(logical))
        if mode == "serve":
            logical = tuple(None if e == DATA else e for e in logical)
        if stacked:
            logical = (None,) + logical
        return concretize(logical, mesh, shape, strict=True)

    return jax.tree_util.tree_map_with_path(one, params)


def shardings_for(params: Any, mesh: jax.sharding.Mesh, *, mode="train"):
    specs = partition_specs(params, mesh, mode=mode)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
