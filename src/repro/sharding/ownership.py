"""Two-level ownership map: node id -> virtual bucket -> shard (DESIGN.md §10).

The seed's ``owner_of`` was a static hash ``(hash(src) >> 8) % S`` — total and
cheap, but frozen: changing the shard count rewrites every node's owner, and a
hot shard (Zipf src skew) cannot shed load without moving *individual nodes*.
The classic fix (consistent-hashing virtual nodes, Dynamo-style) is a small
indirection table: nodes hash into ``num_buckets`` **virtual buckets** (far
more buckets than shards) and an explicit ``assignment[bucket] -> shard``
table maps buckets to owners.  Reassigning one bucket moves ~1/num_buckets of
the key space; restoring a snapshot onto M shards is just the default
assignment at M (`persist/reshard.py` re-routes the live edges).

The default assignment ``bucket % num_shards`` reproduces the seed routing
bit-for-bit whenever ``num_shards`` divides ``num_buckets`` (every power-of-two
shard count up to ``num_buckets``), because ``x % B % S == x % S`` when S | B.

Frozen and hashable: the assignment is a tuple, so an ``Ownership`` can ride
inside the static ``ShardedConfig`` and bake into jitted routing programs as a
constant — reassignment builds new programs, which is the right cost model
(rebalancing is rare; routing is the hot path).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.hashtable import hash_u32


@dataclasses.dataclass(frozen=True)
class Ownership:
    """hash -> virtual bucket -> shard map.  ``assignment=()`` means the
    default ``bucket % num_shards`` (seed-compatible, see module docstring)."""

    num_shards: int
    num_buckets: int = 256
    assignment: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {self.num_shards}")
        if self.num_buckets & (self.num_buckets - 1) or self.num_buckets < 1:
            raise ValueError(
                f"num_buckets must be a power of two, got {self.num_buckets}")
        if self.assignment:
            if len(self.assignment) != self.num_buckets:
                raise ValueError(
                    f"assignment has {len(self.assignment)} entries for "
                    f"{self.num_buckets} buckets")
            bad = [s for s in self.assignment
                   if not 0 <= s < self.num_shards]
            if bad:
                raise ValueError(
                    f"assignment targets out-of-range shards {sorted(set(bad))} "
                    f"(num_shards={self.num_shards})")

    # ------------------------------------------------------------------
    def resolved_assignment(self) -> Tuple[int, ...]:
        if self.assignment:
            return self.assignment
        return tuple(b % self.num_shards for b in range(self.num_buckets))

    def table(self) -> jax.Array:
        """The bucket -> shard table as an int32 device constant."""
        return jnp.asarray(self.resolved_assignment(), jnp.int32)

    # ------------------------------------------------------------------
    def bucket_of(self, src: jax.Array) -> jax.Array:
        """Virtual bucket of a node id.  Uses the high mix bits so the src
        hash table inside each shard (low bits) stays well distributed."""
        return ((hash_u32(src) >> jnp.uint32(8))
                % jnp.uint32(self.num_buckets)).astype(jnp.int32)

    def owner_of(self, src: jax.Array) -> jax.Array:
        """Owner shard of a node id: total and static for a fixed map."""
        return self.table()[self.bucket_of(src)]

    # ------------------------------------------------------------------
    def reassign(self, bucket: int, shard: int) -> "Ownership":
        """Move one virtual bucket to ``shard`` (the rebalancing primitive:
        ~1/num_buckets of the key space migrates)."""
        if not 0 <= bucket < self.num_buckets:
            raise ValueError(f"bucket {bucket} out of range")
        assign = list(self.resolved_assignment())
        assign[bucket] = shard
        return dataclasses.replace(self, assignment=tuple(assign))

    def with_num_shards(self, num_shards: int) -> "Ownership":
        """Default map at a different shard count (N -> M reshard-on-restore:
        the bucket level is shard-count-invariant, only the table changes)."""
        return Ownership(num_shards=num_shards, num_buckets=self.num_buckets)

    def shards_of_buckets(self) -> Tuple[Tuple[int, ...], ...]:
        """Buckets grouped per shard — the inspection view rebalancers use."""
        groups: list = [[] for _ in range(self.num_shards)]
        for b, s in enumerate(self.resolved_assignment()):
            groups[s].append(b)
        return tuple(tuple(g) for g in groups)
