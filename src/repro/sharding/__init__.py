from repro.sharding.specs import (  # noqa: F401
    batch_axes,
    constrain,
    current_mesh,
    partition_specs,
    resolve,
)
