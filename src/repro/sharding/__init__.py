from repro.sharding.ownership import Ownership  # noqa: F401
from repro.sharding.specs import (  # noqa: F401
    batch_axes,
    constrain,
    current_mesh,
    partition_specs,
    resolve,
)
