"""Fault-tolerance runtime: straggler watchdog, failure policy, elastic mesh.

On a real pod these hooks wire into the launcher (SIGTERM from the resource
manager, ICI heartbeat failures, per-step deadlines).  The policies are pure
and unit-testable here; the container can only simulate events.

Flow (train.py): every step runs under ``StepWatchdog``; a missed deadline
increments the straggler count and (policy) triggers a checkpoint-now; a
device failure raises, the launcher calls ``plan_elastic_remesh`` to get the
largest healthy mesh, and ``ckpt.restore`` re-shards onto it — training
resumes within one checkpoint interval (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class WatchdogConfig:
    deadline_s: float = 60.0          # per-step wall-clock budget
    max_consecutive_slow: int = 3     # then escalate
    checkpoint_on_escalate: bool = True


class StepWatchdog:
    """Per-step deadline monitor (straggler mitigation, host side).

    On TPU pods, a straggling step usually means a flaky host or a
    pre-empted neighbour; the mitigation at this layer is (1) record, (2)
    escalate to checkpoint-now so a kill loses nothing, (3) let the launcher
    decide on re-mesh.  Detection must be host-side wall clock — device-side
    collectives just hang.
    """

    def __init__(self, cfg: WatchdogConfig,
                 on_escalate: Optional[Callable[[], None]] = None):
        self.cfg = cfg
        self.on_escalate = on_escalate
        self.slow_steps: List[Tuple[int, float]] = []
        self._consecutive = 0
        self._step = 0

    def observe(self, duration_s: float) -> bool:
        """Record one step duration. Returns True if escalation fired."""
        self._step += 1
        if duration_s > self.cfg.deadline_s:
            self.slow_steps.append((self._step, duration_s))
            self._consecutive += 1
        else:
            self._consecutive = 0
        if self._consecutive >= self.cfg.max_consecutive_slow:
            self._consecutive = 0
            if self.on_escalate is not None:
                self.on_escalate()
            return True
        return False

    def timed(self, fn, *args, **kw):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        self.observe(time.monotonic() - t0)
        return out


def plan_elastic_remesh(total_devices: int, failed_devices: int,
                        model_axis: int) -> Tuple[int, int]:
    """Largest (data, model) mesh on the healthy devices.

    Keeps the model axis fixed (weight shards must still fit) and shrinks the
    data axis — batch is re-balanced, optimizer state re-sharded on restore.
    Returns (data_axis, model_axis); raises if nothing fits.
    """
    healthy = total_devices - failed_devices
    if healthy < model_axis:
        raise RuntimeError(
            f"{healthy} healthy devices cannot host model axis {model_axis}")
    data_axis = healthy // model_axis
    return data_axis, model_axis


@dataclasses.dataclass
class FailurePolicy:
    """What the launcher does per event class."""

    checkpoint_interval_steps: int = 200

    def on_step_failure(self, consecutive_failures: int) -> str:
        # transient XLA/ICI error: retry once, then restart from checkpoint
        return "retry" if consecutive_failures < 2 else "restore"

    def on_device_loss(self) -> str:
        return "remesh_restore"

    def on_preemption_notice(self) -> str:
        return "checkpoint_now"
