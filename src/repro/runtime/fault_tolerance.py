"""Fault-tolerance runtime: straggler watchdog, failure policy, elastic mesh.

On a real pod these hooks wire into the launcher (SIGTERM from the resource
manager, ICI heartbeat failures, per-step deadlines).  The policies are pure
and unit-testable here; the container can only simulate events.

Flow (train.py): every step runs under ``StepWatchdog``; a missed deadline
increments the straggler count and (policy) triggers a checkpoint-now; a
device failure raises, the launcher calls ``plan_elastic_remesh`` to get the
largest healthy mesh, and ``ckpt.restore`` re-shards onto it — training
resumes within one checkpoint interval (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import errno as _errno
import random
import threading
import time
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WatchdogConfig:
    deadline_s: float = 60.0          # per-step wall-clock budget
    max_consecutive_slow: int = 3     # then escalate
    checkpoint_on_escalate: bool = True


class StepWatchdog:
    """Per-step deadline monitor (straggler mitigation, host side).

    On TPU pods, a straggling step usually means a flaky host or a
    pre-empted neighbour; the mitigation at this layer is (1) record, (2)
    escalate to checkpoint-now so a kill loses nothing, (3) let the launcher
    decide on re-mesh.  Detection must be host-side wall clock — device-side
    collectives just hang.
    """

    def __init__(self, cfg: WatchdogConfig,
                 on_escalate: Optional[Callable[[], None]] = None):
        self.cfg = cfg
        self.on_escalate = on_escalate
        self.slow_steps: List[Tuple[int, float]] = []
        self._consecutive = 0
        self._step = 0

    def observe(self, duration_s: float) -> bool:
        """Record one step duration. Returns True if escalation fired."""
        self._step += 1
        if duration_s > self.cfg.deadline_s:
            self.slow_steps.append((self._step, duration_s))
            self._consecutive += 1
        else:
            self._consecutive = 0
        if self._consecutive >= self.cfg.max_consecutive_slow:
            self._consecutive = 0
            if self.on_escalate is not None:
                self.on_escalate()
            return True
        return False

    def timed(self, fn, *args, **kw):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        self.observe(time.monotonic() - t0)
        return out


def plan_elastic_remesh(total_devices: int, failed_devices: int,
                        model_axis: int) -> Tuple[int, int]:
    """Largest (data, model) mesh on the healthy devices.

    Keeps the model axis fixed (weight shards must still fit) and shrinks the
    data axis — batch is re-balanced, optimizer state re-sharded on restore.
    Returns (data_axis, model_axis); raises if nothing fits.
    """
    healthy = total_devices - failed_devices
    if healthy < model_axis:
        raise RuntimeError(
            f"{healthy} healthy devices cannot host model axis {model_axis}")
    data_axis = healthy // model_axis
    return data_axis, model_axis


@dataclasses.dataclass
class FailurePolicy:
    """What the launcher does per event class."""

    checkpoint_interval_steps: int = 200

    def on_step_failure(self, consecutive_failures: int) -> str:
        # transient XLA/ICI error: retry once, then restart from checkpoint
        return "retry" if consecutive_failures < 2 else "restore"

    def on_device_loss(self) -> str:
        return "remesh_restore"

    def on_preemption_notice(self) -> str:
        return "checkpoint_now"


# ---------------------------------------------------------------------------
# retry / escalation layer (DESIGN.md §12)
# ---------------------------------------------------------------------------


class RetryBudgetExceeded(RuntimeError):
    """A transient fault survived every retry attempt; escalate."""


class EngineWriteUnavailable(RuntimeError):
    """The engine's write path is poisoned after an escalated persistent
    fault; reads keep serving the last published epoch, writes raise this
    until ``restore()`` heals the WAL position (DESIGN.md §12, A13)."""


class UnretryableIOError(OSError):
    """An IO fault that must escalate WITHOUT retry even though its errno
    looks transient — the operation is not idempotent from where it
    failed.  Canonical case: a rotation fsync failing under WAL policy
    ``rotate`` (the durability point of the whole segment); retrying the
    *append* there would re-log an already-written record under a new
    seq and double-apply it on replay."""


class ShardDispatchError(RuntimeError):
    """A dispatch failure attributable to ONE shard (a per-shard RPC
    timing out, a device owned by that shard lost).  Carries ``.shard``
    so the engine's strike path can take that shard down automatically
    after ``health_strikes`` consecutive escalations; unattributable
    dispatch faults degrade the call but strike nobody."""

    def __init__(self, shard: int, message: str = ""):
        super().__init__(
            message or f"dispatch failed against shard {shard}")
        self.shard = int(shard)


def shard_from_exception(exc: Optional[BaseException]) -> Optional[int]:
    """Extract the striking shard id from an exception's cause/context
    chain (``RetryBudgetExceeded`` chains the last fault as its cause);
    None when no link carries a ``.shard``."""
    hops = 0
    while exc is not None and hops < 8:
        shard = getattr(exc, "shard", None)
        if isinstance(shard, int):
            return shard
        exc = exc.__cause__ or exc.__context__
        hops += 1
    return None


#: errnos that retrying cannot fix: the disk is full/read-only/over quota
#: or the file is unreachable — escalate immediately (checkpoint-now /
#: degraded mode), never spin (A13).
PERSISTENT_ERRNOS = frozenset({
    _errno.ENOSPC, _errno.EROFS, _errno.EDQUOT, _errno.EACCES,
    _errno.EPERM, _errno.ENAMETOOLONG,
})


def classify_io_error(exc: BaseException) -> str:
    """``"persistent"`` (retry cannot help) or ``"transient"``.

    OSErrors are classified by errno; anything non-OSError coming out of
    an IO edge (a dead thread, a device dispatch failure) is treated as
    transient — one retry round is cheap and device hiccups recover.
    :class:`UnretryableIOError` is persistent whatever its errno: the
    raiser is telling us the operation cannot be retried from where it
    failed (see the class docstring).
    """
    if isinstance(exc, UnretryableIOError):
        return "persistent"
    if isinstance(exc, OSError) and exc.errno in PERSISTENT_ERRNOS:
        return "persistent"
    return "transient"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``max_attempts`` counts total tries (first call included).  The delay
    before retry ``k`` (1-based) is ``base * 2**(k-1)`` capped at
    ``max_delay_s``, scaled by a jitter factor drawn uniformly from
    ``[1 - jitter, 1]`` out of a stream seeded by ``seed`` — two engines
    retrying the same fault decorrelate, one engine replays exactly.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.005
    max_delay_s: float = 0.5
    jitter: float = 0.5
    seed: int = 0

    def delays(self):
        rng = random.Random(self.seed)
        for k in range(1, self.max_attempts):
            raw = min(self.base_delay_s * (2.0 ** (k - 1)),
                      self.max_delay_s)
            yield raw * (1.0 - self.jitter * rng.random())


def call_with_retry(fn: Callable[[], object], *,
                    policy: Optional[RetryPolicy] = None,
                    classify: Callable[[BaseException], str]
                    = classify_io_error,
                    retry_on: Tuple[type, ...] = (Exception,),
                    on_retry: Optional[Callable[[int, BaseException],
                                                None]] = None,
                    sleep: Callable[[float], None] = time.sleep,
                    metrics=None):
    """Run ``fn`` under the retry ladder.

    Transient faults back off and retry up to ``policy.max_attempts``
    total tries; a persistent fault re-raises immediately (escalation is
    the caller's job); an exhausted budget raises
    :class:`RetryBudgetExceeded` from the last fault.  ``on_retry`` is
    called with ``(attempt_index, exc)`` before each backoff sleep —
    the engine counts these into ``stats``.  ``metrics`` (an
    ``obs.Registry``) records each backoff delay into the
    ``retry.backoff`` histogram (DESIGN.md §13), so the ladder's actual
    sleep distribution is observable, not just its retry counts.
    """
    policy = policy or RetryPolicy()
    last: Optional[BaseException] = None
    delays = policy.delays()
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retry_on as exc:
            if classify(exc) == "persistent":
                raise
            last = exc
            try:
                delay = next(delays)
            except StopIteration:
                break
            if on_retry is not None:
                on_retry(attempt + 1, exc)
            if metrics is not None:
                metrics.hist_record("retry.backoff", delay)
            sleep(delay)
    raise RetryBudgetExceeded(
        f"{policy.max_attempts} attempts exhausted: {last!r}") from last


class ShardHealth:
    """Per-shard health map for graceful degradation (DESIGN.md §12).

    Writers mark a shard down after ``strike_limit`` consecutive
    dispatch failures; routed reads exclude down shards via
    :meth:`healthy_mask` (answers stay sorted-descending from the
    survivors, ``degraded_answers`` counted by the engine); writes bound
    for a down shard queue here (bounded by ``deferred_cap`` items
    total) and drain on :meth:`heal`.  Readers never take the mutex: the
    down-set is an immutable frozenset swapped atomically, so a query
    thread observes either the old or the new set, never a torn one —
    the same publish idiom as the epoch store.

    The down-set and deferred queue are recovery state (A15): they ride
    snapshot meta via :meth:`dump`/:meth:`load` because snapshot-cadence
    WAL GC may unlink the deferred batches' original log records.
    Strikes are transient and never persisted.
    """

    _MCQ_LOCK_ORDER = ("_mu",)
    _MCQ_LOCK_PROTECTS = {
        "_mu": ("_down", "_strikes", "_deferred", "_deferred_items"),
    }

    def __init__(self, num_shards: int, *, strike_limit: int = 3,
                 deferred_cap: int = 4096):
        self.num_shards = int(num_shards)
        self.strike_limit = int(strike_limit)
        self.deferred_cap = int(deferred_cap)
        self._mu = threading.Lock()
        self._down: FrozenSet[int] = frozenset()
        self._strikes: Dict[int, int] = {}
        self._deferred: Dict[int, list] = {}
        self._deferred_items = 0

    # -- read side (lock-free) -----------------------------------------
    @property
    def down(self) -> FrozenSet[int]:
        return self._down

    @property
    def degraded(self) -> bool:
        return bool(self._down)

    def healthy_mask(self) -> np.ndarray:
        """bool[num_shards], True where the shard serves reads."""
        mask = np.ones(self.num_shards, dtype=bool)
        for s in self._down:
            mask[s] = False
        return mask

    # -- write side ----------------------------------------------------
    def record_failure(self, shard: int) -> bool:
        """One dispatch failure against ``shard``; returns True when this
        strike marks it down (caller escalates to degraded mode)."""
        with self._mu:
            if shard in self._down:
                return False
            n = self._strikes.get(shard, 0) + 1
            self._strikes[shard] = n
            if n < self.strike_limit:
                return False
            self._down = self._down | {shard}
            self._strikes.pop(shard, None)
            return True

    def record_success(self, shard: int) -> None:
        with self._mu:
            self._strikes.pop(shard, None)

    def record_success_all(self) -> None:
        """A whole-mesh dispatch succeeded: every shard answered, so all
        strike streaks break (the down-set is untouched).  Cheap racy
        emptiness peek first — the common healthy path takes no lock."""
        if not self._strikes:
            return
        with self._mu:
            self._strikes.clear()

    def mark_down(self, shard: int) -> None:
        with self._mu:
            self._down = self._down | {shard}
            self._strikes.pop(shard, None)

    def defer(self, shard: int, src, dst, w) -> bool:
        """Queue one write batch for a down shard; False = cap reached
        and the batch is dropped (counted by the caller)."""
        with self._mu:
            n = int(np.asarray(src).size)
            if self._deferred_items + n > self.deferred_cap:
                return False
            self._deferred.setdefault(shard, []).append(
                (np.asarray(src).copy(), np.asarray(dst).copy(),
                 np.asarray(w).copy() if w is not None else None))
            self._deferred_items += n
            return True

    def heal(self, shard: int) -> List[tuple]:
        """Re-admit ``shard``; returns its deferred write batches in
        arrival order for the caller to re-apply."""
        with self._mu:
            self._down = self._down - {shard}
            self._strikes.pop(shard, None)
            batches = self._deferred.pop(shard, [])
            self._deferred_items -= sum(int(b[0].size) for b in batches)
            return batches

    def requeue(self, shard: int, batches: List[tuple]) -> None:
        """Push back batches :meth:`heal` popped but the caller could not
        apply, at the FRONT of the shard's queue (arrival order holds) and
        cap-exempt — they were admitted under the cap once already, so a
        failed heal must not convert them into drops."""
        if not batches:
            return
        with self._mu:
            self._deferred[shard] = (list(batches)
                                     + self._deferred.get(shard, []))
            self._deferred_items += sum(int(b[0].size) for b in batches)

    def dump(self) -> dict:
        """JSON-serialisable image of the recovery-relevant state (the
        down-set and the deferred queue; strikes are transient and omitted)
        for snapshot meta.  ``deferred`` is a flat ``[shard, src, dst, w]``
        list in per-shard arrival order."""
        with self._mu:
            return {
                "down": sorted(self._down),
                "deferred": [
                    [shard, b[0].tolist(), b[1].tolist(),
                     None if b[2] is None else b[2].tolist()]
                    for shard in sorted(self._deferred)
                    for b in self._deferred[shard]],
            }

    def load(self, image: dict) -> None:
        """Replace the health state with a :meth:`dump` image (restore
        path): the live down-set, strikes and deferred queue are discarded
        — recovery state comes from the snapshot, never from the
        pre-restore process (A15)."""
        with self._mu:
            self._down = frozenset(int(s) for s in image.get("down", ()))
            self._strikes = {}
            self._deferred = {}
            self._deferred_items = 0
            for shard, src, dst, w in image.get("deferred", ()):
                src = np.asarray(src, np.int32)
                self._deferred.setdefault(int(shard), []).append(
                    (src, np.asarray(dst, np.int32),
                     None if w is None else np.asarray(w, np.int32)))
                self._deferred_items += int(src.size)

    def stats(self) -> Dict[str, int]:
        with self._mu:
            return {"shards_down": len(self._down),
                    "deferred_writes": self._deferred_items}
