"""Fault-tolerance runtime: straggler watchdog, failure policy, elastic mesh.

On a real pod these hooks wire into the launcher (SIGTERM from the resource
manager, ICI heartbeat failures, per-step deadlines).  The policies are pure
and unit-testable here; the container can only simulate events.

Flow (train.py): every step runs under ``StepWatchdog``; a missed deadline
increments the straggler count and (policy) triggers a checkpoint-now; a
device failure raises, the launcher calls ``plan_elastic_remesh`` to get the
largest healthy mesh, and ``ckpt.restore`` re-shards onto it — training
resumes within one checkpoint interval (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import errno as _errno
import random
import threading
import time
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WatchdogConfig:
    deadline_s: float = 60.0          # per-step wall-clock budget
    max_consecutive_slow: int = 3     # then escalate
    checkpoint_on_escalate: bool = True


class StepWatchdog:
    """Per-step deadline monitor (straggler mitigation, host side).

    On TPU pods, a straggling step usually means a flaky host or a
    pre-empted neighbour; the mitigation at this layer is (1) record, (2)
    escalate to checkpoint-now so a kill loses nothing, (3) let the launcher
    decide on re-mesh.  Detection must be host-side wall clock — device-side
    collectives just hang.
    """

    def __init__(self, cfg: WatchdogConfig,
                 on_escalate: Optional[Callable[[], None]] = None):
        self.cfg = cfg
        self.on_escalate = on_escalate
        self.slow_steps: List[Tuple[int, float]] = []
        self._consecutive = 0
        self._step = 0

    def observe(self, duration_s: float) -> bool:
        """Record one step duration. Returns True if escalation fired."""
        self._step += 1
        if duration_s > self.cfg.deadline_s:
            self.slow_steps.append((self._step, duration_s))
            self._consecutive += 1
        else:
            self._consecutive = 0
        if self._consecutive >= self.cfg.max_consecutive_slow:
            self._consecutive = 0
            if self.on_escalate is not None:
                self.on_escalate()
            return True
        return False

    def timed(self, fn, *args, **kw):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        self.observe(time.monotonic() - t0)
        return out


def plan_elastic_remesh(total_devices: int, failed_devices: int,
                        model_axis: int) -> Tuple[int, int]:
    """Largest (data, model) mesh on the healthy devices.

    Keeps the model axis fixed (weight shards must still fit) and shrinks the
    data axis — batch is re-balanced, optimizer state re-sharded on restore.
    Returns (data_axis, model_axis); raises if nothing fits.
    """
    healthy = total_devices - failed_devices
    if healthy < model_axis:
        raise RuntimeError(
            f"{healthy} healthy devices cannot host model axis {model_axis}")
    data_axis = healthy // model_axis
    return data_axis, model_axis


@dataclasses.dataclass
class FailurePolicy:
    """What the launcher does per event class."""

    checkpoint_interval_steps: int = 200

    def on_step_failure(self, consecutive_failures: int) -> str:
        # transient XLA/ICI error: retry once, then restart from checkpoint
        return "retry" if consecutive_failures < 2 else "restore"

    def on_device_loss(self) -> str:
        return "remesh_restore"

    def on_preemption_notice(self) -> str:
        return "checkpoint_now"


# ---------------------------------------------------------------------------
# retry / escalation layer (DESIGN.md §12)
# ---------------------------------------------------------------------------


class RetryBudgetExceeded(RuntimeError):
    """A transient fault survived every retry attempt; escalate."""


class EngineWriteUnavailable(RuntimeError):
    """The engine's write path is poisoned after an escalated persistent
    fault; reads keep serving the last published epoch, writes raise this
    until ``restore()`` heals the WAL position (DESIGN.md §12, A13)."""


#: errnos that retrying cannot fix: the disk is full/read-only/over quota
#: or the file is unreachable — escalate immediately (checkpoint-now /
#: degraded mode), never spin (A13).
PERSISTENT_ERRNOS = frozenset({
    _errno.ENOSPC, _errno.EROFS, _errno.EDQUOT, _errno.EACCES,
    _errno.EPERM, _errno.ENAMETOOLONG,
})


def classify_io_error(exc: BaseException) -> str:
    """``"persistent"`` (retry cannot help) or ``"transient"``.

    OSErrors are classified by errno; anything non-OSError coming out of
    an IO edge (a dead thread, a device dispatch failure) is treated as
    transient — one retry round is cheap and device hiccups recover.
    """
    if isinstance(exc, OSError) and exc.errno in PERSISTENT_ERRNOS:
        return "persistent"
    return "transient"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic jitter.

    ``max_attempts`` counts total tries (first call included).  The delay
    before retry ``k`` (1-based) is ``base * 2**(k-1)`` capped at
    ``max_delay_s``, scaled by a jitter factor drawn uniformly from
    ``[1 - jitter, 1]`` out of a stream seeded by ``seed`` — two engines
    retrying the same fault decorrelate, one engine replays exactly.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.005
    max_delay_s: float = 0.5
    jitter: float = 0.5
    seed: int = 0

    def delays(self):
        rng = random.Random(self.seed)
        for k in range(1, self.max_attempts):
            raw = min(self.base_delay_s * (2.0 ** (k - 1)),
                      self.max_delay_s)
            yield raw * (1.0 - self.jitter * rng.random())


def call_with_retry(fn: Callable[[], object], *,
                    policy: Optional[RetryPolicy] = None,
                    classify: Callable[[BaseException], str]
                    = classify_io_error,
                    retry_on: Tuple[type, ...] = (Exception,),
                    on_retry: Optional[Callable[[int, BaseException],
                                                None]] = None,
                    sleep: Callable[[float], None] = time.sleep):
    """Run ``fn`` under the retry ladder.

    Transient faults back off and retry up to ``policy.max_attempts``
    total tries; a persistent fault re-raises immediately (escalation is
    the caller's job); an exhausted budget raises
    :class:`RetryBudgetExceeded` from the last fault.  ``on_retry`` is
    called with ``(attempt_index, exc)`` before each backoff sleep —
    the engine counts these into ``stats``.
    """
    policy = policy or RetryPolicy()
    last: Optional[BaseException] = None
    delays = policy.delays()
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retry_on as exc:
            if classify(exc) == "persistent":
                raise
            last = exc
            try:
                delay = next(delays)
            except StopIteration:
                break
            if on_retry is not None:
                on_retry(attempt + 1, exc)
            sleep(delay)
    raise RetryBudgetExceeded(
        f"{policy.max_attempts} attempts exhausted: {last!r}") from last


class ShardHealth:
    """Per-shard health map for graceful degradation (DESIGN.md §12).

    Writers mark a shard down after ``strike_limit`` consecutive
    dispatch failures; routed reads exclude down shards via
    :meth:`healthy_mask` (answers stay sorted-descending from the
    survivors, ``degraded_answers`` counted by the engine); writes bound
    for a down shard queue here (bounded by ``deferred_cap`` items
    total) and drain on :meth:`heal`.  Readers never take the mutex: the
    down-set is an immutable frozenset swapped atomically, so a query
    thread observes either the old or the new set, never a torn one —
    the same publish idiom as the epoch store.
    """

    _MCQ_LOCK_ORDER = ("_mu",)
    _MCQ_LOCK_PROTECTS = {
        "_mu": ("_down", "_strikes", "_deferred", "_deferred_items"),
    }

    def __init__(self, num_shards: int, *, strike_limit: int = 3,
                 deferred_cap: int = 4096):
        self.num_shards = int(num_shards)
        self.strike_limit = int(strike_limit)
        self.deferred_cap = int(deferred_cap)
        self._mu = threading.Lock()
        self._down: FrozenSet[int] = frozenset()
        self._strikes: Dict[int, int] = {}
        self._deferred: Dict[int, list] = {}
        self._deferred_items = 0

    # -- read side (lock-free) -----------------------------------------
    @property
    def down(self) -> FrozenSet[int]:
        return self._down

    @property
    def degraded(self) -> bool:
        return bool(self._down)

    def healthy_mask(self) -> np.ndarray:
        """bool[num_shards], True where the shard serves reads."""
        mask = np.ones(self.num_shards, dtype=bool)
        for s in self._down:
            mask[s] = False
        return mask

    # -- write side ----------------------------------------------------
    def record_failure(self, shard: int) -> bool:
        """One dispatch failure against ``shard``; returns True when this
        strike marks it down (caller escalates to degraded mode)."""
        with self._mu:
            if shard in self._down:
                return False
            n = self._strikes.get(shard, 0) + 1
            self._strikes[shard] = n
            if n < self.strike_limit:
                return False
            self._down = self._down | {shard}
            self._strikes.pop(shard, None)
            return True

    def record_success(self, shard: int) -> None:
        with self._mu:
            self._strikes.pop(shard, None)

    def mark_down(self, shard: int) -> None:
        with self._mu:
            self._down = self._down | {shard}
            self._strikes.pop(shard, None)

    def defer(self, shard: int, src, dst, w) -> bool:
        """Queue one write batch for a down shard; False = cap reached
        and the batch is dropped (counted by the caller)."""
        with self._mu:
            n = int(np.asarray(src).size)
            if self._deferred_items + n > self.deferred_cap:
                return False
            self._deferred.setdefault(shard, []).append(
                (np.asarray(src).copy(), np.asarray(dst).copy(),
                 np.asarray(w).copy() if w is not None else None))
            self._deferred_items += n
            return True

    def heal(self, shard: int) -> List[tuple]:
        """Re-admit ``shard``; returns its deferred write batches in
        arrival order for the caller to re-apply."""
        with self._mu:
            self._down = self._down - {shard}
            self._strikes.pop(shard, None)
            batches = self._deferred.pop(shard, [])
            self._deferred_items -= sum(int(b[0].size) for b in batches)
            return batches

    def stats(self) -> Dict[str, int]:
        with self._mu:
            return {"shards_down": len(self._down),
                    "deferred_writes": self._deferred_items}
