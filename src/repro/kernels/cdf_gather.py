"""Pallas TPU kernel: fused row-gather + CDF threshold walk (paper §II.B).

The unfused inference path materialises every queried row's counts/dsts in
priority order on the host side (``mcprioq._ordered_rows``: three O(B*C)
``take_along_axis`` gathers) before ``cdf_query`` ever launches — O(B*C)
memory traffic regardless of the threshold.  This kernel makes the read side
honor the paper's O(CDF^-1(t)) bound at the traffic level: the queried row
indices arrive via **scalar prefetch** (``pltpu.PrefetchScalarGridSpec``), so
each grid instance's BlockSpec index map points the DMA engine straight at
``cnt/dst/order[rows[i]]`` in the slab arrays — only queried rows ever move,
and the order-gather (slot permutation -> priority order) happens on the
VMEM-resident row tile inside the kernel, chunk by chunk inside the
predicated walk body, so skipped chunks do no gather work.

The walk itself is ``cdf_query.walk_chunks`` — same integer-exact cumulative
semantics, same ``@pl.when`` chunk predication, but with a **one-query
block** the early exit is per-row exact, not block-granular: each query
stops touching lanes the moment its own cumulative count crosses the
threshold.

Semantics oracle: ``ref.cdf_query_fused_ref`` (single fused advanced-index
gather + the shared ref walk); bit-identical to the unfused path by the
integer-walk contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cdf_query import walk_chunks


def _fused_kernel(rows_ref, cnt_ref, dst_ref, ord_ref, tot_ref, found_ref,
                  t_ref, dst_out_ref, prob_out_ref, n_out_ref, carry_ref,
                  *, max_items: int, chunks: int, topk: bool):
    # cnt/dst/ord_ref are the (1, C) tiles of THIS query's row, DMA'd via
    # the scalar-prefetched row index.  The priority-order gather runs
    # chunk-by-chunk inside load(k) — i.e. inside the predicated walk body —
    # so a chunk skipped by the early exit does no gather work either.
    cap = cnt_ref.shape[-1]
    chunk = cap // chunks
    totf = jnp.maximum(tot_ref[...], 1).astype(jnp.float32)  # (1,)

    def load(k):
        ords = ord_ref[:, k * chunk:(k + 1) * chunk]       # (1, chunk)
        ck = jnp.take_along_axis(cnt_ref[...], ords, axis=1)
        ck = jnp.where(found_ref[...] > 0, ck, 0)          # unknown src -> 0
        dk = jnp.take_along_axis(dst_ref[...], ords, axis=1)
        return ck, dk

    walk_chunks(load, totf, t_ref[0], dst_out_ref, prob_out_ref, n_out_ref,
                carry_ref, cap=cap, max_items=max_items, chunks=chunks,
                topk=topk)


@functools.partial(
    jax.jit,
    static_argnames=("max_items", "chunks", "topk", "interpret"))
def cdf_query_fused_pallas(rows: jax.Array, found: jax.Array,
                           cnt: jax.Array, dst: jax.Array, order: jax.Array,
                           tot: jax.Array, threshold=0.0, *,
                           max_items: int = 16, chunks: int = 1,
                           topk: bool = False, interpret: bool = True):
    """rows[B] (pre-resolved, 0 where missing), found[B] int32 mask,
    cnt/dst/order: [N, C] slab arrays, tot: [N].  Returns
    (dsts[B, max_items], probs[B, max_items], n_needed[B]).
    """
    b = rows.shape[0]
    n, cap = cnt.shape
    assert cap % chunks == 0, (cap, chunks)
    t_arr = jnp.asarray([threshold], jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cap), lambda i, rows_ref: (rows_ref[i], 0)),
            pl.BlockSpec((1, cap), lambda i, rows_ref: (rows_ref[i], 0)),
            pl.BlockSpec((1, cap), lambda i, rows_ref: (rows_ref[i], 0)),
            pl.BlockSpec((1,), lambda i, rows_ref: (rows_ref[i],)),
            pl.BlockSpec((1,), lambda i, rows_ref: (i,)),
            pl.BlockSpec((1,), lambda i, rows_ref: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, max_items), lambda i, rows_ref: (i, 0)),
            pl.BlockSpec((1, max_items), lambda i, rows_ref: (i, 0)),
            pl.BlockSpec((1,), lambda i, rows_ref: (i,)),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, max_items=max_items, chunks=chunks,
                          topk=topk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, max_items), jnp.int32),
            jax.ShapeDtypeStruct((b, max_items), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(rows, cnt, dst, order, tot, found.astype(jnp.int32), t_arr)
