"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernels must match exactly
(integer ops) or to float tolerance (probability ops).  The oracles reuse the
core library where it defines the semantics (slab.py odd-even passes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.core.hashtable import EMPTY


def oddeven_ref(c_ord: jax.Array, order: jax.Array, passes: int):
    """k odd-even passes over counts-in-order + the order permutation.

    c_ord[N, C] are the counts *already gathered into order position* (the
    kernel-side layout); order[N, C] the slot permutation. Returns the pair
    after ``passes`` full (even+odd) sweeps, descending target.
    """
    for _ in range(passes):
        for start in (0, 1):
            left_c = c_ord[:, start:-1:2]
            right_c = c_ord[:, start + 1 :: 2]
            m = min(left_c.shape[1], right_c.shape[1])
            left_c, right_c = left_c[:, :m], right_c[:, :m]
            left_o = order[:, start:-1:2][:, :m]
            right_o = order[:, start + 1 :: 2][:, :m]
            swap = left_c < right_c
            nl_c = jnp.where(swap, right_c, left_c)
            nr_c = jnp.where(swap, left_c, right_c)
            nl_o = jnp.where(swap, right_o, left_o)
            nr_o = jnp.where(swap, left_o, right_o)
            c_ord = c_ord.at[:, start : start + 2 * m : 2].set(nl_c)
            c_ord = c_ord.at[:, start + 1 : start + 1 + 2 * m : 2].set(nr_c)
            order = order.at[:, start : start + 2 * m : 2].set(nl_o)
            order = order.at[:, start + 1 : start + 1 + 2 * m : 2].set(nr_o)
    return c_ord, order


def oddeven_on_slabs_ref(cnt: jax.Array, order: jax.Array, passes: int):
    """Same semantics as slab.oddeven_passes (permutation-only view)."""
    return sl.oddeven_passes(cnt, order, passes)


def slab_update_ref(rows: jax.Array, dsts: jax.Array, w: jax.Array,
                    dst: jax.Array, cnt: jax.Array, tot: jax.Array):
    """Fast-path batched edge increment (paper §II.A.2, existing edges only).

    For each item i: find slot of dsts[i] in row rows[i]; if present add w[i]
    to cnt and tot.  Items whose edge is absent are no-ops (the caller sends
    them down the slow path).  rows < 0 marks padding.
    """
    active = rows >= 0
    safe_rows = jnp.maximum(rows, 0)
    hit = dst[safe_rows] == dsts[:, None]          # [B, C]
    found = jnp.any(hit, axis=1) & active
    slot = jnp.argmax(hit, axis=1)
    addw = jnp.where(found, w, 0)
    cnt = cnt.at[safe_rows, slot].add(addw)
    tot = tot.at[safe_rows].add(addw)
    return dst, cnt, tot, found


def probe_find_ref(rows: jax.Array, keys_q: jax.Array,
                   keys: jax.Array, vals: jax.Array, max_probes: int):
    """Batched open-addressing probe (the shared lookup oracle).

    rows[B] select a table out of keys/vals[N, H]; rows < 0 marks padding.
    Covers both the per-row dst hash (paper §II.2, N = slab rows) and the
    flat src table (paper §II.1, N = 1).  Returns ``(slots[B], found[B])``
    with slot EMPTY when missing.

    Semantics are the core scalar probe (:func:`repro.core.hashtable.lookup`
    — scan from the home slot, stop at the key or the first EMPTY, give up
    after ``max_probes``) but vectorised the same way the Pallas kernel is:
    one (B, max_probes) window gather + min-reductions over probe positions,
    instead of a vmapped fori_loop (which XLA:CPU lowers to per-item scalar
    chains — the old O(B) probe loop this PR's read path removes).  First-
    occurrence equivalence holds even when the window wraps a small table:
    a slot's first visit time IS its probe position mod H.
    """
    h = keys.shape[1]
    safe_rows = jnp.maximum(rows, 0)
    h0 = (ht.hash_u32(keys_q) & jnp.uint32(h - 1)).astype(jnp.int32)
    p = jnp.arange(max_probes, dtype=jnp.int32)[None, :]       # (1, P)
    idx = (h0[:, None] + p) & (h - 1)                          # (B, P)
    win = keys[safe_rows[:, None], idx]                        # (B, P)
    big = jnp.int32(max_probes)
    key_p = jnp.min(jnp.where(win == keys_q[:, None], p, big), axis=1)
    empty_p = jnp.min(jnp.where(win == EMPTY, p, big), axis=1)
    found = (key_p < empty_p) & (rows >= 0)
    slot_idx = (h0 + jnp.minimum(key_p, big - 1)) & (h - 1)
    slots = vals[safe_rows, slot_idx]
    return jnp.where(found, slots, EMPTY), found


# the dst-hash entry point is the same probe; kept under its §II.2 name
dh_find_ref = probe_find_ref


def _needed_walk(c_ord: jax.Array, totf: jax.Array, threshold):
    """The A9 integer walk shared by every CDF oracle: which priority
    positions a reader needs, and how many (CDF^-1).  ``threshold=None`` is
    top-k mode (every live item)."""
    if threshold is None:
        needed = c_ord > 0
    else:
        cum = jnp.cumsum(c_ord, axis=1)
        before = (cum - c_ord).astype(jnp.float32)
        needed = (before < threshold * totf[:, None]) & (c_ord > 0)
    return needed, jnp.sum(needed.astype(jnp.int32), axis=1)


def _pad_items(dk: jax.Array, pk: jax.Array, max_items: int):
    """Pad the emission window out to ``max_items`` when it exceeds C, so
    the ref path returns the same (B, max_items) shape the kernels allocate
    (entries past C are always EMPTY/0 — a row has at most C items)."""
    pad = max_items - dk.shape[1]
    if pad > 0:
        dk = jnp.pad(dk, ((0, 0), (0, pad)), constant_values=EMPTY)
        pk = jnp.pad(pk, ((0, 0), (0, pad)))
    return dk, pk


def cdf_query_ref(c_ord: jax.Array, d_ord: jax.Array, tot: jax.Array,
                  threshold, max_items: int):
    """Cumulative-probability threshold query (paper §II.B).

    c_ord/d_ord[B, C]: counts/dsts gathered in descending-priority order
    (zeros for missing rows). Returns (dsts[B,k], probs[B,k], n_needed[B]).

    ``threshold=None`` is top-k mode: keep every live item (no threshold
    test).  The cumulative walk runs in exact integer count space —
    ``needed[j] = (sum(cnt[<j]) < t * tot) & (cnt[j] > 0)`` — so the result
    is independent of how a kernel chunks the walk (int prefix sums are
    association-free; float ones are not).  The only float ops, ``t * tot``
    and ``p = cnt / tot``, are per-row/per-item.
    """
    totf = jnp.maximum(tot, 1).astype(jnp.float32)
    needed, n_needed = _needed_walk(c_ord, totf, threshold)
    k = min(max_items, c_ord.shape[1])
    keep = needed[:, :k]
    pk_raw = c_ord[:, :k].astype(jnp.float32) / totf[:, None]
    dk = jnp.where(keep, d_ord[:, :k], EMPTY)
    pk = jnp.where(keep, pk_raw, 0.0)
    dk, pk = _pad_items(dk, pk, max_items)
    return dk, pk, n_needed


def cdf_query_fused_ref(rows: jax.Array, found: jax.Array,
                        cnt: jax.Array, dst: jax.Array, order: jax.Array,
                        tot: jax.Array, threshold, max_items: int):
    """Fused row-gather + CDF walk (oracle of ``cdf_gather.py``).

    rows[B] are pre-resolved row indices (0 where missing), found[B] the
    src-lookup mask; cnt/dst/order[N, C], tot[N] are the raw slab arrays.
    One combined linear-index gather pulls counts straight into priority
    order (no intermediate ``cnt[rows]`` materialisation), and — because the
    gather is fused into the query — dsts/probs are only gathered for the
    ``max_items`` emission window instead of all C (``n_needed`` still walks
    every count).  Bit-identical to ``_ordered_rows`` + ``cdf_query_ref``:
    same integer walk, same per-item float ops.
    """
    r = jnp.maximum(rows, 0)
    cap = cnt.shape[1]
    flat = r[:, None] * cap + order[r]                 # [B, C] linear slots
    c_ord = jnp.where(found[:, None], cnt.reshape(-1)[flat], 0)
    totf = jnp.maximum(tot[r], 1).astype(jnp.float32)
    needed, n_needed = _needed_walk(c_ord, totf, threshold)
    k = min(max_items, cap)
    keep = needed[:, :k]
    d_k = dst.reshape(-1)[flat[:, :k]]                 # emission window only
    p_k = c_ord[:, :k].astype(jnp.float32) / totf[:, None]
    dk = jnp.where(keep, d_k, EMPTY)
    pk = jnp.where(keep, p_k, 0.0)
    dk, pk = _pad_items(dk, pk, max_items)
    return dk, pk, n_needed


def topn_merge_ref(probs: jax.Array, dsts: jax.Array, srcs: jax.Array,
                   n: int):
    """Fixed-shape k-way merge of per-shard descending top lists.

    probs/dsts/srcs[S, M]: each shard's local answer, descending by prob
    (dead entries carry prob 0 / EMPTY ids at the tail).  Classic k-way
    head-pointer merge as a lax.scan of n steps: every step reads the S list
    heads, emits the max (ties break toward the lowest shard id — argmax
    first occurrence — so the merge is deterministic), and advances that
    shard's pointer.  Because each input list is descending, the emitted
    stream is globally descending.  Exhausted or dead heads emit
    EMPTY/EMPTY/0.0; output is always (srcs[n], dsts[n], probs[n]).
    """
    s, m = probs.shape

    def step(ptr, _):
        j = jnp.minimum(ptr, m - 1)
        head = probs[jnp.arange(s), j]
        head = jnp.where(ptr < m, head, 0.0)
        best = jnp.argmax(head)
        p = head[best]
        live = p > 0
        src = jnp.where(live, srcs[best, j[best]], EMPTY)
        dst = jnp.where(live, dsts[best, j[best]], EMPTY)
        ptr = ptr.at[best].add(1)
        return ptr, (src, dst, jnp.where(live, p, 0.0))

    _, (ms, md, mp) = jax.lax.scan(
        step, jnp.zeros((s,), jnp.int32), None, length=n)
    return ms, md, mp


def draft_walk_ref(window: jax.Array, ht_keys: jax.Array, ht_vals: jax.Array,
                   cnt: jax.Array, dst: jax.Array, ord0: jax.Array,
                   *, k: int, max_probes: int):
    """k-step greedy draft walk (oracle of ``kernels/walk.py``).

    A lax.scan of (rolling ctx hash -> src probe -> top-1 gather) with a
    dead-lane stop: once a step finds no transition the lane emits token 0 /
    ok False for every later step and does no further lookups' worth of
    state changes.  window[B, order] int32; returns (toks[B, k], ok[B, k]).
    """
    n = cnt.shape[0]

    def step(carry, _):
        win, alive = carry
        src = ht.ctx_window_hash(win)
        rows, found = probe_find_ref(jnp.zeros_like(src), src,
                                     ht_keys[None], ht_vals[None], max_probes)
        rowm = jnp.clip(jnp.where(found, rows, 0), 0, n - 1)
        slot0 = ord0[rowm]
        cnt0 = cnt[rowm, slot0]
        dst0 = dst[rowm, slot0]
        ok = alive & found & (cnt0 > 0) & (dst0 != EMPTY)
        nxt = jnp.where(ok, dst0, 0)
        win = jnp.concatenate([win[:, 1:], nxt[:, None]], axis=1)
        return (win, ok), (nxt, ok)

    alive0 = jnp.ones((window.shape[0],), bool)
    _, (toks, oks) = jax.lax.scan(step, (window, alive0), None, length=k)
    return toks.T, oks.T.astype(jnp.int32)
