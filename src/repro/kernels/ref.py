"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernels must match exactly
(integer ops) or to float tolerance (probability ops).  The oracles reuse the
core library where it defines the semantics (slab.py odd-even passes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.core.hashtable import EMPTY


def oddeven_ref(c_ord: jax.Array, order: jax.Array, passes: int):
    """k odd-even passes over counts-in-order + the order permutation.

    c_ord[N, C] are the counts *already gathered into order position* (the
    kernel-side layout); order[N, C] the slot permutation. Returns the pair
    after ``passes`` full (even+odd) sweeps, descending target.
    """
    for _ in range(passes):
        for start in (0, 1):
            left_c = c_ord[:, start:-1:2]
            right_c = c_ord[:, start + 1 :: 2]
            m = min(left_c.shape[1], right_c.shape[1])
            left_c, right_c = left_c[:, :m], right_c[:, :m]
            left_o = order[:, start:-1:2][:, :m]
            right_o = order[:, start + 1 :: 2][:, :m]
            swap = left_c < right_c
            nl_c = jnp.where(swap, right_c, left_c)
            nr_c = jnp.where(swap, left_c, right_c)
            nl_o = jnp.where(swap, right_o, left_o)
            nr_o = jnp.where(swap, left_o, right_o)
            c_ord = c_ord.at[:, start : start + 2 * m : 2].set(nl_c)
            c_ord = c_ord.at[:, start + 1 : start + 1 + 2 * m : 2].set(nr_c)
            order = order.at[:, start : start + 2 * m : 2].set(nl_o)
            order = order.at[:, start + 1 : start + 1 + 2 * m : 2].set(nr_o)
    return c_ord, order


def oddeven_on_slabs_ref(cnt: jax.Array, order: jax.Array, passes: int):
    """Same semantics as slab.oddeven_passes (permutation-only view)."""
    return sl.oddeven_passes(cnt, order, passes)


def slab_update_ref(rows: jax.Array, dsts: jax.Array, w: jax.Array,
                    dst: jax.Array, cnt: jax.Array, tot: jax.Array):
    """Fast-path batched edge increment (paper §II.A.2, existing edges only).

    For each item i: find slot of dsts[i] in row rows[i]; if present add w[i]
    to cnt and tot.  Items whose edge is absent are no-ops (the caller sends
    them down the slow path).  rows < 0 marks padding.
    """
    active = rows >= 0
    safe_rows = jnp.maximum(rows, 0)
    hit = dst[safe_rows] == dsts[:, None]          # [B, C]
    found = jnp.any(hit, axis=1) & active
    slot = jnp.argmax(hit, axis=1)
    addw = jnp.where(found, w, 0)
    cnt = cnt.at[safe_rows, slot].add(addw)
    tot = tot.at[safe_rows].add(addw)
    return dst, cnt, tot, found


def dh_find_ref(rows: jax.Array, dsts: jax.Array,
                keys: jax.Array, vals: jax.Array, max_probes: int):
    """Batched per-row dst-hash lookup (paper §II.2 optional optimisation).

    rows[B] select a per-row table out of keys/vals[N, H]; each item runs the
    core linear probe (:func:`repro.core.hashtable.lookup`).  rows < 0 marks
    padding.  Returns ``(slots[B], found[B])`` with slot EMPTY when missing.
    """
    safe_rows = jnp.maximum(rows, 0)

    def one(r, d):
        return ht.lookup(ht.HashTable(keys[r], vals[r]), d, max_probes)

    slots, found = jax.vmap(one)(safe_rows, dsts)
    found = found & (rows >= 0)
    return jnp.where(found, slots, EMPTY), found


def cdf_query_ref(c_ord: jax.Array, d_ord: jax.Array, tot: jax.Array,
                  threshold: float, max_items: int):
    """Cumulative-probability threshold query (paper §II.B).

    c_ord/d_ord[B, C]: counts/dsts gathered in descending-priority order
    (zeros for missing rows). Returns (dsts[B,k], probs[B,k], n_needed[B]).
    """
    totf = jnp.maximum(tot, 1).astype(jnp.float32)
    p = c_ord.astype(jnp.float32) / totf[:, None]
    cum = jnp.cumsum(p, axis=1)
    before = cum - p
    needed = (before < threshold) & (c_ord > 0)
    n_needed = jnp.sum(needed.astype(jnp.int32), axis=1)
    k = max_items
    keep = needed[:, :k]
    dk = jnp.where(keep, d_ord[:, :k], EMPTY)
    pk = jnp.where(keep, p[:, :k], 0.0)
    return dk, pk, n_needed
