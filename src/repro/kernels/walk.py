"""Pallas TPU kernel: one-shot k-step greedy draft walk (speculative.draft).

Drafting k tokens from the n-gram chain is k sequential iterations of
(rolling ctx hash -> src-table probe -> top-1 slab gather).  As a
``lax.scan`` over ``query_topk`` that is k separate kernel dispatches plus k
host round trips through lookup+gather+cdf_query — but the chain snapshot is
immutable for the duration of a draft (RCU/EpochStore contract), so the
whole walk collapses into ONE kernel: the src hash table and the slabs sit
in VMEM once, and each step is a handful of VPU ops.

Per step, vectorised across the query block:

  * rolling hash of the ctx window — same recurrence as
    ``speculative.context_ids`` (newest token first);
  * src probe — the same lane-parallel linear-probe reductions as
    ``kernels/probe.py`` (key_p/empty_p min over probe positions);
  * top-1 gather — the order head ``order[row, 0]`` IS the approximate
    argmax (paper §II.2), so top-1 needs no CDF walk: one cnt/dst gather.

Dead lanes stop walking: ``alive`` (scratch) clears when a step finds no
transition, later steps emit token 0 / ok False for that lane, and the whole
step body is predicated off with ``@pl.when`` once every lane in the block
is dead — no hashing or probing on dead work.  The window and alive mask
live in scratch because values cannot thread through ``@pl.when`` bodies.

The top-1 gathers use in-kernel advanced indexing on the VMEM-resident
slabs; a real-TPU lowering would replace them with per-query ``pl.dslice``
loads (semantics identical — see ``ref.draft_walk_ref``, the lax.scan
oracle this kernel must match token-for-token).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashtable import EMPTY, ctx_window_hash, hash_u32

DEFAULT_QUERIES_PER_BLOCK = 128


def _walk_kernel(win_ref, hk_ref, hv_ref, cnt_ref, dst_ref, ord0_ref,
                 tok_out_ref, ok_out_ref, win_scr, alive_scr,
                 *, steps: int, max_probes: int, valid: int):
    t_size = hk_ref.shape[0]
    n = cnt_ref.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, t_size), 1)
    big = jnp.int32(t_size)
    tok_out_ref[...] = jnp.zeros_like(tok_out_ref[...])
    ok_out_ref[...] = jnp.zeros_like(ok_out_ref[...])
    win_scr[...] = win_ref[...]
    # batch-padding lanes (>= valid) start dead: no probe work, and they
    # cannot hold a block open after every real lane has died
    row0 = pl.program_id(0) * win_ref.shape[0]
    qidx = row0 + jax.lax.broadcasted_iota(jnp.int32, alive_scr.shape, 0)
    alive_scr[...] = (qidx < valid).astype(jnp.int32)

    for s in range(steps):

        def step(s=s):
            win = win_scr[...]
            alive = alive_scr[:, 0] > 0
            # rolling ctx hash, newest token first (context_ids recurrence)
            src = ctx_window_hash(win)
            # lane-parallel src probe (kernels/probe.py semantics)
            h0 = (hash_u32(src) & jnp.uint32(t_size - 1)).astype(jnp.int32)
            p = (lane - h0[:, None]) & (t_size - 1)          # (Q, T)
            keys = hk_ref[...][None, :]
            in_win = p < max_probes
            is_key = in_win & (keys == src[:, None])
            is_empty = in_win & (keys == EMPTY)
            key_p = jnp.min(jnp.where(is_key, p, big), axis=1)
            empty_p = jnp.min(jnp.where(is_empty, p, big), axis=1)
            found = key_p < empty_p
            row = jnp.sum(jnp.where(is_key & (p == key_p[:, None]),
                                    hv_ref[...][None, :], 0), axis=1)
            rowm = jnp.clip(jnp.where(found, row, 0), 0, n - 1)
            # top-1 gather: the order head is the approximate argmax
            slot0 = ord0_ref[...][rowm]                      # (Q,)
            cnt0 = cnt_ref[...][rowm, slot0]
            dst0 = dst_ref[...][rowm, slot0]
            ok = alive & found & (cnt0 > 0) & (dst0 != EMPTY)
            nxt = jnp.where(ok, dst0, 0)
            tok_out_ref[:, s] = nxt
            ok_out_ref[:, s] = ok.astype(jnp.int32)
            alive_scr[:, 0] = ok.astype(jnp.int32)
            win_scr[...] = jnp.concatenate([win[:, 1:], nxt[:, None]], axis=1)

        if s == 0:
            step()
        else:  # all lanes dead -> the whole step is predicated off
            pl.when(jnp.sum(alive_scr[...]) > 0)(step)


@functools.partial(
    jax.jit,
    static_argnames=("k", "max_probes", "queries_per_block", "valid",
                     "interpret"))
def draft_walk_pallas(window: jax.Array, ht_keys: jax.Array,
                      ht_vals: jax.Array, cnt: jax.Array, dst: jax.Array,
                      ord0: jax.Array, *, k: int = 4, max_probes: int = 64,
                      queries_per_block: int = DEFAULT_QUERIES_PER_BLOCK,
                      valid: int = 0, interpret: bool = True):
    """window: [B, order] recent tokens per sequence; ht_keys/ht_vals: [T]
    flat src table; cnt/dst: [N, C] slabs; ord0: [N] order head per row
    (``slabs.order[:, 0]``).  ``valid`` marks the real (pre-padding) batch
    size; lanes past it never walk (0 = all lanes real).  Returns
    ``(toks[B, k], ok[B, k] int32)``.
    """
    b, _ = window.shape
    qb = min(queries_per_block, b)
    assert b % qb == 0, (b, qb)
    grid = (b // qb,)
    valid = valid or b
    win_spec = pl.BlockSpec((qb, window.shape[1]), lambda i: (i, 0))
    full1 = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0,))
    full2 = lambda arr: pl.BlockSpec(arr.shape, lambda i: (0, 0))
    out_spec = pl.BlockSpec((qb, k), lambda i: (i, 0))
    toks, oks = pl.pallas_call(
        functools.partial(_walk_kernel, steps=k, max_probes=max_probes,
                          valid=valid),
        grid=grid,
        in_specs=[win_spec, full1(ht_keys), full1(ht_vals),
                  full2(cnt), full2(dst), full1(ord0)],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((qb, window.shape[1]), jnp.int32),
                        pltpu.VMEM((qb, 1), jnp.int32)],
        interpret=interpret,
    )(window, ht_keys, ht_vals, cnt, dst, ord0)
    return toks, oks
