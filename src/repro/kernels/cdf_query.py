"""Pallas TPU kernel: cumulative-probability threshold query (paper §II.B).

Fuses the whole inference path — probability normalisation (two-counter
scheme), prefix-sum, threshold test, and masked top-item emission — into one
VPU kernel over a (QUERIES_PER_BLOCK, C) VMEM tile.  The paper's
O(CDF^-1(t)) bound shows up twice:

  * ``n_needed`` reports CDF^-1(t) per query, and
  * with ``chunks`` > 1 the walk over C runs in lane-width chunks whose
    bodies are predicated off with ``@pl.when`` once **every** row of the
    block has crossed the threshold — the block-granular analogue of the
    paper's per-reader early exit.  Work done then tracks ``mean_items``
    (CDF^-1), not C.

Exactness contract (shared with ``ref.cdf_query_ref`` and the fused-gather
variant in ``cdf_gather.py``): the cumulative walk runs in **integer count
space** — ``needed[j] = (sum(cnt[<j]) < t * tot) & (cnt[j] > 0)`` with the
prefix sums exact int32 — so any chunking of the walk is bit-identical to
any other (float prefix sums would make the result depend on association
order).  The only float ops, ``t * tot`` and ``p = cnt / tot``, are
per-row/per-item and association-free.

``threshold=None`` selects **top-k mode** (keep every live item, emit the
first ``max_items``): the mode is a static kernel flag, not an unreachable
sentinel threshold, so the contract never relies on a float that cannot be
crossed.  The early-exit carry state lives in a scratch ref because values
cannot thread through ``@pl.when`` bodies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hashtable import EMPTY

DEFAULT_QUERIES_PER_BLOCK = 128
LANE_WIDTH = 128  # VPU lane dim; auto-chunking targets one chunk per lane tile


def auto_chunks(capacity: int, chunks: int) -> int:
    """Resolve ``chunks=0`` (auto) from C and the lane width: one chunk per
    128-lane tile when C is a lane multiple, else a single chunk.  Explicit
    chunk counts are validated here — once, for every backend — so a bad
    ``MCConfig.query_chunks`` fails identically on ref and pallas instead
    of crashing only at TPU trace time."""
    if chunks:
        if capacity % chunks:
            raise ValueError(
                f"chunks={chunks} must divide capacity={capacity} "
                f"(MCConfig.query_chunks)")
        return chunks
    if capacity % LANE_WIDTH == 0 and capacity > LANE_WIDTH:
        return capacity // LANE_WIDTH
    return 1


def walk_chunks(load, totf, t, dst_out_ref, prob_out_ref, n_out_ref,
                carry_ref, *, cap: int, max_items: int, chunks: int,
                topk: bool):
    """The chunked CDF walk shared by the pre-gathered and fused kernels.

    ``load(k) -> (ck, dk)`` yields chunk ``k`` of the counts/dsts in
    priority order (reads happen inside the predicated body, so a skipped
    chunk costs nothing).  ``carry_ref`` is an int32 (Q, 1) scratch holding
    each row's exact cumulative count; outputs are initialised here and
    written per chunk.  ``topk=True`` keeps every live item and disables
    the early exit (there is no threshold to cross).
    """
    chunk = cap // chunks
    dst_out_ref[...] = jnp.full_like(dst_out_ref[...], EMPTY)
    prob_out_ref[...] = jnp.zeros_like(prob_out_ref[...])
    n_out_ref[...] = jnp.zeros_like(n_out_ref[...])
    carry_ref[...] = jnp.zeros_like(carry_ref[...])
    tcnt = t * totf                                   # (Q,) float32

    for k in range(chunks):

        def body(k=k):
            ck, dk = load(k)                          # (Q, chunk) int32
            carry = carry_ref[:, 0]                   # exact int32 prefix
            cum = carry[:, None] + jnp.cumsum(ck, axis=1)
            if topk:
                needed = ck > 0
            else:
                before = (cum - ck).astype(jnp.float32)
                needed = (before < tcnt[:, None]) & (ck > 0)
            n_out_ref[...] = n_out_ref[...] + jnp.sum(
                needed.astype(jnp.int32), axis=1)
            lo = k * chunk
            if lo < max_items:
                hi = min(lo + chunk, max_items)
                w = hi - lo
                p = ck.astype(jnp.float32) / totf[:, None]
                keep = needed[:, :w]
                dst_out_ref[:, lo:hi] = jnp.where(keep, dk[:, :w], EMPTY)
                prob_out_ref[:, lo:hi] = jnp.where(keep, p[:, :w], 0.0)
            carry_ref[:, 0] = cum[:, -1]

        if topk or chunks == 1:
            body()
        else:
            # real early exit: once every row's cumulative count crossed the
            # threshold no later item can be needed (prefix counts are
            # monotone), so the whole chunk is predicated off.  Skipping
            # leaves carry stale, which keeps the block skipped — exact.
            done = carry_ref[:, 0].astype(jnp.float32) >= tcnt
            pl.when((k == 0) | ~jnp.all(done))(body)


def _cdf_kernel(c_ref, d_ref, tot_ref, t_ref, dst_out_ref, prob_out_ref,
                n_out_ref, carry_ref, *, max_items: int, chunks: int,
                topk: bool):
    cap = c_ref.shape[-1]
    chunk = cap // chunks
    totf = jnp.maximum(tot_ref[...], 1).astype(jnp.float32)  # (Qb,)

    def load(k):
        return (c_ref[:, k * chunk:(k + 1) * chunk],
                d_ref[:, k * chunk:(k + 1) * chunk])

    walk_chunks(load, totf, t_ref[0], dst_out_ref, prob_out_ref, n_out_ref,
                carry_ref, cap=cap, max_items=max_items, chunks=chunks,
                topk=topk)


@functools.partial(
    jax.jit,
    static_argnames=("max_items", "queries_per_block", "chunks", "topk",
                     "interpret"))
def cdf_query_pallas(c_ord: jax.Array, d_ord: jax.Array, tot: jax.Array,
                     threshold=0.0, *, max_items: int = 16,
                     queries_per_block: int = DEFAULT_QUERIES_PER_BLOCK,
                     chunks: int = 1, topk: bool = False,
                     interpret: bool = True):
    """c_ord/d_ord: [B, C] counts/dsts in priority order (0 where missing),
    tot: [B]. Returns (dsts[B, max_items], probs[B, max_items], n_needed[B]).
    ``topk=True`` ignores the threshold and keeps every live item.
    """
    b, cap = c_ord.shape
    qb = min(queries_per_block, b)
    assert b % qb == 0, (b, qb)
    assert cap % chunks == 0, (cap, chunks)
    grid = (b // qb,)
    t_arr = jnp.asarray([threshold], jnp.float32)
    tile2d = pl.BlockSpec((qb, cap), lambda i: (i, 0))
    tile1d = pl.BlockSpec((qb,), lambda i: (i,))
    tscalar = pl.BlockSpec((1,), lambda i: (0,))
    tilek = pl.BlockSpec((qb, max_items), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cdf_kernel, max_items=max_items, chunks=chunks,
                          topk=topk),
        grid=grid,
        in_specs=[tile2d, tile2d, tile1d, tscalar],
        out_specs=[tilek, tilek, tile1d],
        out_shape=[
            jax.ShapeDtypeStruct((b, max_items), jnp.int32),
            jax.ShapeDtypeStruct((b, max_items), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((qb, 1), jnp.int32)],
        interpret=interpret,
    )(c_ord, d_ord, tot, t_arr)
