"""Pallas TPU kernel: cumulative-probability threshold query (paper §II.B).

Fuses the whole inference path — probability normalisation (two-counter
scheme), prefix-sum, threshold test, and masked top-item emission — into one
VPU kernel over a (QUERIES_PER_BLOCK, C) VMEM tile.  The paper's
O(CDF^-1(t)) bound shows up as ``n_needed``; on real TPU the chunked variant
(``chunks`` > 1) walks C in lane-width chunks carrying the running cumsum so
late chunks of already-satisfied rows are predicated off — the block-granular
analogue of the paper's early exit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashtable import EMPTY

DEFAULT_QUERIES_PER_BLOCK = 128


def _cdf_kernel(c_ref, d_ref, tot_ref, t_ref, dst_out_ref, prob_out_ref,
                n_out_ref, *, max_items: int, chunks: int):
    c = c_ref[...].astype(jnp.float32)          # (Qb, C)
    d = d_ref[...]
    tot = jnp.maximum(tot_ref[...], 1).astype(jnp.float32)  # (Qb,)
    t = t_ref[0]
    cap = c.shape[-1]
    chunk = cap // chunks
    p = c / tot[:, None]

    n_acc = jnp.zeros((c.shape[0],), jnp.int32)
    carry = jnp.zeros((c.shape[0],), jnp.float32)
    for k in range(chunks):
        pk = p[:, k * chunk : (k + 1) * chunk]
        ck = c[:, k * chunk : (k + 1) * chunk]
        # rows with carry >= t are done: their whole chunk is predicated off
        # (on TPU this chunk's VPU work is skipped via @pl.when per block row
        #  group; numerically the mask below is equivalent)
        cum = carry[:, None] + jnp.cumsum(pk, axis=1)
        before = cum - pk
        needed = (before < t) & (ck > 0)
        n_acc = n_acc + jnp.sum(needed.astype(jnp.int32), axis=1)
        if k * chunk < max_items:
            lo, hi = k * chunk, min((k + 1) * chunk, max_items)
            width = hi - lo
            keep = needed[:, :width]
            dst_out_ref[:, lo:hi] = jnp.where(keep, d[:, lo:hi], EMPTY)
            prob_out_ref[:, lo:hi] = jnp.where(keep, pk[:, :width], 0.0)
        carry = cum[:, -1]
    n_out_ref[...] = n_acc


@functools.partial(
    jax.jit,
    static_argnames=("max_items", "queries_per_block", "chunks", "interpret"))
def cdf_query_pallas(c_ord: jax.Array, d_ord: jax.Array, tot: jax.Array,
                     threshold, *, max_items: int = 16,
                     queries_per_block: int = DEFAULT_QUERIES_PER_BLOCK,
                     chunks: int = 1, interpret: bool = True):
    """c_ord/d_ord: [B, C] counts/dsts in priority order (0 where missing),
    tot: [B]. Returns (dsts[B, max_items], probs[B, max_items], n_needed[B]).
    """
    b, cap = c_ord.shape
    qb = min(queries_per_block, b)
    assert b % qb == 0, (b, qb)
    assert cap % chunks == 0, (cap, chunks)
    grid = (b // qb,)
    t_arr = jnp.asarray([threshold], jnp.float32)
    tile2d = pl.BlockSpec((qb, cap), lambda i: (i, 0))
    tile1d = pl.BlockSpec((qb,), lambda i: (i,))
    tscalar = pl.BlockSpec((1,), lambda i: (0,))
    tilek = pl.BlockSpec((qb, max_items), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_cdf_kernel, max_items=max_items, chunks=chunks),
        grid=grid,
        in_specs=[tile2d, tile2d, tile1d, tscalar],
        out_specs=[tilek, tilek, tile1d],
        out_shape=[
            jax.ShapeDtypeStruct((b, max_items), jnp.int32),
            jax.ShapeDtypeStruct((b, max_items), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(c_ord, d_ord, tot, t_arr)
