"""Pallas TPU kernel: fused batched edge-increment (paper §II.A.2 hot path).

Fuses the paper's "O(1) dst lookup + atomic increment" for a whole update
batch: each grid instance owns a (ROWS_PER_BLOCK, C) slab tile in VMEM and
replays the (pre-row-resolved) update list against it — items landing outside
the tile are predicated off, so every tile applies exactly its own updates
and writes are conflict-free by construction (the TPU reading of "lock-free":
determinism instead of atomics, DESIGN.md §2).

The dst-slot lookup inside the tile is a single C-lane vector compare per
item — the paper's §II.2 observation that a linear scan can rival a hash
table is literal here: on TPU the scan is one VPU op.

Layout notes for real TPU: C is the lane dim (multiple of 128); the per-item
row access is a dynamic sublane slice (supported by Mosaic); the item loop is
a fori over scalars + VMEM vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS_PER_BLOCK = 256


def _slab_update_kernel(rows_ref, dsts_ref, w_ref, cnt_ref, tot_ref,
                        dst_slab_ref, cnt_out_ref, tot_out_ref,
                        *, rows_per_block: int):
    # start from the incoming tile; the item loop read-modify-writes it
    cnt_out_ref[...] = cnt_ref[...]
    tot_out_ref[...] = tot_ref[...]
    r0 = pl.program_id(0) * rows_per_block
    batch = rows_ref.shape[0]

    def body(i, _):
        r = rows_ref[i] - r0
        in_block = (r >= 0) & (r < rows_per_block)
        rr = jnp.clip(r, 0, rows_per_block - 1)
        row_dst = dst_slab_ref[pl.dslice(rr, 1), :]  # (1, C)
        hit = row_dst == dsts_ref[i]
        # first hit only: slab rows hold unique dsts by invariant, but the
        # kernel must stay exact even on degenerate inputs (and tot must see
        # each item's weight exactly once)
        hit = hit & (jnp.cumsum(hit, axis=1) == 1)
        found = jnp.any(hit)
        w = jnp.where(in_block & found, w_ref[i], 0).astype(jnp.int32)
        row_cnt = cnt_out_ref[pl.dslice(rr, 1), :]
        cnt_out_ref[pl.dslice(rr, 1), :] = row_cnt + hit.astype(jnp.int32) * w
        tot_row = tot_out_ref[pl.dslice(rr, 1)]
        tot_out_ref[pl.dslice(rr, 1)] = tot_row + w
        return 0

    jax.lax.fori_loop(0, batch, body, 0)


@functools.partial(
    jax.jit, static_argnames=("rows_per_block", "interpret"))
def slab_update_pallas(rows: jax.Array, dsts: jax.Array, w: jax.Array,
                       dst_slab: jax.Array, cnt: jax.Array, tot: jax.Array,
                       *, rows_per_block: int = DEFAULT_ROWS_PER_BLOCK,
                       interpret: bool = True):
    """Apply fast-path increments. rows[B] (< 0 = padding), dsts[B], w[B];
    dst_slab/cnt[N, C], tot[N]. Returns (cnt', tot')."""
    n, cap = cnt.shape
    rb = min(rows_per_block, n)
    assert n % rb == 0, (n, rb)
    grid = (n // rb,)
    full = pl.BlockSpec(rows.shape, lambda i: (0,))
    tile2d = pl.BlockSpec((rb, cap), lambda i: (i, 0))
    tile1d = pl.BlockSpec((rb,), lambda i: (i,))
    cnt_out, tot_out = pl.pallas_call(
        functools.partial(_slab_update_kernel, rows_per_block=rb),
        grid=grid,
        in_specs=[full, full, full, tile2d, tile1d, tile2d],
        out_specs=[tile2d, tile1d],
        out_shape=[
            jax.ShapeDtypeStruct(cnt.shape, cnt.dtype),
            jax.ShapeDtypeStruct(tot.shape, tot.dtype),
        ],
        interpret=interpret,
    )(rows, dsts, w, cnt, tot, dst_slab)
    return cnt_out, tot_out
