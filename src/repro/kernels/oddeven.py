"""Pallas TPU kernel: vectorised odd-even transposition over slab rows.

The paper's lock-free bubble sort, as a VPU-only kernel.  Roll-based
compare-exchange — no lane-strided slicing, no gathers — so every pass is a
handful of lane shifts + selects, ideal for the TPU vector unit:

  for each parity p in {even, odd}:
    take_next[i] = (i % 2 == p) and i < C-1 and c[i] < c[i+1]
    gave_prev[i] = take_next[i-1]
    c'[i] = c[i+1] if take_next else (c[i-1] if gave_prev else c[i])

VMEM tiling: a (ROWS_PER_BLOCK, C) tile of both the count-in-order array and
the permutation; grid over row blocks.  C (slab capacity) is the lane dim —
configs keep it a multiple of 128 for MXU/VPU alignment; smaller capacities
are padded by the ops.py wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS_PER_BLOCK = 256


def _compare_exchange(c, o, idx, parity):
    cap = c.shape[-1]
    cn = jnp.roll(c, -1, axis=1)
    cp = jnp.roll(c, 1, axis=1)
    on = jnp.roll(o, -1, axis=1)
    op = jnp.roll(o, 1, axis=1)
    is_left = ((idx % 2) == parity) & (idx < cap - 1)
    take_next = is_left & (c < cn)            # descending order target
    gave_prev = jnp.roll(take_next, 1, axis=1)  # wrap safe: last lane masked
    new_c = jnp.where(take_next, cn, jnp.where(gave_prev, cp, c))
    new_o = jnp.where(take_next, on, jnp.where(gave_prev, op, o))
    return new_c, new_o


def _oddeven_kernel(c_ref, o_ref, c_out_ref, o_out_ref, *, passes: int):
    c = c_ref[...]
    o = o_ref[...]
    cap = c.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
    for _ in range(passes):
        for parity in (0, 1):
            c, o = _compare_exchange(c, o, idx, parity)
    c_out_ref[...] = c
    o_out_ref[...] = o


@functools.partial(
    jax.jit, static_argnames=("passes", "rows_per_block", "interpret"))
def oddeven_pallas(c_ord: jax.Array, order: jax.Array, *, passes: int = 1,
                   rows_per_block: int = DEFAULT_ROWS_PER_BLOCK,
                   interpret: bool = True):
    """k odd-even passes. c_ord/order: [N, C], N divisible by rows_per_block
    (ops.py pads). Returns (c_ord', order')."""
    n, cap = c_ord.shape
    rb = min(rows_per_block, n)
    assert n % rb == 0, (n, rb)
    grid = (n // rb,)
    spec = pl.BlockSpec((rb, cap), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_oddeven_kernel, passes=passes),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(c_ord.shape, c_ord.dtype),
            jax.ShapeDtypeStruct(order.shape, order.dtype),
        ],
        interpret=interpret,
    )(c_ord, order)
