"""Pallas TPU kernel: shared open-addressing probe (paper §II.1-2).

One lane-parallel linear-probe kernel serves every hash lookup in the
system.  The table layout is always ``keys/vals[N, H]`` — a stack of N
open-addressing tables probed independently:

  * **per-row dst hash** (paper §II.2 "optional optimization"): N = slab
    rows, H = per-row table size; ``rows[i]`` selects which table item i
    probes (``ops.dh_find``).
  * **flat src table** (paper §II.1, the node-id -> row lookup at the head
    of every query): N = 1, H = the table size; all items probe table 0
    (``ops.ht_find`` — the kernelized ``hashtable.lookup_batch``).

Each grid instance owns a (ROWS_PER_BLOCK, H) tile of the tables in VMEM and
resolves the query list against it; items landing outside the tile are
predicated off, exactly like ``slab_update``.

The linear-probe loop is vectorised across the H lanes instead of iterated:
for a query key ``d`` with home slot ``h0``, lane ``j`` sits at probe
position ``p = (j - h0) mod H``.  The probe semantics of
``hashtable.lookup`` — scan from ``h0``, stop at the key or the first EMPTY,
give up after ``max_probes`` — become three lane-parallel reductions:

  key_p   = min p over lanes holding the key      (H if none in window)
  empty_p = min p over lanes holding EMPTY        (H if none in window)
  found   = key_p < empty_p                       (TOMB lanes just probe on)

One table load + a handful of VPU ops per item; no scalar probe chains.  H
is the lane dim (power of two by construction, multiple of 128 for real-TPU
alignment at the sizes the configs use; smaller tables run in interpret mode
off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashtable import EMPTY, hash_u32

DEFAULT_ROWS_PER_BLOCK = 256


def _probe_kernel(rows_ref, keys_q_ref, tab_keys_ref, tab_vals_ref,
                  slot_out_ref, found_out_ref,
                  *, rows_per_block: int, max_probes: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        slot_out_ref[...] = jnp.full_like(slot_out_ref[...], EMPTY)
        found_out_ref[...] = jnp.zeros_like(found_out_ref[...])

    r0 = pl.program_id(0) * rows_per_block
    batch = rows_ref.shape[0]
    h = tab_keys_ref.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, h), 1)
    big = jnp.int32(h)

    def body(i, _):
        r = rows_ref[i] - r0
        in_block = (r >= 0) & (r < rows_per_block)
        rr = jnp.clip(r, 0, rows_per_block - 1)
        row_keys = tab_keys_ref[pl.dslice(rr, 1), :]      # (1, H)
        row_vals = tab_vals_ref[pl.dslice(rr, 1), :]
        d = keys_q_ref[i]
        h0 = (hash_u32(d) & jnp.uint32(h - 1)).astype(jnp.int32)
        p = (lane - h0) & (h - 1)                     # probe position per lane
        in_win = p < max_probes
        is_key = in_win & (row_keys == d)
        is_empty = in_win & (row_keys == EMPTY)
        key_p = jnp.min(jnp.where(is_key, p, big))
        empty_p = jnp.min(jnp.where(is_empty, p, big))
        found = in_block & (key_p < empty_p)
        slot = jnp.sum(jnp.where(is_key & (p == key_p), row_vals, 0))
        cur_s = slot_out_ref[pl.dslice(i, 1)]
        cur_f = found_out_ref[pl.dslice(i, 1)]
        out_s = jnp.where(in_block, jnp.where(found, slot, EMPTY), cur_s[0])
        out_f = jnp.where(in_block, found.astype(jnp.int32), cur_f[0])
        slot_out_ref[pl.dslice(i, 1)] = out_s.reshape(1).astype(jnp.int32)
        found_out_ref[pl.dslice(i, 1)] = out_f.reshape(1).astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, batch, body, 0)


@functools.partial(
    jax.jit, static_argnames=("max_probes", "rows_per_block", "interpret"))
def probe_find_pallas(rows: jax.Array, keys_q: jax.Array,
                      tab_keys: jax.Array, tab_vals: jax.Array,
                      *, max_probes: int = 64,
                      rows_per_block: int = DEFAULT_ROWS_PER_BLOCK,
                      interpret: bool = True):
    """Batched open-addressing probe. rows[B] select a table out of
    ``tab_keys/tab_vals[N, H]`` (rows < 0 = padding); keys_q[B] are the
    probed keys.  Returns ``(slots[B], found[B] int32)`` with slot EMPTY
    where not found."""
    n, h = tab_keys.shape
    rb = min(rows_per_block, n)
    assert n % rb == 0, (n, rb)
    grid = (n // rb,)
    full = pl.BlockSpec(rows.shape, lambda i: (0,))
    tile = pl.BlockSpec((rb, h), lambda i: (i, 0))
    slots, found = pl.pallas_call(
        functools.partial(_probe_kernel, rows_per_block=rb,
                          max_probes=max_probes),
        grid=grid,
        in_specs=[full, full, tile, tile],
        out_specs=[full, full],
        out_shape=[
            jax.ShapeDtypeStruct(rows.shape, jnp.int32),
            jax.ShapeDtypeStruct(rows.shape, jnp.int32),
        ],
        interpret=interpret,
    )(rows, keys_q, tab_keys, tab_vals)
    return slots, found
