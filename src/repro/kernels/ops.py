"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, the order-gather layout transform, and
backend dispatch: ``impl='pallas'`` (interpret=True on CPU — the container
has no TPU), ``impl='ref'`` (pure-jnp oracle), ``impl='auto'`` (pallas on
TPU, ref otherwise — the ref *is* the XLA fast path on CPU).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.analysis.invariants import kernel_op
from repro.obs import tracing as _obs_tracing
from repro.kernels import cdf_gather as _cg
from repro.kernels import cdf_query as _cdf
from repro.kernels import oddeven as _oe
from repro.kernels import probe as _pr
from repro.kernels import ref as _ref
from repro.kernels import slab_update as _su
from repro.kernels import walk as _wk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_IMPLS = ("auto", "ref", "pallas")


def _use_ref(impl: str) -> bool:
    """Validate ``impl`` and decide the dispatch (trace time, static arg)."""
    if impl not in _IMPLS:
        raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")
    return impl == "ref" or (impl == "auto" and not _on_tpu())


def _pad_rows(x: jax.Array, mult: int, fill) -> Tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        pad_block = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
        x = jnp.concatenate([x, pad_block], axis=0)
    return x, n


def _annotate(fn):
    """Opt-in profiler annotation around a jitted dispatcher (DESIGN.md
    §13).  This wrapper stays OUTSIDE the jit (the jitted body must remain
    pure — no module-global reads inside the trace), so the module-bool
    gate costs one branch per call when disarmed.  When
    ``obs.tracing.KERNEL_ANNOTATE`` is on, the dispatch traces under
    ``jax.named_scope("mcq.<op>")`` and the op name lands in the HLO
    metadata every profiler timeline shows.  Enable BEFORE the first
    dispatch: jit caches the traced program, so already-compiled
    signatures keep whatever scopes they were traced with."""
    @functools.wraps(fn)
    def dispatch(*args, **kwargs):
        if _obs_tracing.KERNEL_ANNOTATE:
            with jax.named_scope(f"mcq.{fn.__name__}"):
                return fn(*args, **kwargs)
        return fn(*args, **kwargs)
    return dispatch


# ---------------------------------------------------------------------------


@_annotate
@functools.partial(jax.jit, static_argnames=("passes", "impl"))
@kernel_op(ref="oddeven_ref", pallas="oddeven_pallas")
def oddeven_sort(cnt: jax.Array, order: jax.Array, *, passes: int = 1,
                 impl: str = "auto") -> jax.Array:
    """k odd-even passes over every slab row; returns the new order
    permutation (slabs themselves never move — DESIGN.md §2)."""
    # kernel layout: gather counts into order position ONCE and carry them
    # through the swaps, instead of re-gathering every half-pass (same
    # semantics; see test_oddeven_ref_equals_slab_semantics)
    c_ord = jnp.take_along_axis(cnt, order, axis=1)
    if _use_ref(impl):
        _, new_order = _ref.oddeven_ref(c_ord, order, passes)
        return new_order
    rb = min(_oe.DEFAULT_ROWS_PER_BLOCK, cnt.shape[0])
    c_ord, n = _pad_rows(c_ord, rb, 0)
    order_p, _ = _pad_rows(order, rb, 0)
    _, new_order = _oe.oddeven_pallas(
        c_ord, order_p, passes=passes, rows_per_block=rb,
        interpret=not _on_tpu())
    return new_order[:n]


@_annotate
@functools.partial(jax.jit, static_argnames=("impl",))
@kernel_op(ref="slab_update_ref", pallas="slab_update_pallas")
def slab_update(rows: jax.Array, dsts: jax.Array, w: jax.Array,
                dst_slab: jax.Array, cnt: jax.Array, tot: jax.Array,
                *, impl: str = "auto"):
    """Fast-path batched increments; returns (cnt', tot').
    rows < 0 = padding/inactive items."""
    if _use_ref(impl):
        _, cnt2, tot2, _ = _ref.slab_update_ref(rows, dsts, w, dst_slab, cnt, tot)
        return cnt2, tot2
    rb = min(_su.DEFAULT_ROWS_PER_BLOCK, cnt.shape[0])
    dst_p, n = _pad_rows(dst_slab, rb, -1)
    cnt_p, _ = _pad_rows(cnt, rb, 0)
    tot_p, _ = _pad_rows(tot, rb, 0)
    cnt2, tot2 = _su.slab_update_pallas(
        rows, dsts, w, dst_p, cnt_p, tot_p, rows_per_block=rb,
        interpret=not _on_tpu())
    return cnt2[:n], tot2[:n]


@_annotate
@functools.partial(jax.jit, static_argnames=("impl",))
@kernel_op(ref="oddeven_ref", composes=("oddeven_sort",))
def decay_sort(cnt: jax.Array, dst: jax.Array, order: jax.Array,
               *, impl: str = "auto"):
    """Fused §II.C decay: halve counters, evict dead edges, fully re-sort.

    The compaction sort composes the odd-even kernel with C/2+1 passes (a
    full odd-even transposition network sorts any input), so the whole decay
    runs as VPU sweeps over the slab tiles.  Returns (cnt', dst', order',
    tot') with evicted slots at the order tail.
    """
    new_cnt = cnt >> 1
    new_dst = jnp.where(new_cnt == 0, -1, dst)
    new_tot = jnp.sum(new_cnt, axis=1).astype(jnp.int32)
    passes = cnt.shape[1] // 2 + 1
    new_order = oddeven_sort(new_cnt, order, passes=passes, impl=impl)
    return new_cnt, new_dst, new_order, new_tot


@_annotate
@functools.partial(jax.jit, static_argnames=("max_probes", "impl"))
@kernel_op(ref="dh_find_ref", pallas="probe_find_pallas")
def dh_find(rows: jax.Array, dsts: jax.Array,
            dh_keys: jax.Array, dh_vals: jax.Array,
            *, max_probes: int = 64, impl: str = "auto"):
    """Batched per-row dst-hash lookup: ``(slots[B], found[B] bool)``.

    The paper's §II.2 dst -> slot tables as one fused dispatch through the
    shared probe kernel (``kernels/probe.py``); rows < 0 are padding.
    Semantics are the core linear probe (``hashtable.lookup``).
    """
    if _use_ref(impl):
        slots, found = _ref.dh_find_ref(rows, dsts, dh_keys, dh_vals,
                                        max_probes)
        return slots, found
    rb = min(_pr.DEFAULT_ROWS_PER_BLOCK, dh_keys.shape[0])
    keys_p, _ = _pad_rows(dh_keys, rb, -1)
    vals_p, _ = _pad_rows(dh_vals, rb, -1)
    slots, found = _pr.probe_find_pallas(
        rows, dsts, keys_p, vals_p, max_probes=max_probes,
        rows_per_block=rb, interpret=not _on_tpu())
    return slots, found.astype(bool)


@_annotate
@functools.partial(jax.jit, static_argnames=("max_probes", "impl"))
@kernel_op(ref="probe_find_ref", pallas="probe_find_pallas")
def ht_find(keys_q: jax.Array, tab_keys: jax.Array, tab_vals: jax.Array,
            *, max_probes: int = 64, impl: str = "auto"):
    """Batched flat-table lookup: ``(vals[B], found[B] bool)``.

    The src node-id -> row probe at the head of every query (paper §II.1),
    kernelized: the flat table is the N = 1 case of the shared probe kernel.
    ``hashtable.lookup_batch`` routes here when an impl is requested.
    """
    rows = jnp.zeros_like(keys_q)
    if _use_ref(impl):
        slots, found = _ref.probe_find_ref(
            rows, keys_q, tab_keys[None], tab_vals[None], max_probes)
        return slots, found
    slots, found = _pr.probe_find_pallas(
        rows, keys_q, tab_keys[None], tab_vals[None],
        max_probes=max_probes, rows_per_block=1, interpret=not _on_tpu())
    return slots, found.astype(bool)


@_annotate
@functools.partial(jax.jit,
                   static_argnames=("max_items", "chunks", "topk", "impl"))
@kernel_op(ref="cdf_query_ref", pallas="cdf_query_pallas")
def cdf_query(c_ord: jax.Array, d_ord: jax.Array, tot: jax.Array,
              threshold, *, max_items: int = 16, chunks: int = 0,
              topk: bool = False, impl: str = "auto"):
    """Threshold inference over pre-ordered rows; see cdf_query.py.

    ``threshold`` is required; passing ``None`` explicitly selects top-k
    mode (keep every live item — the explicit contract, not an unreachable
    threshold).  ``chunks=0`` auto-picks the chunked early-exit walk from C
    and the lane width.
    """
    topk = topk or threshold is None
    chunks = _cdf.auto_chunks(c_ord.shape[1], chunks)
    if _use_ref(impl):
        return _ref.cdf_query_ref(c_ord, d_ord, tot,
                                  None if topk else threshold, max_items)
    qb = min(_cdf.DEFAULT_QUERIES_PER_BLOCK, c_ord.shape[0])
    c_p, b = _pad_rows(c_ord, qb, 0)
    d_p, _ = _pad_rows(d_ord, qb, 0)
    t_p, _ = _pad_rows(tot, qb, 0)
    dk, pk, nn = _cdf.cdf_query_pallas(
        c_p, d_p, t_p, 0.0 if topk else threshold, max_items=max_items,
        queries_per_block=qb, chunks=chunks, topk=topk,
        interpret=not _on_tpu())
    return dk[:b], pk[:b], nn[:b]


@_annotate
@functools.partial(jax.jit,
                   static_argnames=("max_items", "chunks", "topk", "impl"))
@kernel_op(ref="cdf_query_fused_ref", pallas="cdf_query_fused_pallas")
def cdf_query_fused(rows: jax.Array, found: jax.Array,
                    cnt: jax.Array, dst: jax.Array, order: jax.Array,
                    tot: jax.Array, threshold, *, max_items: int = 16,
                    chunks: int = 0, topk: bool = False, impl: str = "auto"):
    """Fused inference: in-kernel row gather + CDF walk (cdf_gather.py).

    Takes pre-resolved rows[B] (0 where missing) + found[B] and the raw slab
    arrays; only queried rows are touched (scalar-prefetch DMA on TPU, one
    combined gather in the ref path).  Bit-identical to ``cdf_query`` over
    ``_ordered_rows`` output by the integer-walk contract.
    """
    topk = topk or threshold is None
    chunks = _cdf.auto_chunks(cnt.shape[1], chunks)
    if _use_ref(impl):
        return _ref.cdf_query_fused_ref(rows, found, cnt, dst, order, tot,
                                        None if topk else threshold,
                                        max_items)
    return _cg.cdf_query_fused_pallas(
        rows, found, cnt, dst, order, tot, 0.0 if topk else threshold,
        max_items=max_items, chunks=chunks, topk=topk,
        interpret=not _on_tpu())


@_annotate
@functools.partial(jax.jit, static_argnames=("n", "impl"))
@kernel_op(ref="topn_merge_ref", pallas=None)
def topn_merge(probs: jax.Array, dsts: jax.Array, srcs: jax.Array,
               *, n: int, impl: str = "auto"):
    """Cross-shard top-n merge: ``(srcs[n], dsts[n], probs[n])`` descending.

    Merges S per-shard descending top lists (``probs/dsts/srcs[S, M]``) into
    one globally descending n-list — the reduce step of the sharded headline
    query (``core/sharded.py`` all_gathers local answers, then merges).
    A fixed-shape scalar head-pointer merge over an (S, M) tile is branch-
    serial by nature and tiny (S = shards, M <= n), so every backend runs
    the ref merge; ``impl`` is still validated so dispatch stays uniform
    with the other ops.
    """
    _use_ref(impl)
    return _ref.topn_merge_ref(probs, dsts, srcs, n)


@_annotate
@functools.partial(jax.jit,
                   static_argnames=("k", "max_probes", "impl"))
@kernel_op(ref="draft_walk_ref", pallas="draft_walk_pallas")
def draft_walk(window: jax.Array, ht_keys: jax.Array, ht_vals: jax.Array,
               cnt: jax.Array, dst: jax.Array, ord0: jax.Array,
               *, k: int = 4, max_probes: int = 64, impl: str = "auto"):
    """One-shot k-step greedy draft walk (kernels/walk.py).

    window[B, order] recent tokens; the chain snapshot (src table + slabs +
    order heads) is immutable during a draft, so the whole k-step scan runs
    as one dispatch.  Returns ``(toks[B, k], ok[B, k] bool)``.
    """
    if _use_ref(impl):
        toks, oks = _ref.draft_walk_ref(window, ht_keys, ht_vals, cnt, dst,
                                        ord0, k=k, max_probes=max_probes)
        return toks, oks.astype(bool)
    qb = min(_wk.DEFAULT_QUERIES_PER_BLOCK, window.shape[0])
    win_p, b = _pad_rows(window, qb, 0)
    toks, oks = _wk.draft_walk_pallas(
        win_p, ht_keys, ht_vals, cnt, dst, ord0, k=k, max_probes=max_probes,
        queries_per_block=qb, valid=b, interpret=not _on_tpu())
    return toks[:b], oks[:b].astype(bool)
