"""Pallas TPU kernels for the paper's compute hot spots.

  * :mod:`repro.kernels.slab_update` — fused batched edge increment (§II.A)
  * :mod:`repro.kernels.oddeven`     — lock-free bubble sort, vectorised (§II.2)
  * :mod:`repro.kernels.cdf_query`   — chunked early-exit threshold inference
                                       (§II.B)
  * :mod:`repro.kernels.cdf_gather`  — fused row-gather + CDF walk (scalar
                                       prefetch; §II.B at the traffic level)
  * :mod:`repro.kernels.probe`       — shared open-addressing probe: per-row
                                       dst hash (§II.2) + flat src table (§II.1)
  * :mod:`repro.kernels.walk`        — one-shot k-step greedy draft walk
                                       (speculative decoding)

Public API lives in :mod:`repro.kernels.ops` (padding + backend dispatch);
``ref.py`` holds the pure-jnp oracles each kernel is tested against.
"""

from repro.kernels import ops  # noqa: F401
