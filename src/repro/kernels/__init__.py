"""Pallas TPU kernels for the paper's compute hot spots.

  * :mod:`repro.kernels.slab_update` — fused batched edge increment (§II.A)
  * :mod:`repro.kernels.oddeven`     — lock-free bubble sort, vectorised (§II.2)
  * :mod:`repro.kernels.cdf_query`   — threshold inference (§II.B)

Public API lives in :mod:`repro.kernels.ops` (padding + backend dispatch);
``ref.py`` holds the pure-jnp oracles each kernel is tested against.
"""

from repro.kernels import ops  # noqa: F401
