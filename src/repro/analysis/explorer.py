"""Deterministic interleaving explorer for the lock-free engine (DESIGN.md
§11, the dynamic half of the invariant catalog).

``tools/mcqlint`` proves the *declared* concurrency contract statically; this
module checks the *behaviour*: it runs real :class:`ShardedEngine` host-side
control flow (locks, EpochStore publish/acquire, WAL append/replay, stats
accounting) under a cooperative scheduler that owns every thread switch, and
explores the interleavings of ``observe``/``query``/``topn``/``checkpoint``/
``reassign``/recovery either exhaustively (DFS with CHESS-style preemption
bounding — most real races need one or two preemptions) or randomly (seeded).

Only the *device* compute is faked: the ``sh.make_*_fn`` factories and
``mc.counter_stats`` are patched with host-side stand-ins over a tiny
:class:`FakeState` (numpy leaves, so the real snapshot writer still works).
Each fake routing program bakes in the routing generation it was built for —
``resolved_ownership().num_buckets`` — and raises :class:`GenMismatch` when
dispatched against a snapshot of a different generation, which is exactly
the (program, snapshot) mispairing invariant I8.  Everything the invariants
actually live in — lock protocol, epoch store, WAL files — is the real code.

Regression contract (checked by ``tests/test_explorer.py`` and the CI
``--smoke``): with the shipped *pre-fix* bodies of three races the PR-4/PR-5
reviews caught (stats-dict lost update, route/snapshot mispairing, double
WAL replay during restore), the explorer finds each violation and the
violating schedule replays deterministically; on the current (fixed) code
paths every schedule is clean.

Determinism: a schedule is the sequence of thread choices at yield points;
scenario code is yield-deterministic (no wall clock, no host RNG), so a
recorded trace replays bit-identically — the explorer is its own minimiser
and reproducer.
"""

from __future__ import annotations

import argparse
import contextlib
import os
import random
import shutil
import sys
import tempfile
import threading
from collections import OrderedDict
from typing import (Any, Callable, Dict, List, NamedTuple, Optional,
                    Sequence, Tuple)

import numpy as np

# NOTE: jax (via the engine import) is needed only for jnp.asarray on tiny
# host batches inside the engine's padding path; no device compute runs.
from repro import faults
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.runtime.fault_tolerance import (EngineWriteUnavailable,
                                           RetryPolicy)
from repro.serve import engine as engine_mod
from repro.sharding.ownership import Ownership


# ---------------------------------------------------------------------------
# cooperative scheduler
# ---------------------------------------------------------------------------


class _Aborted(BaseException):
    """Raised inside a scheduled thread to unwind it after a deadlock."""


class _ThreadState:
    def __init__(self, name: str):
        self.name = name
        self.event = threading.Event()
        self.thread: Optional[threading.Thread] = None
        self.done = False
        self.error: Optional[BaseException] = None
        self.pred: Optional[Callable[[], bool]] = None
        self.tag = "start"
        self.abort = False


class Scheduler:
    """Cooperative, driver-controlled scheduler.

    Exactly one scenario thread runs at a time; at every ``yield_point`` the
    running thread parks and the driver (the test's main thread) picks the
    next one, so the interleaving IS the recorded ``trace``.  Threads never
    registered with the scheduler (setup/check code on the main thread) pass
    through ``yield_point`` untouched — setup is atomic by construction.

    ``yield_tags`` optionally restricts instrumentation to yield points whose
    tag starts with one of the given prefixes: scenarios use it to bound the
    decision-point count for exhaustive exploration (the same filter applies
    to the buggy and the fixed variant, so the comparison stays honest).
    """

    def __init__(self, yield_tags: Optional[Sequence[str]] = None):
        self._threads: "OrderedDict[str, _ThreadState]" = OrderedDict()
        self._ready = threading.Event()
        self._local = threading.local()
        self._yield_tags = (tuple(yield_tags)
                            if yield_tags is not None else None)
        self.trace: List[str] = []
        self.runnables: List[Tuple[str, ...]] = []
        self.deadlock = False

    # -- thread side ----------------------------------------------------
    def current(self) -> Optional[str]:
        return getattr(self._local, "name", None)

    def yield_point(self, tag: str,
                    pred: Optional[Callable[[], bool]] = None) -> None:
        name = self.current()
        if name is None:
            return  # unregistered (main) thread: setup/check is atomic
        if (self._yield_tags is not None
                and not any(tag.startswith(p) for p in self._yield_tags)):
            # Filtered out — no decision point here.  But blocking must
            # never be skipped: when the pred is currently false the thread
            # has to park or it would break mutual exclusion.  When it is
            # true, proceeding without a yield is atomic (no other thread
            # runs concurrently in the cooperative model).
            if pred is None or pred():
                return
        ts = self._threads[name]
        if ts.abort:
            raise _Aborted()
        ts.tag, ts.pred = tag, pred
        self._ready.set()
        ts.event.wait()
        ts.event.clear()
        if ts.abort:
            raise _Aborted()

    def spawn(self, name: str, fn: Callable[[], Any]) -> None:
        ts = _ThreadState(name)

        def body():
            self._local.name = name
            ts.event.wait()       # parked at "start" until first scheduled
            ts.event.clear()
            try:
                if ts.abort:      # deadlock teardown before we ever ran
                    raise _Aborted()
                fn()
            except _Aborted:
                pass
            except BaseException as exc:  # captured, surfaced as violation
                ts.error = exc
            finally:
                ts.done = True
                self._ready.set()

        ts.thread = threading.Thread(target=body, daemon=True,
                                     name=f"explorer:{name}")
        self._threads[name] = ts
        ts.thread.start()

    # -- driver side ----------------------------------------------------
    def run(self, controller) -> None:
        """Drive all spawned threads to completion (or deadlock)."""
        current: Optional[str] = None
        while True:
            alive = [ts for ts in self._threads.values() if not ts.done]
            if not alive:
                return
            runnable = tuple(ts.name for ts in alive
                             if ts.pred is None or ts.pred())
            if not runnable:
                self.deadlock = True
                self._abort_all(alive)
                return
            choice = controller.choose(list(runnable), current)
            self.runnables.append(runnable)
            self.trace.append(choice)
            current = choice
            ts = self._threads[choice]
            ts.pred = None
            self._ready.clear()
            ts.event.set()
            self._ready.wait()

    def _abort_all(self, alive: List[_ThreadState]) -> None:
        for ts in alive:
            ts.abort = True
            ts.event.set()
        for ts in alive:
            ts.thread.join(timeout=5.0)


# -- schedule controllers -------------------------------------------------


class _PrefixController:
    """Replays a recorded choice prefix, then continues with the default
    policy (stay on the current thread while it is runnable — zero added
    preemptions, so a prefix's preemption count is the whole trace's)."""

    def __init__(self, prefix: Sequence[str]):
        self.prefix = list(prefix)
        self.i = 0
        self.diverged = False

    def choose(self, runnable: List[str], current: Optional[str]) -> str:
        runnable = sorted(runnable)
        if self.i < len(self.prefix):
            want = self.prefix[self.i]
            self.i += 1
            if want in runnable:
                return want
            self.diverged = True  # scenario was not schedule-deterministic
        else:
            self.i += 1
        if current is not None and current in runnable:
            return current
        return runnable[0]


class _RandomController:
    def __init__(self, rng: random.Random):
        self.rng = rng

    def choose(self, runnable: List[str], current: Optional[str]) -> str:
        return self.rng.choice(sorted(runnable))


# ---------------------------------------------------------------------------
# instrumentation: scheduler-aware locks, stats, store
# ---------------------------------------------------------------------------


class SchedLock:
    """Drop-in ``threading.Lock`` replacement whose acquire is a yield point.

    Blocking is expressed as a predicate (*runnable once the owner clears*)
    rather than an OS wait, so the driver always knows exactly which threads
    can make progress — a schedule where no predicate holds is a detected
    deadlock, not a hang.
    """

    def __init__(self, sched: Scheduler, name: str):
        self._sched = sched
        self._name = name
        self._owner: Optional[str] = None

    def acquire(self) -> bool:
        me = self._sched.current()
        if me is None:  # main-thread setup: no contention by construction
            if self._owner is not None:
                raise RuntimeError(
                    f"setup acquired {self._name} while a scenario thread "
                    f"holds it")
            self._owner = "<main>"
            return True
        self._sched.yield_point(f"lock:{self._name}",
                                pred=lambda: self._owner is None)
        assert self._owner is None
        self._owner = me
        return True

    def release(self) -> None:
        self._owner = None

    def locked(self) -> bool:
        return self._owner is not None

    def __enter__(self) -> "SchedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class InstrumentedStats(dict):
    """The engine's ``stats`` dict with a yield point before every write.

    A counter bump is ``read -> add -> write``; parking the writer right
    before the write is what lets the explorer interleave a full second
    read-modify-write in between — the schedule that turns an unguarded
    ``stats[k] += 1`` into a lost update.  Reads stay yield-free (the read
    half of the race needs no extra schedule control, and it keeps the
    decision-point count down).
    """

    def __init__(self, sched: Scheduler, data: Dict[str, Any]):
        super().__init__(data)
        self._sched = sched

    def __setitem__(self, key, value):
        self._sched.yield_point(f"stats:set:{key}")
        super().__setitem__(key, value)

    def update(self, other=(), **kw):  # route through __setitem__
        items = other.items() if hasattr(other, "items") else other
        for k, v in items:
            self[k] = v
        for k, v in kw.items():
            self[k] = v


def _instrument_store(sched: Scheduler, store) -> None:
    """Yield before snapshot pin and before publish: the two moments the
    RCU-analogue hand-off can interleave with a routing swap."""
    orig_acquire, orig_publish = store.acquire, store.publish

    def acquire():
        sched.yield_point("store:acquire")
        return orig_acquire()

    def publish(state):
        sched.yield_point("store:publish")
        return orig_publish(state)

    store.acquire, store.publish = acquire, publish


# ---------------------------------------------------------------------------
# fake kernel layer (host-side stand-ins for the sharded device programs)
# ---------------------------------------------------------------------------


class GenMismatch(AssertionError):
    """A routed program was dispatched against a snapshot of a different
    routing generation — the I8 (program, snapshot) pairing violation."""


class FakeState(NamedTuple):
    total: np.ndarray      # int64 scalar: sum of applied weights
    markers: np.ndarray    # int32 [n]: src[0] of each applied batch, ordered
    n_applied: np.ndarray  # int64 scalar: batches applied
    gen: np.ndarray        # int32 scalar: routing generation (num_buckets)


def _gen_of(scfg: sh.ShardedConfig) -> int:
    return int(scfg.resolved_ownership().num_buckets)


def _fake_init(scfg, mesh) -> FakeState:
    return FakeState(np.int64(0), np.zeros((0,), np.int32), np.int64(0),
                     np.int32(_gen_of(scfg)))


def _check_gen(state: FakeState, my_gen: int, what: str) -> None:
    if int(state.gen) != my_gen:
        raise GenMismatch(
            f"{what} program built for routing generation {my_gen} "
            f"dispatched against snapshot generation {int(state.gen)}")


def _fake_make_update_fn(scfg, mesh):
    my_gen = _gen_of(scfg)

    def fn(state, src, dst, w):
        _check_gen(state, my_gen, "update")
        marker = np.int32([int(np.asarray(src)[0])])
        return FakeState(
            np.int64(int(state.total) + int(np.asarray(w).sum())),
            np.concatenate([state.markers, marker]),
            np.int64(int(state.n_applied) + 1),
            state.gen)

    return fn


def _fake_make_maintain_fn(scfg, mesh, total_threshold=0):
    my_gen = _gen_of(scfg)

    def fn(state):
        _check_gen(state, my_gen, "maintain")
        return state

    return fn


def _fake_make_query_fn(scfg, mesh, *, threshold, max_items):
    my_gen = _gen_of(scfg)

    def fn(state, src):
        _check_gen(state, my_gen, "query")
        b = int(np.asarray(src).shape[0])
        return (np.zeros((b, max_items), np.int32),
                np.zeros((b, max_items), np.float32),
                np.zeros((b,), np.int32),
                np.zeros((b,), np.int32))

    return fn


def _fake_make_topn_fn(scfg, mesh, n):
    my_gen = _gen_of(scfg)

    def fn(state):
        _check_gen(state, my_gen, "topn")
        return (np.zeros((n,), np.int32), np.zeros((n,), np.int32),
                np.zeros((n,), np.float32), np.int32(0))

    return fn


def _fake_counter_stats(state) -> Dict[str, int]:
    return {"fake_total": int(state.total),
            "fake_batches": int(state.n_applied)}


@contextlib.contextmanager
def fake_kernel_layer():
    """Patch the ``sh.make_*`` factories + ``mc.counter_stats`` the engine
    resolves at call time, leaving every host-side code path real."""
    saved = (sh.init_sharded, sh.make_update_fn, sh.make_maintain_fn,
             sh.make_query_fn, sh.make_topn_fn, mc.counter_stats)
    sh.init_sharded = _fake_init
    sh.make_update_fn = _fake_make_update_fn
    sh.make_maintain_fn = _fake_make_maintain_fn
    sh.make_query_fn = _fake_make_query_fn
    sh.make_topn_fn = _fake_make_topn_fn
    mc.counter_stats = _fake_counter_stats
    try:
        yield
    finally:
        (sh.init_sharded, sh.make_update_fn, sh.make_maintain_fn,
         sh.make_query_fn, sh.make_topn_fn, mc.counter_stats) = saved


class _FakeMesh:
    """Sentinel passed as ``mesh``: only ever handed to the fake factories."""

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return "<explorer fake mesh>"


def build_engine(sched: Scheduler, *, wal_dir: Optional[str] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0,
                 **cfg_kw) -> engine_mod.ShardedEngine:
    """A real ShardedEngine over the fake kernel layer, with every lock,
    the stats dict, and the EpochStore hand-offs under schedule control."""
    base = mc.MCConfig(num_rows=8, capacity=4)
    scfg = sh.ShardedConfig(base=base, num_shards=1,
                            ownership=Ownership(num_shards=1))
    cfg = engine_mod.ShardedServeConfig(
        sharded=scfg, snapshot_dir=snapshot_dir,
        snapshot_every=snapshot_every, wal_dir=wal_dir, wal_fsync="never",
        **cfg_kw)
    eng = engine_mod.ShardedEngine(cfg, mesh=_FakeMesh())
    for name in eng._MCQ_LOCK_ORDER:
        setattr(eng, name, SchedLock(sched, name))
    eng.stats = InstrumentedStats(sched, dict(eng.stats))
    _instrument_store(sched, eng.store)
    # identity padding: num_shards == 1 and the fakes ignore routing shapes
    eng._pad = lambda *arrays: (*arrays, int(np.asarray(arrays[0]).shape[0]))
    eng._reingest = lambda old_state, scfg2: FakeState(
        old_state.total, old_state.markers, old_state.n_applied,
        np.int32(_gen_of(scfg2)))
    return eng


# ---------------------------------------------------------------------------
# the shipped pre-fix bodies (the races the PR-4/PR-5 reviews caught)
# ---------------------------------------------------------------------------
# These are mechanical reverts of the fixed code paths, kept verbatim so the
# explorer provably re-finds each historical race — the regression contract
# for the explorer itself.


def _reverted_query_stats(eng, src) -> None:
    """PR-4 pre-review ``query``: the counter read-modify-write runs outside
    ``_stats_lock`` — two concurrent queries can lose an increment."""
    import jax.numpy as jnp
    t = float(eng.cfg.threshold)
    k = int(eng.cfg.max_items)
    with eng._route_lock:
        fn = eng._cached_fn(
            eng._query_fns, (t, k),
            lambda: sh.make_query_fn(eng.cfg.sharded, eng.mesh,
                                     threshold=t, max_items=k))
        snap = eng.store.acquire()
    src, b = eng._pad(jnp.asarray(src, jnp.int32))
    try:
        d, p, n, dropped = fn(snap.state, src)
    finally:
        eng.store.release(snap)
    # THE BUG: unguarded RMW on the shared stats dict
    eng.stats["queries"] = eng.stats["queries"] + 1
    eng.stats["query_dropped"] = (eng.stats["query_dropped"]
                                  + int(np.sum(np.asarray(dropped))))


def _reverted_query_unpaired(eng, src) -> None:
    """PR-4 pre-review ``query``: program fetch and snapshot pin are not
    under ``_route_lock`` — a concurrent reassign can slip its swap between
    them and the reader pairs mismatched routing generations."""
    import jax.numpy as jnp
    t = float(eng.cfg.threshold)
    k = int(eng.cfg.max_items)
    # THE BUG: no route lock around the (program, snapshot) pairing
    fn = eng._cached_fn(
        eng._query_fns, (t, k),
        lambda: sh.make_query_fn(eng.cfg.sharded, eng.mesh,
                                 threshold=t, max_items=k))
    snap = eng.store.acquire()
    src, b = eng._pad(jnp.asarray(src, jnp.int32))
    try:
        d, p, n, dropped = fn(snap.state, src)
    finally:
        eng.store.release(snap)
    with eng._stats_lock:
        eng.stats["queries"] = eng.stats["queries"] + 1


def _fresh_state(eng) -> FakeState:
    return FakeState(np.int64(0), np.zeros((0,), np.int32), np.int64(0),
                     np.int32(_gen_of(eng.cfg.sharded)))


def _reverted_restore(eng) -> int:
    """PR-5 pre-review recovery driver: the snapshot reset and each replayed
    record take the write lock *separately*.  A live ``observe`` slipping in
    mid-replay WAL-appends its batch AND the still-open replay generator
    re-reads it — applied twice."""
    with eng._write_lock:
        with eng._route_lock:
            eng.store.publish(_fresh_state(eng))
        eng._seq = -1
    replayed = 0
    # THE BUG: lock released between records; the generator stays open across
    # the gaps and re-reads concurrent appends when it reaches their segment
    for seq, src, dst, w in eng.wal.replay(after_seq=-1):
        with eng._write_lock:
            eng._seq = seq
            eng._apply_locked(src, dst, w)
        replayed += 1
    return replayed


def _fixed_restore(eng) -> int:
    """The shipped driver shape (mirrors ``ShardedEngine.restore``): one
    write-lock hold end to end, reset inside — a concurrent observe either
    fully precedes the recovery (its record replays once, its in-memory
    apply is reset away) or fully follows it."""
    replayed = 0
    with eng._write_lock:
        with eng._route_lock:
            eng.store.publish(_fresh_state(eng))
        eng._seq = -1
        for seq, src, dst, w in eng.wal.replay(after_seq=-1):
            eng._seq = seq
            eng._apply_locked(src, dst, w)
            replayed += 1
    return replayed


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


class ScenarioInstance(NamedTuple):
    threads: "OrderedDict[str, Callable[[], Any]]"
    check: Callable[[], List[str]]
    cleanup: Callable[[], None]


class Scenario:
    """A named concurrency scenario with a buggy (``reverted=True``) and a
    fixed variant sharing the same schedule space."""

    name: str = ""
    yield_tags: Optional[Tuple[str, ...]] = None

    def build(self, sched: Scheduler, reverted: bool) -> ScenarioInstance:
        raise NotImplementedError


class StatsLostUpdate(Scenario):
    """Two concurrent queries bump ``stats['queries']``; invariant: the
    count conserves (== 2).  Dynamic side of invariant I1."""

    name = "stats_lost_update"

    def build(self, sched, reverted):
        eng = build_engine(sched)
        src = np.array([3], np.int32)
        if reverted:
            body = lambda: _reverted_query_stats(eng, src)  # noqa: E731
        else:
            body = lambda: eng.query(src)                   # noqa: E731

        def check():
            out = []
            if eng.stats["queries"] != 2:
                out.append(
                    f"counter conservation: stats['queries'] == "
                    f"{eng.stats['queries']} after 2 queries (lost update)")
            if any(n != 0 for n in eng.store._readers.values()):
                out.append(f"leaked epoch readers: {eng.store._readers}")
            return out

        threads = OrderedDict((("q1", body), ("q2", body)))
        return ScenarioInstance(threads, check, lambda: None)


class RouteSnapshotMispairing(Scenario):
    """A reader races a live ``reassign``; invariant: every dispatched
    (program, snapshot) pair is generation-consistent (I8).  The fake
    programs raise :class:`GenMismatch` on a mispairing, which the explorer
    surfaces as the violation."""

    name = "route_snapshot_mispairing"

    def build(self, sched, reverted):
        eng = build_engine(sched)
        src = np.array([5], np.int32)
        eng.query(src)  # pre-warm the routed-program cache (main thread)
        new_own = Ownership(num_shards=1, num_buckets=512)
        if reverted:
            reader = lambda: _reverted_query_unpaired(eng, src)  # noqa: E731
        else:
            reader = lambda: eng.query(src)                      # noqa: E731

        def check():
            out = []
            if any(n != 0 for n in eng.store._readers.values()):
                out.append(f"leaked epoch readers: {eng.store._readers}")
            if _gen_of(eng.cfg.sharded) != int(
                    eng.store._snap.state.gen):
                out.append("installed routing and published snapshot "
                           "disagree on generation after the swap")
            return out

        threads = OrderedDict((
            ("reader", reader),
            ("rebalance", lambda: eng.reassign(new_own)),
        ))
        return ScenarioInstance(threads, check, lambda: None)


class WalDoubleReplay(Scenario):
    """Recovery races a live writer; invariant: after both finish, every
    observed batch is applied exactly once (WAL exactly-once replay, the
    dynamic side of invariant I3).

    Layout matters: 3 pre-seeded batches at ``segment_records=2`` leave a
    closed segment (seq 0, 1) and an open one (seq 2).  The replay generator
    snapshots the segment list once and reads each segment when REACHED, so
    a concurrent append (seq 3) into the open segment is re-read by a replay
    that has not reached it yet — if the driver lets the writer in."""

    name = "wal_double_replay"
    yield_tags = ("lock:_write_lock", "store:")

    def build(self, sched, reverted):
        tmp = tempfile.mkdtemp(prefix="mcq-explorer-")
        eng = build_engine(sched, wal_dir=os.path.join(tmp, "wal"))
        eng.wal.segment_records = 2
        dst = np.array([0], np.int32)
        for marker in (0, 1, 2):   # main thread: atomic pre-seed
            eng.observe(np.array([marker], np.int32), dst)
        expected = [0, 1, 2, 99]
        restore_fn = _reverted_restore if reverted else _fixed_restore

        def check():
            out = []
            markers = sorted(int(m)
                             for m in eng.store._snap.state.markers)
            if markers != expected:
                out.append(
                    f"exactly-once replay: applied markers {markers}, "
                    f"expected {expected} (each batch exactly once)")
            if eng._seq != 3:
                out.append(f"wal position: _seq == {eng._seq}, expected 3")
            return out

        def cleanup():
            eng.wal.close()
            shutil.rmtree(tmp, ignore_errors=True)

        threads = OrderedDict((
            ("recover", lambda: restore_fn(eng)),
            ("writer", lambda: eng.observe(np.array([99], np.int32), dst)),
        ))
        return ScenarioInstance(threads, check, cleanup)


class MixedHeadScenario(Scenario):
    """HEAD-only smoke: observe / query / topn / checkpoint interleave
    freely; invariants: every counter conserves, the WAL position matches
    the applied batches, no reader leaks, no deadlock.  No reverted variant
    — this is the 'current code is clean under schedule stress' probe."""

    name = "mixed_head"

    def build(self, sched, reverted):
        assert not reverted, "mixed_head has no reverted variant"
        tmp = tempfile.mkdtemp(prefix="mcq-explorer-")
        eng = build_engine(sched, wal_dir=os.path.join(tmp, "wal"),
                          snapshot_dir=os.path.join(tmp, "snap"))
        dst = np.array([0], np.int32)
        eng.observe(np.array([1], np.int32), dst)  # seed state (atomic)

        def check():
            out = []
            stats = dict(eng.stats)
            for key, want in (("updates", 2), ("queries", 1),
                              ("topn_calls", 1), ("snapshots", 1)):
                if stats[key] != want:
                    out.append(f"counter conservation: stats[{key!r}] == "
                               f"{stats[key]}, expected {want}")
            if any(n != 0 for n in eng.store._readers.values()):
                out.append(f"leaked epoch readers: {eng.store._readers}")
            markers = sorted(int(m)
                             for m in eng.store._snap.state.markers)
            if markers != [1, 7]:
                out.append(f"applied markers {markers}, expected [1, 7]")
            return out

        def cleanup():
            eng.wal.close()
            shutil.rmtree(tmp, ignore_errors=True)

        threads = OrderedDict((
            ("writer", lambda: eng.observe(np.array([7], np.int32), dst)),
            ("query", lambda: eng.query(np.array([1], np.int32))),
            ("topn", lambda: eng.topn(4)),
            ("ckpt", lambda: eng.checkpoint(sync=True)),
        ))
        return ScenarioInstance(threads, check, cleanup)


def _bridge_failpoints(sched: Scheduler) -> None:
    """Make every failpoint site a schedule decision point: the registry
    observer fires on each hit (DESIGN.md §12 — failpoints double as the
    explorer's IO-edge yield points), so a fault can be interleaved with
    readers at exactly the instant the IO edge runs."""
    faults.set_observer(
        lambda name, ctx: sched.yield_point(f"fault:{name}"))


#: zero-delay ladder: retries are schedule steps, not wall-clock waits
_NO_BACKOFF = RetryPolicy(max_attempts=2, base_delay_s=0.0, max_delay_s=0.0)


class FaultTransientWrite(Scenario):
    """A one-shot injected WAL fault races a concurrent query; invariants:
    the retry ladder absorbs the fault invisibly (the batch lands exactly
    once, ``wal_retries`` counts one round), the reader completes cleanly
    whatever instant the fault fires, and no epoch reader leaks.  HEAD-only
    — the dynamic side of the A14 retry contract."""

    name = "fault_transient_write"
    yield_tags = ("fault:", "lock:_write_lock", "store:")

    def build(self, sched, reverted):
        assert not reverted, "fault scenarios have no reverted variant"
        tmp = tempfile.mkdtemp(prefix="mcq-explorer-")
        eng = build_engine(sched, wal_dir=os.path.join(tmp, "wal"),
                          retry=_NO_BACKOFF)
        _bridge_failpoints(sched)
        dst = np.array([0], np.int32)
        eng.observe(np.array([1], np.int32), dst)   # seed state (atomic)
        faults.arm("wal.append.write",
                   faults.FaultInjected("wal.append.write"), count=1)

        def check():
            out = []
            stats = dict(eng.stats)
            for key, want in (("updates", 2), ("queries", 1),
                              ("wal_retries", 1)):
                if stats[key] != want:
                    out.append(f"counter conservation: stats[{key!r}] == "
                               f"{stats[key]}, expected {want}")
            markers = sorted(int(m) for m in eng.store._snap.state.markers)
            if markers != [1, 7]:
                out.append(f"applied markers {markers}, expected [1, 7] "
                           f"(retried batch must land exactly once)")
            if eng._seq != 1:
                out.append(f"wal position: _seq == {eng._seq}, expected 1")
            if not eng.write_available:
                out.append("transient fault escalated to poison")
            if any(n != 0 for n in eng.store._readers.values()):
                out.append(f"leaked epoch readers: {eng.store._readers}")
            return out

        def cleanup():
            faults.reset()
            faults.set_observer(None)
            eng.wal.close()
            shutil.rmtree(tmp, ignore_errors=True)

        threads = OrderedDict((
            ("writer", lambda: eng.observe(np.array([7], np.int32), dst)),
            ("query", lambda: eng.query(np.array([1], np.int32))),
        ))
        return ScenarioInstance(threads, check, cleanup)


class FaultPoisonedWrite(Scenario):
    """A persistent injected WAL fault (ENOSPC) races a concurrent query;
    invariants: the writer escalates to ``EngineWriteUnavailable`` without
    publishing anything (markers unchanged, ``_seq`` parked), the write
    lock is released (poison is a state, not a held lock), and the reader
    serves the last published epoch cleanly at every interleaving — the
    dynamic side of the A13 escalation contract."""

    name = "fault_poisoned_write"
    yield_tags = ("fault:", "lock:_write_lock", "store:")

    def build(self, sched, reverted):
        assert not reverted, "fault scenarios have no reverted variant"
        tmp = tempfile.mkdtemp(prefix="mcq-explorer-")
        eng = build_engine(sched, wal_dir=os.path.join(tmp, "wal"),
                          retry=_NO_BACKOFF)
        _bridge_failpoints(sched)
        dst = np.array([0], np.int32)
        eng.observe(np.array([1], np.int32), dst)   # seed state (atomic)
        import errno as _errno
        faults.arm("wal.append.write",
                   faults.FaultInjected("wal.append.write", _errno.ENOSPC))
        seen = {}

        def writer():
            try:
                eng.observe(np.array([7], np.int32), dst)
            except EngineWriteUnavailable:
                seen["escalated"] = True

        def check():
            out = []
            if not seen.get("escalated"):
                out.append("persistent fault did not raise "
                           "EngineWriteUnavailable")
            if eng.write_available:
                out.append("write path not poisoned after persistent fault")
            markers = sorted(int(m) for m in eng.store._snap.state.markers)
            if markers != [1]:
                out.append(f"applied markers {markers}, expected [1] "
                           f"(faulted batch must never publish)")
            if eng._seq != 0:
                out.append(f"wal position: _seq == {eng._seq}, expected 0")
            if eng._write_lock.locked():
                out.append("write lock still held after escalation")
            if eng.stats["queries"] != 1:
                out.append(f"reader did not complete: queries == "
                           f"{eng.stats['queries']}")
            if any(n != 0 for n in eng.store._readers.values()):
                out.append(f"leaked epoch readers: {eng.store._readers}")
            return out

        def cleanup():
            faults.reset()
            faults.set_observer(None)
            eng.wal.close()
            shutil.rmtree(tmp, ignore_errors=True)

        threads = OrderedDict((
            ("writer", writer),
            ("query", lambda: eng.query(np.array([1], np.int32))),
        ))
        return ScenarioInstance(threads, check, cleanup)


RACE_SCENARIOS: Tuple[Scenario, ...] = (
    StatsLostUpdate(), RouteSnapshotMispairing(), WalDoubleReplay())

SCENARIOS: Dict[str, Scenario] = {
    s.name: s for s in RACE_SCENARIOS + (MixedHeadScenario(),
                                         FaultTransientWrite(),
                                         FaultPoisonedWrite())}


# ---------------------------------------------------------------------------
# exploration
# ---------------------------------------------------------------------------


class RunResult(NamedTuple):
    trace: Tuple[str, ...]
    runnables: Tuple[Tuple[str, ...], ...]
    violations: Tuple[str, ...]
    deadlock: bool


class Exploration(NamedTuple):
    scenario: str
    reverted: bool
    mode: str
    runs: int
    exhausted: bool          # DFS drained its frontier within max_runs
    violations: Tuple[RunResult, ...]

    @property
    def found(self) -> bool:
        return bool(self.violations)

    @property
    def first_trace(self) -> Optional[Tuple[str, ...]]:
        return self.violations[0].trace if self.violations else None


def _run_once(scenario: Scenario, reverted: bool,
              controller) -> RunResult:
    sched = Scheduler(scenario.yield_tags)
    with fake_kernel_layer():
        inst = scenario.build(sched, reverted)
        try:
            for name, fn in inst.threads.items():
                sched.spawn(name, fn)
            sched.run(controller)
            violations: List[str] = []
            if sched.deadlock:
                held = {name: ts.tag
                        for name, ts in sched._threads.items()
                        if not ts.done}
                violations.append(f"deadlock: no runnable thread, "
                                  f"blocked at {held}")
            for name, ts in sched._threads.items():
                if ts.error is not None:
                    violations.append(
                        f"{name}: {type(ts.error).__name__}: {ts.error}")
            if not sched.deadlock:
                violations.extend(inst.check())
        finally:
            inst.cleanup()
    if getattr(controller, "diverged", False):
        violations.append("schedule replay diverged (scenario is not "
                          "yield-deterministic)")
    return RunResult(tuple(sched.trace), tuple(sched.runnables),
                     tuple(violations), sched.deadlock)


def _preemptions(trace: Sequence[str],
                 runnables: Sequence[Tuple[str, ...]]) -> int:
    n = 0
    for i in range(1, len(trace)):
        if trace[i] != trace[i - 1] and trace[i - 1] in runnables[i]:
            n += 1
    return n


def explore(scenario: Scenario, *, reverted: bool, mode: str = "dfs",
            preemption_bound: int = 2, max_runs: int = 4000,
            random_runs: int = 64, seed: int = 0,
            stop_on_violation: bool = True) -> Exploration:
    """Explore the scenario's schedule space.

    ``dfs``: exhaustive over schedules with at most ``preemption_bound``
    preemptions (a context switch away from a still-runnable thread), the
    CHESS result that most concurrency bugs need very few.  ``random``:
    ``random_runs`` seeded uniform schedules.  Both are deterministic.
    """
    violations: List[RunResult] = []
    runs = 0
    exhausted = False
    if mode == "dfs":
        stack: List[List[str]] = [[]]
        while stack and runs < max_runs:
            prefix = stack.pop()
            res = _run_once(scenario, reverted, _PrefixController(prefix))
            runs += 1
            if res.violations:
                violations.append(res)
                if stop_on_violation:
                    break
            # branch: alternatives at every decision at/after the prefix
            # (earlier points were branched when this prefix was created)
            for i in range(len(prefix), len(res.trace)):
                for alt in res.runnables[i]:
                    if alt == res.trace[i]:
                        continue
                    cand = list(res.trace[:i]) + [alt]
                    if _preemptions(cand, res.runnables) <= preemption_bound:
                        stack.append(cand)
        exhausted = not stack
    elif mode == "random":
        rng = random.Random(seed)
        for _ in range(random_runs):
            if runs >= max_runs:
                break
            res = _run_once(scenario, reverted, _RandomController(rng))
            runs += 1
            if res.violations:
                violations.append(res)
                if stop_on_violation:
                    break
        exhausted = False
    else:
        raise ValueError(f"unknown mode {mode!r} (dfs | random)")
    return Exploration(scenario.name, reverted, mode, runs, exhausted,
                       tuple(violations))


def replay(scenario: Scenario, *, reverted: bool,
           trace: Sequence[str]) -> RunResult:
    """Re-run one recorded schedule; bit-identical by construction."""
    return _run_once(scenario, reverted, _PrefixController(trace))


# ---------------------------------------------------------------------------
# CLI: the CI smoke gate
# ---------------------------------------------------------------------------


def _xml_escape(s: str) -> str:
    return (s.replace("&", "&amp;").replace("<", "&lt;")
            .replace(">", "&gt;").replace('"', "&quot;"))


def _write_junit(path: str, cases: List[Tuple[str, Optional[str]]]) -> None:
    failures = sum(1 for _, msg in cases if msg is not None)
    lines = ['<?xml version="1.0" encoding="utf-8"?>',
             f'<testsuite name="explorer" tests="{len(cases)}" '
             f'failures="{failures}">']
    for name, msg in cases:
        lines.append(f'  <testcase classname="repro.analysis.explorer" '
                     f'name="{_xml_escape(name)}">')
        if msg is not None:
            lines.append(f'    <failure message="violation">'
                         f'{_xml_escape(msg)}</failure>')
        lines.append('  </testcase>')
    lines.append('</testsuite>')
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def _smoke(junit: Optional[str], seed: int) -> int:
    """The CI gate: every historical race is re-found when its fix is
    reverted, every scenario is clean on the current code."""
    cases: List[Tuple[str, Optional[str]]] = []
    ok = True
    for scenario in RACE_SCENARIOS:
        rev = explore(scenario, reverted=True)
        msg = None
        if not rev.found:
            msg = (f"explorer failed to re-find the reverted race "
                   f"({rev.runs} schedules explored)")
        else:
            seen = replay(scenario, reverted=True, trace=rev.first_trace)
            if not seen.violations:
                msg = "violating schedule did not replay deterministically"
        cases.append((f"{scenario.name}:reverted", msg))
        ok &= msg is None
        status = "ok" if msg is None else "FAIL"
        detail = (f"violation in {rev.runs} schedules, trace length "
                  f"{len(rev.first_trace or ())}" if rev.found
                  else "no violation")
        print(f"[explorer] {scenario.name:28s} reverted: {status} "
              f"({detail})")
    for scenario in SCENARIOS.values():
        head = explore(scenario, reverted=False, stop_on_violation=True)
        msg = None
        if head.found:
            first = head.violations[0]
            msg = (f"violation on HEAD: {'; '.join(first.violations)} "
                   f"(trace {' '.join(first.trace)})")
        cases.append((f"{scenario.name}:head", msg))
        ok &= msg is None
        status = "ok" if msg is None else "FAIL"
        print(f"[explorer] {scenario.name:28s} head:     {status} "
              f"({head.runs} schedules, "
              f"{'exhausted' if head.exhausted else 'capped'})")
    # seeded random stress on the mixed scenario rides on top of its DFS
    mixed = SCENARIOS["mixed_head"]
    rnd = explore(mixed, reverted=False, mode="random", random_runs=64,
                  seed=seed)
    msg = None
    if rnd.found:
        first = rnd.violations[0]
        msg = f"violation on HEAD (random): {'; '.join(first.violations)}"
    cases.append(("mixed_head:random", msg))
    ok &= msg is None
    print(f"[explorer] mixed_head random ({rnd.runs} schedules, seed "
          f"{seed}): {'ok' if msg is None else 'FAIL'}")
    if junit:
        _write_junit(junit, cases)
    return 0 if ok else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.explorer",
        description="deterministic interleaving explorer for the engine")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI gate: reverted races re-found, HEAD "
                         "clean")
    ap.add_argument("--scenario", choices=sorted(SCENARIOS),
                    help="explore one scenario")
    ap.add_argument("--reverted", action="store_true",
                    help="use the pre-fix body (race scenarios only)")
    ap.add_argument("--mode", choices=("dfs", "random"), default="dfs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runs", type=int, default=64,
                    help="random-mode schedule count")
    ap.add_argument("--junit", help="write a junit XML report here")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke(args.junit, args.seed)
    if not args.scenario:
        ap.error("need --smoke or --scenario")
    result = explore(SCENARIOS[args.scenario], reverted=args.reverted,
                     mode=args.mode, seed=args.seed,
                     random_runs=args.runs, stop_on_violation=True)
    print(f"{result.scenario}: {result.runs} schedules explored "
          f"({'exhausted' if result.exhausted else 'capped'})")
    for res in result.violations:
        print(f"  violation: {'; '.join(res.violations)}")
        print(f"  schedule:  {' '.join(res.trace)}")
    return 1 if result.found else 0


if __name__ == "__main__":
    sys.exit(main())
