"""Static/dynamic analysis substrate (DESIGN.md §11).

``invariants`` holds the zero-cost annotation decorators the engine code
declares its concurrency contract with (``@requires_lock``, ``@kernel_op``);
``tools/mcqlint`` checks the declarations statically, ``explorer`` checks the
interleaving behaviour dynamically.  This ``__init__`` deliberately imports
nothing heavyweight: ``repro.serve.engine`` and ``repro.core.epoch`` import
``repro.analysis.invariants`` at module load, so anything here is on the
serving import path.
"""

from repro.analysis.invariants import kernel_op, requires_lock

__all__ = ["kernel_op", "requires_lock"]
