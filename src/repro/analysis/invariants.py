"""Machine-checkable concurrency/kernel contract annotations (DESIGN.md §11).

The lock-freedom story of the engine rests on a handful of invariants that
used to live only in prose: the single-writer publish cycle, WAL-append-
before-apply, sidecar-before-manifest-rename, the (routing program, snapshot)
pairing, and kernel/ref parity.  This module is the *declaration* side of
making them machine-checked:

* :func:`requires_lock` — annotates a function whose **caller** must hold the
  named lock(s).  Zero-cost by default (returns the function unchanged after
  attaching metadata); with ``MCQ_RUNTIME_LOCK_CHECKS=1`` in the environment
  at import time it wraps the function with a ``lock.locked()`` assertion so
  test runs fail loudly on a violated contract.
* :func:`kernel_op` — registers a kernel dispatcher's ref oracle / pallas
  implementation pair (or its composition in terms of other ops), the
  ``I-parity`` invariant's declaration.
* class-attribute conventions ``_MCQ_LOCK_ORDER`` / ``_MCQ_LOCK_PROTECTS`` —
  a class owning ``threading.Lock``s declares the total acquisition order and
  which attributes/operations each lock guards.

``tools/mcqlint`` reads all three **statically** (AST level — the decorators
never need to run) and enforces them repo-wide; the interleaving explorer
(``repro.analysis.explorer``) reuses the named-lock declarations to place its
schedule-controlled yield points.  Keep the declarations boring and literal:
the linter parses them as syntax, not by importing the module.
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Optional, Sequence, Tuple

#: Attribute carrying the tuple of lock attribute names a function requires.
REQUIRES_ATTR = "__mcq_requires_locks__"

#: Attribute carrying the (ref, pallas, composes) registration of a kernel op.
KERNEL_OP_ATTR = "__mcq_kernel_op__"

#: Class attribute naming the normative lock acquisition order (a tuple of
#: lock attribute names, outermost first).  Acquiring a lock while holding a
#: later-ranked one is a lock-order inversion (rule MCQ-L003).
LOCK_ORDER_ATTR = "_MCQ_LOCK_ORDER"

#: Class attribute mapping lock attribute name -> tuple of protected
#: resources.  A resource is either an instance attribute name (``"stats"``:
#: any mutation of ``self.stats`` needs the lock) or a dotted call pattern
#: (``"store.publish"``: any call of ``self.store.publish`` needs the lock).
LOCK_PROTECTS_ATTR = "_MCQ_LOCK_PROTECTS"

_RUNTIME_CHECKS = os.environ.get("MCQ_RUNTIME_LOCK_CHECKS", "") not in (
    "", "0", "false")


def requires_lock(*names: str) -> Callable:
    """Declare that callers must hold ``self.<name>`` for every name.

    The declaration is the contract the static analyzer enforces at every
    call site (rule MCQ-L002) and seeds the callee's held-lock set with
    (rule MCQ-L001), so a helper like ``_apply_locked`` can mutate
    write-lock-protected state without re-acquiring the lock — exactly the
    idiom the engine already uses, now checkable.
    """
    if not names or not all(isinstance(n, str) and n for n in names):
        raise ValueError("requires_lock needs one or more lock names")

    def deco(fn: Callable) -> Callable:
        if not _RUNTIME_CHECKS:
            setattr(fn, REQUIRES_ATTR, tuple(names))
            return fn

        @functools.wraps(fn)
        def checked(self, *args, **kwargs):
            for name in names:
                lock = getattr(self, name)
                # threading.Lock has .locked(); instrumented locks mirror it
                if hasattr(lock, "locked") and not lock.locked():
                    raise AssertionError(
                        f"{type(self).__name__}.{fn.__name__} requires "
                        f"{name} held (MCQ_RUNTIME_LOCK_CHECKS)")
            return fn(self, *args, **kwargs)

        setattr(checked, REQUIRES_ATTR, tuple(names))
        return checked

    return deco


def kernel_op(*, ref: Optional[str] = None, pallas: Optional[str] = None,
              composes: Sequence[str] = ()) -> Callable:
    """Register a kernel dispatcher's parity contract (invariant I-parity).

    ``ref`` names the bit-exact oracle in ``kernels/ref.py``; ``pallas`` the
    TPU implementation in a sibling ``kernels/`` module (``None`` for ops
    that deliberately run the ref on every backend, e.g. the scalar-serial
    top-n merge); ``composes`` names other registered ops an op is built
    from, inheriting their parity.  The static analyzer checks that every
    declared name exists, that every ``*_pallas`` kernel in the package is
    reachable from some registration, and that an equivalence test mentions
    the op.
    """
    if ref is None and not composes:
        raise ValueError("kernel_op needs a ref oracle or a composes list")

    def deco(fn: Callable) -> Callable:
        setattr(fn, KERNEL_OP_ATTR,
                {"ref": ref, "pallas": pallas, "composes": tuple(composes)})
        return fn

    return deco


def declared_locks(cls) -> Tuple[str, ...]:
    """The class's normative lock order (empty when undeclared)."""
    return tuple(getattr(cls, LOCK_ORDER_ATTR, ()))
