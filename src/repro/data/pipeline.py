"""Device data pipeline: host batches -> mesh-sharded global arrays.

Single-process in this container; the code path is the multi-host one
(``jax.make_array_from_process_local_data``) so it drops onto a real pod
unchanged: every host feeds its slice of the global batch.
"""

from __future__ import annotations

from typing import Dict, Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.specs import batch_axes


def batch_sharding(mesh: jax.sharding.Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(batch_axes(mesh)))


def shard_batch(batch: Dict[str, np.ndarray], mesh: jax.sharding.Mesh
                ) -> Dict[str, jax.Array]:
    """Host batch dict -> global sharded arrays (batch dim over BATCH axes).
    Falls back to replication for arrays whose batch dim does not divide."""
    sh = batch_sharding(mesh)
    ax = 1
    for a in batch_axes(mesh):
        ax *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    out = {}
    for k, v in batch.items():
        if v.shape[0] % ax == 0:
            out[k] = jax.make_array_from_process_local_data(sh, v)
        else:
            out[k] = jax.device_put(
                v, NamedSharding(mesh, P(*([None] * v.ndim))))
    return out


class ShardedIterator:
    """Wrap a host iterator; yields mesh-sharded batches."""

    def __init__(self, it: Iterator[Dict[str, np.ndarray]],
                 mesh: jax.sharding.Mesh):
        self.it = it
        self.mesh = mesh

    def __iter__(self):
        return self

    def __next__(self):
        return shard_batch(next(self.it), self.mesh)
