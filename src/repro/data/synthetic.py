"""Synthetic data: Zipf-distributed sparse Markov chains and token streams.

The paper's workload model (§II.B): "oftentimes the edges follow a Zipf
distribution".  ``MarkovGraphSampler`` builds a ground-truth random sparse
graph with Zipf edge probabilities and samples transition streams from it —
used by the recommender/telecom examples, the benchmarks (update throughput,
CDF query complexity) and the convergence tests (does MCPrioQ recover the
true edge ranking?).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class MarkovGraphSampler:
    num_nodes: int = 1000
    out_degree: int = 32
    zipf_s: float = 1.5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.dsts = np.stack([
            rng.choice(self.num_nodes, size=self.out_degree, replace=False)
            for _ in range(self.num_nodes)
        ]).astype(np.int32)
        ranks = np.arange(1, self.out_degree + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_s)
        self.probs = (p / p.sum()).astype(np.float64)
        # each node gets its own permutation of the Zipf weights
        self.perm = np.stack([rng.permutation(self.out_degree)
                              for _ in range(self.num_nodes)])
        self._rng = rng

    def true_probs(self, src: int) -> Tuple[np.ndarray, np.ndarray]:
        """(dsts, probs) in descending probability order for a node."""
        p = self.probs[np.argsort(self.perm[src])]
        order = np.argsort(-p, kind="stable")
        return self.dsts[src][order], p[order]

    def sample_transitions(self, batch: int) -> Tuple[np.ndarray, np.ndarray]:
        """(src[batch], dst[batch]) i.i.d. src, Zipf dst."""
        src = self._rng.integers(0, self.num_nodes, batch).astype(np.int32)
        choice = np.array([
            self._rng.choice(self.out_degree,
                             p=self.probs[np.argsort(self.perm[s])])
            for s in src
        ])
        dst = self.dsts[src, choice].astype(np.int32)
        return src, dst

    def sample_transitions_mixed(self, batch: int, new_frac: float,
                                 new_offset: int = 0
                                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Batch with a controlled fraction of guaranteed-new edges.

        ``round(new_frac * batch)`` items get dst ids beyond ``num_nodes``
        (so the edge cannot exist in any state warmed on this graph), each
        unique within the batch; the rest are ordinary graph transitions.
        ``new_offset`` shifts the injected id range so successive calls can
        produce disjoint new edges.  Used by the B1 new-edge-fraction sweep.
        """
        src, dst = self.sample_transitions(batch)
        n_new = int(round(new_frac * batch))
        if n_new:
            idx = self._rng.choice(batch, size=n_new, replace=False)
            dst[idx] = (self.num_nodes + new_offset
                        + np.arange(n_new)).astype(np.int32)
        return src, dst

    def sample_walks(self, batch: int, length: int) -> np.ndarray:
        """Random walks [batch, length] — session streams for the
        recommender example / token streams for the drafter."""
        out = np.empty((batch, length), np.int32)
        cur = self._rng.integers(0, self.num_nodes, batch)
        out[:, 0] = cur
        for t in range(1, length):
            nxt = np.empty(batch, np.int64)
            for i, s in enumerate(cur):
                c = self._rng.choice(self.out_degree,
                                     p=self.probs[np.argsort(self.perm[s])])
                nxt[i] = self.dsts[s, c]
            cur = nxt
            out[:, t] = cur
        return out


def token_stream(vocab_size: int, batch: int, seq_len: int, seed: int = 0
                 ) -> Iterator[dict]:
    """LM training stream with learnable bigram structure (so a few hundred
    steps of training measurably reduce loss)."""
    rng = np.random.default_rng(seed)
    # hidden bigram table: each token has 4 likely successors
    succ = rng.integers(0, vocab_size, (vocab_size, 4)).astype(np.int32)
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab_size, batch)
        for t in range(1, seq_len + 1):
            pick = rng.integers(0, 4, batch)
            follow = succ[toks[:, t - 1], pick]
            noise = rng.integers(0, vocab_size, batch)
            use_noise = rng.random(batch) < 0.2
            toks[:, t] = np.where(use_noise, noise, follow)
        yield {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }
