"""Spans and the flight recorder (DESIGN.md §13).

A :class:`Span` is a nestable monotonic-clock context manager: entering
pushes its name onto a thread-local stack (so a child records its parent),
exiting records the duration into the registry histogram of the same name
and appends a compact record to the registry's fixed-size ring.  Exit is
exception-safe — a raising body still closes the span (flagged
``error=True``) and re-raises.

``dump_incident`` is the flight recorder's readout: on a fault event
(write-path poison, shard strike-out, degraded read) the engine calls
``registry.incident(reason, **ctx)`` which snapshots the last N spans plus
the scalar deltas since the previous incident and writes one JSON file —
tmp + ``os.replace`` so a crash mid-dump never leaves a torn incident —
making a chaos-soak kill diagnosable post-mortem.

``KERNEL_ANNOTATE`` gates the opt-in trace-annotation wrapper in
``kernels/ops.py``: when enabled, each dispatcher traces under a
``jax.named_scope("mcq.<op>")`` so profiler timelines carry op names.
Enable it *before* the first dispatch — jit caches the traced computation,
so scopes only land in programs compiled while the flag is on.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Optional

#: module-bool gate for jax.named_scope annotations around kernel dispatch
KERNEL_ANNOTATE = False


def enable_kernel_annotations(on: bool = True) -> None:
    global KERNEL_ANNOTATE
    KERNEL_ANNOTATE = on


def _span_stack(registry) -> list:
    stack = getattr(registry._local, "span_stack", None)
    if stack is None:
        stack = []
        registry._local.span_stack = stack
    return stack


class Span:
    """One timed region; created armed-only via ``Registry.span``."""

    __slots__ = ("_registry", "name", "attrs", "parent", "_t0")

    def __init__(self, registry, name: str, attrs: Optional[dict] = None):
        self._registry = registry
        self.name = name
        self.attrs = dict(attrs) if attrs else None
        self.parent = None
        self._t0 = 0.0

    def __enter__(self):
        stack = _span_stack(self._registry)
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self._t0 = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.monotonic() - self._t0
        stack = _span_stack(self._registry)
        if stack and stack[-1] == self.name:
            stack.pop()
        self._registry.hist_record(self.name, dur)
        rec = {"name": self.name, "dur_s": dur, "parent": self.parent,
               "thread": threading.current_thread().name,
               "error": exc_type is not None}
        if self.attrs:
            rec["attrs"] = self.attrs
        self._registry._spans.append(rec)
        return False   # never swallow the exception


def dump_incident(registry, reason: str, ctx: dict) -> Optional[str]:
    """Write one incident file; returns its path (None when no incident
    dir is configured or the per-process cap is exhausted — the counter
    still bumps so the scrape shows suppressed incidents)."""
    registry.counter_add("incidents")
    seq = registry._next_incident()
    directory = registry.incident_dir
    if directory is None or seq > registry.max_incidents:
        return None
    spans = registry.spans()
    scalars = registry.scalars()
    deltas = registry.incident_delta(scalars)
    payload = {
        "schema": "mcq-incident-v1",
        "reason": reason,
        "ctx": {k: repr(v) if not isinstance(
            v, (int, float, str, bool, type(None))) else v
            for k, v in ctx.items()},
        "seq": seq,
        "pid": os.getpid(),
        "unix_time": time.time(),
        "spans": spans,
        "scalars": scalars,
        "deltas": deltas,
    }
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"incident_{seq:04d}_{os.getpid()}.json")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2, default=repr)
        os.replace(tmp, final)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return None
    return final
