"""Lock-free telemetry registry (DESIGN.md §13).

The measurement layer is built in the same spirit as the data structure it
observes: the hot-path record is a single numpy array increment into a
*per-thread shard* — no lock, no CAS, no allocation — and the shards are
merged only at scrape time, where p50/p90/p99/max fall out of log-bucketed
histogram counts.  A thread only ever writes its own shard (registered once
under ``_mu`` at first use), so increments cannot be lost to each other;
a concurrent scrape may miss an in-flight increment (eventually consistent,
exact once the writer quiesces — the same "approximately correct during
concurrent updates" contract as the chain itself).

Armed/disarmed follows the ``faults/registry.py`` pattern: a module-level
bool gate.  Counters and gauges are ALWAYS recorded (they implement the
engines' pre-existing stats contract); histograms, spans, traffic vectors
and incident dumps only record while armed (``arm()`` /
``MCQ_METRICS=1``), so the disarmed overhead on the serving hot paths is
one global-bool read (bounded by benchmark B10).

Metric *names* are a closed catalog (``METRIC_CATALOG``): every name
recorded anywhere in ``src/`` must be declared here with a kind and help
text, and every declared name must be recorded somewhere — the MCQ-M001
diagonal, statically enforced by mcqlint.
"""

from __future__ import annotations

import contextlib
import math
import os
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: metric kinds a catalog entry may declare
KINDS = ("counter", "gauge", "histogram", "vector")

#: the closed metric catalog: name -> (kind, help).  Counters are
#: monotonically accumulated; gauges are last-value-wins absolute reads;
#: histograms are log-bucketed latency distributions in SECONDS; vectors
#: are fixed-size integer arrays (per-bucket / per-shard traffic).
#: Values surfaced through a registry *provider* (the engines' stats
#: snapshots, device counter sums) are typed here too so the exposition
#: layer can render them with the right TYPE/HELP.
METRIC_CATALOG: Dict[str, Tuple[str, str]] = {
    # -- sharded serving host counters (provided via stats_snapshot) ----
    "updates": ("counter", "observe() batches applied and published"),
    "queries": ("counter", "threshold-query calls served"),
    "topn_calls": ("counter", "global top-n merge reads served"),
    "query_dropped": ("counter", "query items dropped for routing skew"),
    "snapshots": ("counter", "snapshots captured (sync + async)"),
    "route_retried": ("counter",
                      "skew-dropped update items re-queued for retry"),
    "route_lost": ("counter",
                   "update items lost after the route-retry budget"),
    "query_retried": ("counter", "query items re-dispatched for skew"),
    "query_lost": ("counter",
                   "query items still dropped after the retry budget"),
    "degraded_answers": ("counter",
                         "read items answered empty by degradation"),
    "wal_errors": ("counter", "WAL io_errors absorbed (swallow-and-count)"),
    "wal_retries": ("counter", "WAL append retry rounds"),
    "apply_retries": ("counter", "device-apply retry rounds"),
    "dispatch_retries": ("counter", "routed-read dispatch retry rounds"),
    "write_errors": ("counter", "write-path poison escalations"),
    "snapshot_failures": ("counter", "snapshot attempts that failed"),
    # -- unsharded Engine counters (recorded via counter_add) -----------
    "model_calls": ("counter", "target-model decode/extend forwards"),
    "accepted": ("counter", "draft tokens accepted by verification"),
    "drafted": ("counter", "draft tokens proposed"),
    "rounds": ("counter", "speculative draft-verify rounds"),
    "draft_calls": ("counter", "fused draft-walk dispatches"),
    # -- telemetry self-accounting --------------------------------------
    "incidents": ("counter", "flight-recorder incidents fired"),
    # -- device counter sums (provided; cumulative since init) ----------
    "dropped_rows": ("counter", "row-table insertions dropped (capacity)"),
    "dropped_probes": ("counter", "hash probes dropped (window overflow)"),
    "evictions": ("counter", "Space-Saving slab evictions"),
    "deferred_new": ("counter", "new edges deferred past the slow-path cap"),
    "route_dropped": ("counter", "routed items dropped at bucket capacity"),
    "decay_steps": ("counter", "decay maintenance steps applied"),
    "dh_rebuilds": ("counter", "full dst-hash rebuilds"),
    "dh_tombstones": ("counter", "dst-hash tombstones created"),
    # -- gauges ---------------------------------------------------------
    "n_rows": ("gauge", "live rows in the chain"),
    "topn_dropped": ("gauge", "unexposed top-n candidates (last read)"),
    "deferred_writes": ("gauge", "write items deferred for down shards"),
    "shards_down": ("gauge", "shards currently marked down"),
    "read_epoch_lag": ("gauge",
                       "publish-to-read epoch lag seen by the last query"),
    "store_version": ("gauge", "current published epoch version"),
    # -- latency histograms (seconds) -----------------------------------
    "engine.observe": ("histogram", "observe() wall time (write cycle)"),
    "engine.apply": ("histogram", "device apply+publish inside observe"),
    "engine.query": ("histogram", "threshold-query wall time"),
    "engine.topn": ("histogram", "global top-n read wall time"),
    "engine.learn": ("histogram", "unsharded learner step wall time"),
    "wal.append": ("histogram", "WAL append (frame+write+flush) time"),
    "wal.fsync": ("histogram", "per-append WAL fsync time"),
    "wal.rotate": ("histogram", "WAL segment rotation time"),
    "snapshot.save": ("histogram", "snapshot save (arrays+meta+commit)"),
    "snapshot.restore": ("histogram", "snapshot restore read time"),
    "retry.backoff": ("histogram", "retry-ladder backoff sleeps"),
    # -- traffic vectors (the ROADMAP rebalancer's input) ---------------
    "bucket_traffic": ("vector", "update items per virtual bucket"),
    "shard_traffic": ("vector", "update items per owner shard"),
}

_COUNTER_NAMES: Tuple[str, ...] = tuple(
    n for n, (k, _) in METRIC_CATALOG.items() if k == "counter")
_COUNTER_IDX: Dict[str, int] = {n: i for i, n in enumerate(_COUNTER_NAMES)}
_HIST_NAMES: Tuple[str, ...] = tuple(
    n for n, (k, _) in METRIC_CATALOG.items() if k == "histogram")
_HIST_IDX: Dict[str, int] = {n: i for i, n in enumerate(_HIST_NAMES)}

# log-bucketed histogram layout: value v = m * 2**e (math.frexp,
# 0.5 <= m < 1) lands in octave e, sub-bucket floor((m - 0.5) * 2 * B)
# of B per octave.  E_MIN..E_MAX octaves cover ~0.5ns .. ~1024s; values
# outside clamp to the edge buckets.  The estimate a scrape reports is
# the bucket's UPPER edge, so est/true is within [1, (B+1)/B].
E_MIN = -30
E_MAX = 10
DEFAULT_BUCKETS_PER_OCTAVE = 4

_ARMED = False


def arm() -> None:
    """Enable histograms, spans, traffic vectors and incident dumps."""
    global _ARMED
    _ARMED = True


def disarm() -> None:
    global _ARMED
    _ARMED = False


def is_armed() -> bool:
    return _ARMED


@contextlib.contextmanager
def armed():
    """Scoped arming for tests."""
    prev = _ARMED
    arm()
    try:
        yield
    finally:
        if not prev:
            disarm()


def arm_from_env(env: Optional[Dict[str, str]] = None) -> Optional[str]:
    """Arm telemetry from the environment (the subprocess analogue of
    ``faults.arm_from_env``): ``MCQ_METRICS`` truthy arms the gate,
    ``MCQ_TRACE_KERNELS`` truthy enables kernel trace annotations, and the
    returned ``MCQ_METRICS_INCIDENT_DIR`` (or None) is where an arming
    engine should dump incident files."""
    env = os.environ if env is None else env
    if env.get("MCQ_METRICS", "") not in ("", "0", "false", "no"):
        arm()
    if env.get("MCQ_TRACE_KERNELS", "") not in ("", "0", "false", "no"):
        from repro.obs import tracing
        tracing.enable_kernel_annotations()
    return env.get("MCQ_METRICS_INCIDENT_DIR") or None


def bucket_index(value: float, buckets_per_octave: int) -> int:
    """Histogram bucket for ``value`` (seconds); <=0 clamps to bucket 0."""
    if value <= 0.0:
        return 0
    m, e = math.frexp(value)
    if e < E_MIN:
        return 0
    if e > E_MAX:
        return (E_MAX - E_MIN + 1) * buckets_per_octave - 1
    sub = int((m - 0.5) * 2.0 * buckets_per_octave)
    if sub >= buckets_per_octave:
        sub = buckets_per_octave - 1
    return (e - E_MIN) * buckets_per_octave + sub


def bucket_edges(buckets_per_octave: int) -> np.ndarray:
    """Upper edge of every bucket (monotonically increasing)."""
    n = (E_MAX - E_MIN + 1) * buckets_per_octave
    idx = np.arange(n)
    e = E_MIN + idx // buckets_per_octave
    sub = idx % buckets_per_octave
    return np.exp2(e - 1.0) * (1.0 + (sub + 1.0) / buckets_per_octave)


class _NoopSpan:
    """Shared do-nothing context manager returned while disarmed."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _Shard:
    """One thread's private recording arrays (single writer, no lock)."""

    __slots__ = ("counters", "hist_counts", "hist_sums", "hist_maxes",
                 "vectors")

    def __init__(self, n_buckets: int, vector_sizes: Dict[str, int]):
        self.counters = np.zeros(len(_COUNTER_NAMES), np.int64)
        self.hist_counts = np.zeros((len(_HIST_NAMES), n_buckets), np.int64)
        self.hist_sums = np.zeros(len(_HIST_NAMES), np.float64)
        self.hist_maxes = np.zeros(len(_HIST_NAMES), np.float64)
        self.vectors = {name: np.zeros(size, np.int64)
                        for name, size in vector_sizes.items()}


class Registry:
    """A set of named metrics with lock-free recording.

    ``_mu`` guards only the registry's bookkeeping (the shard list, the
    provider list, incident sequencing) — never the record path, and it is
    never held while calling out (providers run after it is released), so
    it cannot participate in a lock cycle with engine locks.
    """

    _MCQ_LOCK_ORDER = ("_mu",)
    _MCQ_LOCK_PROTECTS = {
        "_mu": ("_shards_all", "_providers", "_incident_seq", "_baseline"),
    }

    def __init__(self, *, vectors: Optional[Dict[str, int]] = None,
                 buckets_per_octave: int = DEFAULT_BUCKETS_PER_OCTAVE,
                 flight_spans: int = 64,
                 incident_dir: Optional[str] = None,
                 max_incidents: int = 32):
        self._bpo = int(buckets_per_octave)
        self._n_buckets = (E_MAX - E_MIN + 1) * self._bpo
        self._edges = bucket_edges(self._bpo)
        self._vector_sizes = dict(vectors or {})
        self._local = threading.local()
        self._mu = threading.Lock()
        self._shards_all: List[_Shard] = []
        self._providers: List[Callable[[], Dict[str, int]]] = []
        self._gauges: Dict[str, float] = {}   # GIL-atomic stores, no lock
        # flight recorder: bounded deque appends are thread-safe; the ring
        # holds the last N completed spans for incident dumps
        import collections
        self._spans = collections.deque(maxlen=int(flight_spans))
        self.incident_dir = incident_dir
        self.max_incidents = int(max_incidents)
        self._incident_seq = 0
        self._baseline: Dict[str, float] = {}

    # -- hot path (lock-free) -------------------------------------------
    def _shard(self) -> _Shard:
        s = getattr(self._local, "shard", None)
        if s is None:
            s = _Shard(self._n_buckets, self._vector_sizes)
            self._local.shard = s
            with self._mu:
                self._shards_all.append(s)
        return s

    def counter_add(self, name: str, n: int = 1) -> None:
        """Always recorded (counters implement the stats contract)."""
        self._shard().counters[_COUNTER_IDX[name]] += n

    def gauge_set(self, name: str, value) -> None:
        """Always recorded: one dict store (atomic under the GIL)."""
        self._gauges[name] = value

    def hist_record(self, name: str, value: float) -> None:
        """Record a latency sample (seconds); no-op while disarmed."""
        if not _ARMED:
            return
        i = _HIST_IDX[name]
        s = self._shard()
        s.hist_counts[i, bucket_index(value, self._bpo)] += 1
        s.hist_sums[i] += value
        if value > s.hist_maxes[i]:
            s.hist_maxes[i] = value

    def vector_add(self, name: str, counts: np.ndarray) -> None:
        """Accumulate a traffic count vector; no-op while disarmed.
        Mismatched lengths merge over the common prefix (a rebind may
        change the bucket count mid-flight)."""
        if not _ARMED:
            return
        vec = self._shard().vectors.get(name)
        if vec is None:
            return
        m = min(vec.size, len(counts))
        vec[:m] += np.asarray(counts[:m], np.int64)

    def span(self, name: str, **attrs):
        """A nestable monotonic-clock span context manager; records its
        duration into the histogram ``name`` and pushes a record into the
        flight recorder on exit.  Disarmed: a shared no-op."""
        if not _ARMED:
            return NOOP_SPAN
        from repro.obs.tracing import Span
        return Span(self, name, attrs)

    def incident(self, reason: str, **ctx) -> Optional[str]:
        """Dump a flight-recorder incident file (see tracing.py); returns
        the path (None while disarmed, with no incident dir, or past
        ``max_incidents``)."""
        if not _ARMED:
            return None
        from repro.obs import tracing
        return tracing.dump_incident(self, reason, ctx)

    # -- read side --------------------------------------------------------
    def register_provider(self, fn: Callable[[], Dict[str, int]]) -> None:
        """``fn`` is called at every scrape (AFTER ``_mu`` is released, so
        it may take its own locks) and its dict merges into the snapshot's
        ``provided`` section — how the engines' consistent stats snapshots
        become the one exposition source of truth."""
        with self._mu:
            self._providers.append(fn)

    def spans(self) -> List[dict]:
        """The flight recorder's current contents, oldest first."""
        return list(self._spans)

    def _next_incident(self) -> int:
        with self._mu:
            self._incident_seq += 1
            return self._incident_seq

    def incident_delta(self, scalars: Dict[str, float]) -> Dict[str, float]:
        """Scalar deltas since the previous incident (or since birth),
        then advance the baseline — consecutive incidents show what moved
        *between* them."""
        with self._mu:
            base = self._baseline
            self._baseline = dict(scalars)
        return {k: v - base.get(k, 0)
                for k, v in scalars.items() if v != base.get(k, 0)}

    def quantiles(self, counts: np.ndarray, qs: Sequence[float],
                  vmax: float = 0.0) -> List[float]:
        """Nearest-rank quantile estimates from merged bucket counts; each
        estimate is the containing bucket's upper edge, capped at the
        tracked exact max."""
        total = int(counts.sum())
        out = []
        cum = np.cumsum(counts)
        for q in qs:
            if total == 0:
                out.append(0.0)
                continue
            k = max(1, int(math.ceil(q * total)))
            idx = int(np.searchsorted(cum, k, side="left"))
            est = float(self._edges[min(idx, self._edges.size - 1)])
            if vmax > 0.0:
                est = min(est, float(vmax))
            out.append(est)
        return out

    def snapshot(self) -> dict:
        """Merge every thread shard and call every provider; returns the
        full metrics image ``{counters, gauges, provided, histograms,
        vectors}``."""
        with self._mu:
            shards = list(self._shards_all)
            providers = list(self._providers)
        counters = np.zeros(len(_COUNTER_NAMES), np.int64)
        hist_counts = np.zeros((len(_HIST_NAMES), self._n_buckets), np.int64)
        hist_sums = np.zeros(len(_HIST_NAMES), np.float64)
        hist_maxes = np.zeros(len(_HIST_NAMES), np.float64)
        vectors = {name: np.zeros(size, np.int64)
                   for name, size in self._vector_sizes.items()}
        for s in shards:
            counters += s.counters
            hist_counts += s.hist_counts
            hist_sums += s.hist_sums
            np.maximum(hist_maxes, s.hist_maxes, out=hist_maxes)
            for name, v in s.vectors.items():
                m = min(vectors[name].size, v.size)
                vectors[name][:m] += v[:m]
        provided: Dict[str, float] = {}
        for fn in providers:   # outside _mu: providers may take locks
            provided.update(fn())
        hists = {}
        for i, name in enumerate(_HIST_NAMES):
            row = hist_counts[i]
            count = int(row.sum())
            vmax = float(hist_maxes[i])
            p50, p90, p99 = self.quantiles(row, (0.5, 0.9, 0.99), vmax)
            hists[name] = {"count": count, "sum": float(hist_sums[i]),
                           "max": vmax, "p50": p50, "p90": p90, "p99": p99}
        return {
            "counters": {n: int(counters[i])
                         for i, n in enumerate(_COUNTER_NAMES)},
            "gauges": dict(self._gauges),
            "provided": provided,
            "histograms": hists,
            "vectors": {n: v.tolist() for n, v in vectors.items()},
        }

    def scalars(self) -> Dict[str, float]:
        """Flat scalar view: counters + gauges + provided merged (provided
        wins — the engines' consistent snapshot is authoritative for the
        names both carry)."""
        snap = self.snapshot()
        out: Dict[str, float] = dict(snap["counters"])
        out.update(snap["gauges"])
        out.update(snap["provided"])
        return out


#: the process-global default registry — standalone persist/runtime call
#: sites record here unless handed an engine-owned registry
GLOBAL = Registry()
