"""Lock-free telemetry: metrics registry, spans/flight recorder, exposition.

See DESIGN.md §13.  Quick tour::

    from repro import obs

    obs.arm()                       # histograms/spans/vectors/incidents on
    reg = obs.Registry(vectors={"bucket_traffic": 256})
    with reg.span("engine.observe"):
        ...
    print(obs.render_prometheus(reg.snapshot()))
"""

from repro.obs.metrics import (METRIC_CATALOG, GLOBAL, Registry, arm,
                               arm_from_env, armed, disarm, is_armed)
from repro.obs.export import (MetricsDumper, MetricsServer, render_jsonl,
                              render_prometheus)
from repro.obs import tracing

__all__ = [
    "METRIC_CATALOG", "GLOBAL", "Registry", "arm", "arm_from_env", "armed",
    "disarm", "is_armed", "MetricsDumper", "MetricsServer", "render_jsonl",
    "render_prometheus", "tracing",
]
