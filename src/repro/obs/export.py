"""Metrics exposition: Prometheus text, JSONL, and an embedded server.

``render_prometheus`` turns a registry snapshot into the Prometheus text
format (metric names prefixed ``mcq_``, dots to underscores; histograms
rendered as summaries with p50/p90/p99 quantile series plus ``_count`` /
``_sum`` / ``_max``; traffic vectors as labelled series).  ``render_jsonl``
emits one JSON object per metric for log-shipper pipelines.

``MetricsServer`` is a stdlib ``ThreadingHTTPServer`` on a daemon thread —
``GET /metrics`` for Prometheus scrape, ``GET /metrics.json`` for the raw
snapshot; port 0 binds an ephemeral port (``.port`` tells you which).
``MetricsDumper`` writes a JSONL snapshot file on a fixed cadence
(tmp + ``os.replace``, so a reader never sees a torn file).
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Iterator

from repro.obs.metrics import METRIC_CATALOG, Registry


def _prom_name(name: str) -> str:
    return "mcq_" + name.replace(".", "_").replace("-", "_")


def _fmt(v) -> str:
    if isinstance(v, float):
        return repr(v)
    return str(v)


def _scalar_lines(section: dict, default_kind: str) -> Iterator[str]:
    for name in sorted(section):
        kind, help_ = METRIC_CATALOG.get(name, (default_kind, ""))
        pn = _prom_name(name)
        if help_:
            yield f"# HELP {pn} {help_}"
        yield f"# TYPE {pn} {kind if kind in ('counter', 'gauge') else 'gauge'}"
        yield f"{pn} {_fmt(section[name])}"


def render_prometheus(snap: dict) -> str:
    """Prometheus text exposition of a ``Registry.snapshot()``."""
    lines = []
    lines.extend(_scalar_lines(snap.get("counters", {}), "counter"))
    lines.extend(_scalar_lines(snap.get("gauges", {}), "gauge"))
    # provided names already covered by counters/gauges sections get a
    # distinct series only if absent there; the catalog supplies the kind
    seen = set(snap.get("counters", {})) | set(snap.get("gauges", {}))
    provided = {k: v for k, v in snap.get("provided", {}).items()
                if k not in seen or v}
    lines.extend(_scalar_lines(provided, "gauge"))
    for name in sorted(snap.get("histograms", {})):
        h = snap["histograms"][name]
        pn = _prom_name(name) + "_seconds"
        _, help_ = METRIC_CATALOG.get(name, ("histogram", ""))
        if help_:
            lines.append(f"# HELP {pn} {help_}")
        lines.append(f"# TYPE {pn} summary")
        for q, key in (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99")):
            lines.append(f'{pn}{{quantile="{q}"}} {_fmt(h[key])}')
        lines.append(f"{pn}_count {h['count']}")
        lines.append(f"{pn}_sum {_fmt(h['sum'])}")
        lines.append(f"{pn}_max {_fmt(h['max'])}")
    for name in sorted(snap.get("vectors", {})):
        vec = snap["vectors"][name]
        pn = _prom_name(name)
        _, help_ = METRIC_CATALOG.get(name, ("vector", ""))
        if help_:
            lines.append(f"# HELP {pn} {help_}")
        lines.append(f"# TYPE {pn} gauge")
        label = "shard" if name == "shard_traffic" else "bucket"
        for i, v in enumerate(vec):
            if v:
                lines.append(f'{pn}{{{label}="{i}"}} {v}')
    return "\n".join(lines) + "\n"


def render_jsonl(snap: dict) -> str:
    """One JSON object per metric (counters, gauges, provided, histogram
    summaries, nonzero vector cells)."""
    rows = []
    for section, kind in (("counters", "counter"), ("gauges", "gauge"),
                          ("provided", "provided")):
        for name in sorted(snap.get(section, {})):
            rows.append({"type": kind, "name": name,
                         "value": snap[section][name]})
    for name in sorted(snap.get("histograms", {})):
        rows.append({"type": "histogram", "name": name,
                     **snap["histograms"][name]})
    for name in sorted(snap.get("vectors", {})):
        vec = snap["vectors"][name]
        rows.append({"type": "vector", "name": name,
                     "nonzero": {str(i): v for i, v in enumerate(vec) if v}})
    return "\n".join(json.dumps(r) for r in rows) + "\n"


def _make_handler(registry: Registry):
    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):   # noqa: N802 (stdlib API name)
            try:
                if self.path.startswith("/metrics.json"):
                    body = json.dumps(registry.snapshot(), indent=2)
                    ctype = "application/json"
                elif self.path.startswith("/metrics"):
                    body = render_prometheus(registry.snapshot())
                    ctype = "text/plain; version=0.0.4"
                else:
                    self.send_error(404)
                    return
            except Exception as e:   # surface scrape bugs, don't kill serve
                self.send_error(500, str(e))
                return
            data = body.encode()
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)

        def log_message(self, fmt, *args):
            pass   # scrapes must not spam serve's stdout

    return Handler


class MetricsServer:
    """Serve ``registry`` over HTTP on a daemon thread."""

    def __init__(self, registry: Registry, port: int = 0,
                 host: str = "127.0.0.1"):
        self._httpd = ThreadingHTTPServer((host, port),
                                          _make_handler(registry))
        self._httpd.daemon_threads = True
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, daemon=True,
            name="mcq-metrics-server")

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


class MetricsDumper:
    """Write a JSONL snapshot of ``registry`` to ``path`` every
    ``every_s`` seconds (atomic replace per cadence tick)."""

    def __init__(self, registry: Registry, path: str, every_s: float = 5.0):
        self._registry = registry
        self._path = path
        self._every_s = float(every_s)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="mcq-metrics-dumper")

    def _write_once(self) -> None:
        text = render_jsonl(self._registry.snapshot())
        directory = os.path.dirname(self._path) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, self._path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def _run(self) -> None:
        while not self._stop.wait(self._every_s):
            self._write_once()

    def start(self) -> "MetricsDumper":
        self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._write_once()   # final image on shutdown


__all__ = ["render_prometheus", "render_jsonl", "MetricsServer",
           "MetricsDumper"]
