"""Failpoint registry: named fault-injection sites (DESIGN.md §12).

A *failpoint* is a named call site threaded through an IO or cross-shard
edge — ``failpoint("wal.append.fsync", fh=self._fh)`` — that does nothing
in production and becomes a fault when *armed*.  Arming attaches an
**action** (raise an exception, SIGKILL the process, sleep, or call an
arbitrary hook with the site's keyword context) behind a **trigger**
(always / only the Nth hit / every Nth hit / iid with probability p), via
the API here or the ``MCQ_FAILPOINTS`` environment variable, so a
subprocess under test can be detonated from outside.

Design constraints, in order:

* **Zero-cost when disarmed.**  The hot path of ``failpoint`` is one read
  of a module-level bool; no dict lookup, no lock, no string work.  The
  serving engine calls failpoints on every observe/query, so anything
  more would tax the fast path the paper is about.
* **Closed catalog.**  Every site name must be a key of
  :data:`FAILPOINT_CATALOG`; ``arm`` rejects unknown names at runtime and
  mcqlint rule MCQ-R001 rejects unregistered/untested sites statically
  (invariant I10) — an injection site that exists but is never exercised
  by the fault matrix is a hole in the robustness story.
* **Deterministic.**  Probabilistic triggers take an explicit seed;
  nth-hit triggers count per-site hits.  A chaos run is reproducible from
  its env string.

Failpoints double as *schedule points* for the interleaving explorer:
:func:`set_observer` installs a callback invoked on every hit (arming not
required), which the explorer uses to yield control at IO edges exactly
like its lock/store instrumentation.
"""

from __future__ import annotations

import contextlib
import os
import random
import signal
import threading
import time
from typing import Any, Callable, Dict, Optional

#: The closed catalog of injection sites: name -> where it cuts.  mcqlint
#: rule MCQ-R001 parses this dict *statically* (literal keys only) and
#: cross-checks every ``failpoint("...")`` call site in src/ against it,
#: and every entry against the fault-matrix table in tests/test_faults.py.
FAILPOINT_CATALOG = {
    "wal.segment_open": "opening/creating a WAL segment file",
    "wal.append.write": "writing+flushing one framed record into a segment",
    "wal.append.fsync": "fsync of the open segment (policy: always)",
    "wal.rotate": "segment close/fsync at rotation or WAL close",
    "snapshot.meta_write": "writing the chain.json sidecar of a snapshot",
    "snapshot.arrays_write": "np.savez of a snapshot's array payload",
    "snapshot.manifest_commit": "the atomic os.replace manifest commit",
    "snapshot.io_thread": "body of an async checkpoint IO thread",
    "snapshot.restore_read": "reading manifest/arrays during restore",
    "engine.apply": "device dispatch of acquire->update->maintain",
    "engine.publish": "epoch-store publish of the applied state",
    "engine.query_dispatch": "routed threshold-query device dispatch",
    "engine.topn_dispatch": "routed global top-n device dispatch",
    "engine.learn": "the unsharded Engine's per-token n-gram learn step",
}


class FaultInjected(OSError):
    """Default exception an armed ``raise`` action throws.

    An ``OSError`` subclass so the retry/escalation ladder classifies it
    by ``errno`` exactly like a genuine IO failure.
    """

    def __init__(self, site: str, err: Optional[int] = None):
        super().__init__(err or 0, f"fault injected at {site}")
        self.site = site


class _Arming:
    __slots__ = ("action", "trigger", "count", "fired")

    def __init__(self, action, trigger, count):
        self.action = action
        self.trigger = trigger
        self.count = count          # max fires; None = unlimited
        self.fired = 0


_mu = threading.Lock()
_armed: Dict[str, _Arming] = {}
_hits: Dict[str, int] = {}
_observer: Optional[Callable[[str, dict], None]] = None

#: fast-path gate: True iff any site is armed or an observer is installed.
_ACTIVE = False


def _recompute_active() -> None:
    global _ACTIVE
    _ACTIVE = bool(_armed) or _observer is not None


# ---------------------------------------------------------------------------
# the injection site
# ---------------------------------------------------------------------------


def failpoint(name: str, **ctx: Any) -> None:
    """The injection site.  No-op unless armed or observed.

    ``ctx`` carries site-local objects (file handles, seq numbers) to
    hook actions, so a test can e.g. tear a write half-way before
    raising.  Keep call sites cheap: ctx values must already exist.
    """
    if not _ACTIVE:
        return
    _slow_hit(name, ctx)


def _slow_hit(name: str, ctx: dict) -> None:
    obs = _observer
    if obs is not None:
        obs(name, ctx)
    with _mu:
        hit = _hits.get(name, 0) + 1
        _hits[name] = hit
        arming = _armed.get(name)
        if arming is None:
            return
        if arming.count is not None and arming.fired >= arming.count:
            return
        if not arming.trigger(hit):
            return
        arming.fired += 1
        action = arming.action
    action(ctx)  # outside the lock: may raise, sleep, or never return


# ---------------------------------------------------------------------------
# triggers and actions
# ---------------------------------------------------------------------------


def _make_trigger(spec) -> Callable[[int], bool]:
    """Normalise a trigger spec to ``hit_index -> bool`` (1-based hits).

    Specs: ``"always"`` | ``("nth", n)`` fires on exactly the nth hit |
    ``("every", n)`` fires on every nth | ``("prob", p, seed)`` iid
    Bernoulli from a dedicated seeded stream | a callable, passed through.
    """
    if callable(spec):
        return spec
    if spec == "always":
        return lambda hit: True
    kind = spec[0]
    if kind == "nth":
        n = int(spec[1])
        return lambda hit: hit == n
    if kind == "every":
        n = int(spec[1])
        return lambda hit: hit % n == 0
    if kind == "prob":
        p = float(spec[1])
        rng = random.Random(int(spec[2]) if len(spec) > 2 else 0)
        return lambda hit: rng.random() < p
    raise ValueError(f"unknown trigger spec {spec!r}")


def _make_action(spec, name: str) -> Callable[[dict], None]:
    """Normalise an action spec to ``ctx -> None``.

    Specs: an exception instance or class (raised); ``"kill"`` (SIGKILL
    self — the crash-soak hammer); a float/int (sleep that many seconds);
    a callable, called with the site's ctx dict.
    """
    if isinstance(spec, BaseException):
        def act(ctx, exc=spec):
            raise exc
        return act
    if isinstance(spec, type) and issubclass(spec, BaseException):
        def act(ctx, cls=spec):
            raise cls(f"fault injected at {name}")
        return act
    if spec == "kill":
        def act(ctx):
            os.kill(os.getpid(), signal.SIGKILL)
        return act
    if isinstance(spec, (int, float)) and not isinstance(spec, bool):
        def act(ctx, secs=float(spec)):
            time.sleep(secs)
        return act
    if callable(spec):
        return spec
    raise ValueError(f"unknown action spec {spec!r}")


# ---------------------------------------------------------------------------
# arming API
# ---------------------------------------------------------------------------


def arm(name: str, action, *, trigger="always",
        count: Optional[int] = None) -> None:
    """Arm one site.  Re-arming replaces the previous arming and resets
    its fire count (hit counts persist until :func:`reset`)."""
    if name not in FAILPOINT_CATALOG:
        raise KeyError(
            f"unknown failpoint {name!r}; register it in FAILPOINT_CATALOG")
    a = _Arming(_make_action(action, name), _make_trigger(trigger), count)
    with _mu:
        _armed[name] = a
        _recompute_active()


def disarm(name: str) -> None:
    with _mu:
        _armed.pop(name, None)
        _recompute_active()


def reset() -> None:
    """Disarm everything and zero all hit/fire counters (test teardown)."""
    with _mu:
        _armed.clear()
        _hits.clear()
        _recompute_active()


@contextlib.contextmanager
def armed(name: str, action, *, trigger="always",
          count: Optional[int] = None):
    """``with armed("wal.append.fsync", OSError(...)):`` scoped arming."""
    arm(name, action, trigger=trigger, count=count)
    try:
        yield
    finally:
        disarm(name)


def hits(name: str) -> int:
    """Site passes observed while the registry was active (armed sites
    count every pass, fired or not)."""
    with _mu:
        return _hits.get(name, 0)


def fired(name: str) -> int:
    with _mu:
        a = _armed.get(name)
        return a.fired if a is not None else 0


def snapshot() -> Dict[str, Dict[str, int]]:
    """Per-site {hits, fired} for stats surfacing and test asserts."""
    with _mu:
        return {n: {"hits": _hits.get(n, 0),
                    "fired": a.fired}
                for n, a in _armed.items()} | {
                    n: {"hits": h, "fired": 0}
                    for n, h in _hits.items() if n not in _armed}


# ---------------------------------------------------------------------------
# explorer bridge
# ---------------------------------------------------------------------------


def set_observer(fn: Optional[Callable[[str, dict], None]]) -> None:
    """Install (or clear, with None) a callback invoked on *every* site
    hit.  The interleaving explorer uses this to make failpoints schedule
    yield points; the callback runs before any armed action fires."""
    global _observer
    with _mu:
        _observer = fn
        _recompute_active()


# ---------------------------------------------------------------------------
# environment arming: MCQ_FAILPOINTS="site=action[@trigger][;site=...]"
# ---------------------------------------------------------------------------


def _parse_env_entry(entry: str):
    site, _, rest = entry.partition("=")
    site = site.strip()
    if not rest:
        raise ValueError(f"MCQ_FAILPOINTS entry {entry!r}: missing action")
    action_s, _, trigger_s = rest.partition("@")
    parts = action_s.split(":")
    kind = parts[0]
    if kind == "raise":
        err = int(parts[1]) if len(parts) > 1 else 0
        action = FaultInjected(site, err)
    elif kind == "kill":
        action = "kill"
    elif kind == "sleep":
        action = float(parts[1])
    else:
        raise ValueError(
            f"MCQ_FAILPOINTS entry {entry!r}: unknown action {kind!r}")
    trigger = "always"
    if trigger_s:
        tp = trigger_s.split(":")
        if tp[0] == "always":
            trigger = "always"
        elif tp[0] in ("nth", "every"):
            trigger = (tp[0], int(tp[1]))
        elif tp[0] == "prob":
            trigger = ("prob", float(tp[1]),
                       int(tp[2]) if len(tp) > 2 else 0)
        else:
            raise ValueError(
                f"MCQ_FAILPOINTS entry {entry!r}: unknown trigger {tp[0]!r}")
    return site, action, trigger


def arm_from_env(spec: Optional[str] = None) -> int:
    """Arm sites from ``MCQ_FAILPOINTS`` (or an explicit spec string).

    Format: ``site=action[@trigger]`` entries joined by ``;``, with
    action ``raise[:errno]`` | ``kill`` | ``sleep:secs`` and trigger
    ``always`` | ``nth:N`` | ``every:N`` | ``prob:P[:SEED]``.  Example::

        MCQ_FAILPOINTS="wal.append.fsync=raise:28@nth:3;engine.apply=kill@prob:0.1:7"

    Returns the number of sites armed.  Called once at engine startup
    (``ShardedEngine.__init__``) so subprocess chaos runs arm themselves.
    """
    spec = os.environ.get("MCQ_FAILPOINTS", "") if spec is None else spec
    n = 0
    for entry in spec.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        site, action, trigger = _parse_env_entry(entry)
        arm(site, action, trigger=trigger)
        n += 1
    return n
