"""Fault injection for the persist/serving stack (DESIGN.md §12).

Public surface::

    from repro.faults import failpoint          # the injection site
    from repro import faults                    # arming / test control
    with faults.armed("wal.append.fsync", OSError(28, "no space")):
        ...

See :mod:`repro.faults.registry` for the catalog and semantics.
"""

from repro.faults.registry import (  # noqa: F401
    FAILPOINT_CATALOG,
    FaultInjected,
    arm,
    arm_from_env,
    armed,
    disarm,
    failpoint,
    fired,
    hits,
    reset,
    set_observer,
    snapshot,
)
