"""Epoch-consistent chain snapshots (DESIGN.md §10).

A snapshot is one ``step_<n>/`` directory in the ``checkpoint/ckpt.py``
manifest+npz layout plus a ``chain.json`` sidecar carrying what arrays alone
cannot: the ``MCConfig`` the shapes were built from, the shard count the
leading state dim encodes, the ownership assignment, and ``wal_seq`` — the
WAL position the arrays are consistent with (replay starts *after* it).

Consistency point: the caller captures the state inside the Engine's
writer-lock publish cycle (acquire -> observe -> maintain -> publish), so a
snapshot is always a *published* epoch — never a torn mid-update view.  The
EpochStore makes this nearly free: published pytrees are immutable, so the
device->host gather can race nothing.

Commit protocol (crash-safe): ``chain.json`` and ``arrays.npz`` are written
first, ``manifest.json`` is renamed into place last (the atomic commit, same
as ``ckpt.save``).  A crash mid-snapshot leaves a directory without a valid
manifest — or, under weaker filesystems, a manifest with a truncated npz —
so readers must use :func:`latest_complete_step`, which verifies every array
actually loads before trusting a step, and falls back to the previous
complete one.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt
from repro.faults import failpoint
from repro.obs import metrics as obs_metrics

PyTree = Any

META_NAME = "chain.json"


def step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _write_meta(path: str, meta: dict) -> None:
    failpoint("snapshot.meta_write", path=path)
    tmp = os.path.join(path, META_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, os.path.join(path, META_NAME))


def save_snapshot(state: PyTree, directory: str, step: int,
                  meta: dict,
                  metrics: Optional[obs_metrics.Registry] = None) -> str:
    """Write ``step_<n>/{chain.json, arrays.npz, manifest.json}``.

    ``chain.json`` lands before ``ckpt.save`` commits the manifest, so a
    committed manifest implies the sidecar exists.  Returns the step path.
    """
    metrics = metrics if metrics is not None else obs_metrics.GLOBAL
    with metrics.span("snapshot.save", step=step):
        path = step_dir(directory, step)
        os.makedirs(path, exist_ok=True)
        _write_meta(path, meta)
        return ckpt.save(state, directory, step)


def save_snapshot_async(state: PyTree, directory: str, step: int,
                        meta: dict,
                        on_complete: Optional[Any] = None,
                        on_error: Optional[Any] = None,
                        metrics: Optional[obs_metrics.Registry] = None
                        ) -> threading.Thread:
    """Background-cadence variant: the device->host gather happens on the
    caller thread (under the Engine's writer lock, so the captured epoch is
    exact), file IO on a worker thread with the same commit ordering.
    ``on_complete`` runs on the worker thread after the manifest commits —
    the engine hangs WAL truncation off it, so segments are only GC'd once
    the snapshot that supersedes them is durable.  ``on_error`` receives IO
    faults from the worker (see ``ckpt.save_async``)."""
    metrics = metrics if metrics is not None else obs_metrics.GLOBAL
    t0 = time.monotonic()

    def _complete():
        # capture-to-commit wall time: the number that matters for the
        # cadence budget is when the manifest is durable, not when the
        # worker was spawned
        metrics.hist_record("snapshot.save", time.monotonic() - t0)
        if on_complete is not None:
            on_complete()

    path = step_dir(directory, step)
    os.makedirs(path, exist_ok=True)
    _write_meta(path, meta)
    return ckpt.save_async(state, directory, step, on_complete=_complete,
                           on_error=on_error)


# ---------------------------------------------------------------------------
# completeness checking (crash-during-snapshot recovery)
# ---------------------------------------------------------------------------


def _step_is_complete(path: str, *, require_meta: bool = True) -> bool:
    """A step is complete iff the manifest parses, the sidecar parses (when
    required) and every manifest key loads from the npz with its recorded
    shape.  Anything else — missing files, torn json, truncated zip — is an
    aborted snapshot and must be skipped, never half-restored."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys = manifest["keys"]
        shapes = manifest["shapes"]
        if require_meta:
            with open(os.path.join(path, META_NAME)) as f:
                json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as data:
            for i, (key, shape) in enumerate(zip(keys, shapes)):
                arr = data[f"a{i}"]  # forces the read; truncation raises
                if tuple(arr.shape) != tuple(shape):
                    return False
        return True
    except Exception:
        return False


def latest_complete_step(directory: str,
                         require_meta: bool = True) -> Optional[int]:
    """Newest step whose snapshot is fully readable (see
    :func:`_step_is_complete`); ``None`` when no complete snapshot exists."""
    if not os.path.isdir(directory):
        return None
    steps = sorted(
        (int(name.split("_")[1]) for name in os.listdir(directory)
         if name.startswith("step_")),
        reverse=True)
    for step in steps:
        if _step_is_complete(step_dir(directory, step),
                             require_meta=require_meta):
            return step
    return None


def load_meta(directory: str, step: int) -> dict:
    with open(os.path.join(step_dir(directory, step), META_NAME)) as f:
        return json.load(f)


def restore_snapshot(tree_like: PyTree, directory: str,
                     step: Optional[int] = None,
                     shardings: Optional[PyTree] = None,
                     metrics: Optional[obs_metrics.Registry] = None
                     ) -> Tuple[PyTree, dict, int]:
    """Restore the newest *complete* snapshot (or ``step``) into the
    structure of ``tree_like``.  Returns ``(state, meta, step)``."""
    metrics = metrics if metrics is not None else obs_metrics.GLOBAL
    with metrics.span("snapshot.restore", step=step):
        if step is None:
            step = latest_complete_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no complete snapshot under {directory}")
        elif not _step_is_complete(step_dir(directory, step)):
            raise FileNotFoundError(
                f"snapshot step {step} under {directory} is incomplete")
        meta = load_meta(directory, step)
        state, _ = ckpt.restore(tree_like, directory, step,
                                shardings=shardings)
        return state, meta, step
