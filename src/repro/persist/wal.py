"""Append-only write-ahead log of observed transition batches (DESIGN.md §10).

The learner's input is a stream of ``(src, dst, w)`` int32 batches; every
state transition of the chain is a pure function of (previous state, batch),
so logging the *batches* — not the state deltas — makes recovery a replay:
``restore(latest snapshot)`` then re-apply every record with
``seq > snapshot.wal_seq`` through the same update pipeline.  Determinism of
``update_batch`` / ``maybe_decay`` (pre-aggregation sorts are stable, the
slow path is a sequential scan, kernels are bit-exact across impls) makes
the replay reproduce the pre-crash state *bit-exactly* on the unsharded
path — tested, not assumed.

Format: segments ``wal_<first_seq:016d>.seg`` of length-framed records::

    header  = <4s I q i>  magic 'MCWL', crc32(payload), seq, n_items
    payload = src[n] int32le + dst[n] int32le + w[n] int32le

A record is valid iff the header is whole, the magic matches, the payload is
whole and the CRC agrees.  An invalid record ends its *segment* — the torn
tail a crash mid-append leaves is as if the record never happened (its batch
was also never applied: append happens *before* apply, hence write-AHEAD).
Later segments are still replayed, but only while sequence numbers stay
contiguous: after a crash the writer resumes exactly at the torn record's
seq in a fresh segment (so the chain continues through the tear), whereas a
genuine mid-log gap (bit rot swallowing whole records with valid data
after) breaks contiguity and replay refuses to resurrect anything past it.

fsync policy (assumption A11): ``always`` fsyncs file data after every
append (strongest; one fsync per batch), ``rotate`` (default) fsyncs on
segment close and relies on the OS for the open segment (bounded loss: at
most one segment of batches), ``never`` leaves it all to the OS.  Directory
entries are fsynced on segment create/close under ``always``/``rotate``.
Because rotation is the durability point under ``rotate``, a failed
rotation fsync there raises :class:`SegmentRotationError` (persistent, no
retry) so the caller escalates instead of trusting a segment that may not
survive power loss; under ``always`` the same failure is swallowed and
counted (``io_errors``) — every record is already individually durable.
``close()`` always swallows (counted): it must not mask the caller's
shutdown path, so under ``rotate`` the final segment's durability after a
failing close is best-effort — an engine that needs better runs
``checkpoint()`` before ``close()``.
"""

from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.faults import failpoint
from repro.obs import metrics as obs_metrics
from repro.runtime.fault_tolerance import UnretryableIOError

_MAGIC = b"MCWL"
_HEADER = struct.Struct("<4sIqi")

FSYNC_POLICIES = ("always", "rotate", "never")


class SegmentRotationError(UnretryableIOError):
    """Rotation failed under policy ``rotate`` — the rotation fsync IS the
    segment's durability point there, so every acknowledged record of the
    segment may be lost on power failure.  Classified persistent (no
    retry: the failed append-side retry would re-log the just-written
    record under a new seq and double-apply it on replay); the engine's
    escalation ladder poisons the write path instead and ``restore()``
    re-aligns state with whatever actually survived."""


def _fsync_dir(directory: str) -> None:
    fd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _valid_prefix(data: bytes) -> int:
    """Byte length of the structurally-valid record prefix of a segment
    (whole header, magic, whole payload, CRC agrees).  Everything past it
    is a torn tail or garbage from an aborted append."""
    off = 0
    while off + _HEADER.size <= len(data):
        magic, crc, _, n = _HEADER.unpack_from(data, off)
        end = off + _HEADER.size + 3 * 4 * n
        if magic != _MAGIC or n < 0 or end > len(data):
            break
        if zlib.crc32(data[off + _HEADER.size:end]) != crc:
            break
        off = end
    return off


class WriteAheadLog:
    """Segmented, CRC-framed, fsync-policied append log of int32 batches."""

    def __init__(self, directory: str, *, segment_records: int = 256,
                 fsync: str = "rotate",
                 metrics: Optional[obs_metrics.Registry] = None):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync must be one of {FSYNC_POLICIES}")
        # telemetry (DESIGN.md §13): append/fsync/rotate latency
        # histograms; armed-only, standalone WALs record to the global
        # registry
        self.metrics = metrics if metrics is not None else obs_metrics.GLOBAL
        if segment_records < 1:
            raise ValueError("segment_records must be >= 1")
        self.directory = directory
        self.segment_records = segment_records
        self.fsync = fsync
        os.makedirs(directory, exist_ok=True)
        self._fh = None
        self._fh_records = 0
        # guards the writer file handle vs truncate_through: snapshot GC
        # runs on async snapshot completion threads while the engine's
        # writer keeps appending (appends themselves stay serialised by the
        # engine's write lock; this mutex only makes GC safe against them)
        self._mu = threading.Lock()
        #: swallowed IO faults (rotate/close failures after the record was
        #: already durable) — surfaced into engine stats, never raised
        self.io_errors = 0
        self._next_seq = self._scan_next_seq()

    # -- discovery ------------------------------------------------------
    def _segments(self):
        if not os.path.isdir(self.directory):
            return []
        names = sorted(n for n in os.listdir(self.directory)
                       if n.startswith("wal_") and n.endswith(".seg"))
        return [os.path.join(self.directory, n) for n in names]

    def _scan_next_seq(self) -> int:
        last = -1
        for _, seq, *_ in self._iter_records():
            last = max(last, seq)
        return last + 1

    # -- write side -----------------------------------------------------
    def append(self, src, dst, w=None) -> int:
        """Durably log one batch; returns its sequence number.  Call BEFORE
        applying the batch to the chain (write-ahead ordering)."""
        src = np.asarray(src, dtype="<i4").reshape(-1)
        dst = np.asarray(dst, dtype="<i4").reshape(-1)
        w = (np.ones_like(src) if w is None
             else np.asarray(w, dtype="<i4").reshape(-1))
        if not (src.size == dst.size == w.size):
            raise ValueError(
                f"ragged batch: {src.size}/{dst.size}/{w.size} items")
        with self._mu:
            t_append = time.monotonic()
            seq = self._next_seq
            payload = src.tobytes() + dst.tobytes() + w.tobytes()
            record = _HEADER.pack(_MAGIC, zlib.crc32(payload), seq,
                                  src.size) + payload
            if self._fh is None:
                self._open_segment_locked(seq)
            start = self._fh.tell()
            try:
                failpoint("wal.append.write", fh=self._fh, record=record,
                          seq=seq)
                self._fh.write(record)
                self._fh.flush()
                if self.fsync == "always":
                    failpoint("wal.append.fsync", fh=self._fh, seq=seq)
                    t_fsync = time.monotonic()
                    os.fsync(self._fh.fileno())
                    self.metrics.hist_record(
                        "wal.fsync", time.monotonic() - t_fsync)
            except Exception:
                # the record was NOT acknowledged: scrub whatever partial
                # bytes landed so a retry (same seq) or a later append
                # never writes after garbage mid-segment.  If even the
                # truncate fails, abandon the handle — the next append
                # opens a fresh segment at this seq, which replay accepts
                # (same contiguity rule as crash-resume).
                try:
                    self._fh.truncate(start)
                    self._fh.seek(start)
                except Exception:
                    self._abandon_segment_locked()
                raise
            self._fh_records += 1
            self._next_seq = seq + 1
            if self._fh_records >= self.segment_records:
                # Rotation failure handling depends on where durability
                # lives (A11).  Under 'always' every record is already
                # fsynced, so a failed close costs nothing durable:
                # swallow, count, abandon the segment (raising would make
                # the caller retry an acknowledged record under a new seq
                # — double apply on replay).  Under 'rotate' the rotation
                # fsync IS the durability point of the whole segment:
                # swallowing would acknowledge records that may vanish on
                # power loss, so escalate with an unretryable error — the
                # engine poisons its write path and restore() re-aligns.
                # Under 'never' durability is best-effort by contract.
                try:
                    self._rotate_locked()
                except Exception as exc:
                    self.io_errors += 1
                    self._abandon_segment_locked()
                    if self.fsync == "rotate":
                        raise SegmentRotationError(
                            0, f"segment rotation failed under policy "
                               f"'rotate': {exc!r}") from exc
            self.metrics.hist_record(
                "wal.append", time.monotonic() - t_append)
        return seq

    def _open_segment_locked(self, seq: int) -> None:
        path = os.path.join(self.directory, f"wal_{seq:016d}.seg")
        failpoint("wal.segment_open", path=path)
        if os.path.exists(path) and os.path.getsize(path):
            # crash-resume collision: a previous run tore this segment's
            # FIRST record (otherwise our resume seq would be past it).
            # Appending after the torn bytes would hide every new record
            # from replay, so cut the file back to its valid prefix.
            with open(path, "rb") as f:
                data = f.read()
            keep = _valid_prefix(data)
            if keep < len(data):
                with open(path, "r+b") as f:
                    f.truncate(keep)
        self._fh = open(path, "ab")
        self._fh_records = 0
        if self.fsync != "never":
            _fsync_dir(self.directory)

    def _abandon_segment_locked(self) -> None:
        fh, self._fh, self._fh_records = self._fh, None, 0
        if fh is not None:
            try:
                fh.close()
            except Exception:
                self.io_errors += 1

    def _rotate_locked(self) -> None:
        if self._fh is None:
            return
        t_rotate = time.monotonic()
        failpoint("wal.rotate", fh=self._fh)
        if self.fsync in ("always", "rotate"):
            os.fsync(self._fh.fileno())
        self._fh.close()
        self._fh = None
        self._fh_records = 0
        self.metrics.hist_record("wal.rotate", time.monotonic() - t_rotate)

    def close(self) -> None:
        with self._mu:
            try:
                self._rotate_locked()
            except Exception:
                # every acknowledged record is already as durable as the
                # fsync policy promises; a failing close must not mask
                # the caller's shutdown path
                self.io_errors += 1
                self._abandon_segment_locked()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- read side ------------------------------------------------------
    def _iter_records(self):
        """Yield ``(path, seq, src, dst, w)`` in strictly contiguous
        sequence order.

        An invalid record (bad magic/length/CRC, or a trailing partial —
        the torn tail of a crash mid-append) ends its segment; scanning
        continues with the next segment, because a post-crash writer
        resumes at the torn seq in a fresh segment (the tear hides no
        acknowledged record).  Contiguity is enforced across everything
        yielded: a segment whose first record does not follow the previous
        yielded seq means records were *lost* mid-log, and everything past
        that gap is untrusted — stop."""
        expected = None
        for path in self._segments():
            with open(path, "rb") as f:
                data = f.read()
            off = 0
            while off + _HEADER.size <= len(data):
                magic, crc, seq, n = _HEADER.unpack_from(data, off)
                end = off + _HEADER.size + 3 * 4 * n
                if magic != _MAGIC or n < 0 or end > len(data):
                    break  # torn/corrupt: ends this segment only
                payload = data[off + _HEADER.size:end]
                if zlib.crc32(payload) != crc:
                    break
                if expected is not None and seq < expected:
                    # duplicate from a retried append whose first write
                    # was durable but unacknowledged (fsync raised after
                    # the data landed): same seq, same payload — skip it
                    off = end
                    continue
                if expected is not None and seq > expected:
                    return  # gap: records lost, stop trusting the log
                src = np.frombuffer(payload, dtype="<i4", count=n)
                dst = np.frombuffer(payload, dtype="<i4", count=n,
                                    offset=4 * n)
                w = np.frombuffer(payload, dtype="<i4", count=n,
                                  offset=8 * n)
                yield path, seq, src, dst, w
                expected = seq + 1
                off = end

    def replay(self, after_seq: int = -1
               ) -> Iterator[Tuple[int, np.ndarray, np.ndarray, np.ndarray]]:
        """Yield ``(seq, src, dst, w)`` for every durable record with
        ``seq > after_seq``, in sequence order."""
        for _, seq, src, dst, w in self._iter_records():
            if seq > after_seq:
                yield seq, src, dst, w

    # -- maintenance ----------------------------------------------------
    def truncate_through(self, seq: int) -> int:
        """Delete segments made redundant by a snapshot at ``seq`` (every
        record of the segment has ``seq' <= seq``).  Returns the number of
        segments removed.  Conservative: a segment containing any newer
        record is kept whole.  Safe against a concurrent appender (the
        engine's snapshot-cadence GC runs this from async snapshot
        completion threads): the writer mutex pins the open segment while
        the unlink decisions are made."""
        removed = 0
        with self._mu:
            keep_from: Optional[str] = None
            last_by_path: dict = {}
            for path, rec_seq, *_ in self._iter_records():
                last_by_path[path] = rec_seq
            for path in self._segments():
                if path == (self._fh and self._fh.name):
                    continue  # never unlink the open segment
                if (last_by_path.get(path, seq + 1) <= seq
                        and keep_from is None):
                    os.unlink(path)
                    removed += 1
                else:
                    keep_from = keep_from or path
        return removed

    def resume_at(self, next_seq: int) -> None:
        """Fast-forward the writer's sequence counter (restore path).
        After :meth:`truncate_through` unlinked every segment, a fresh
        process's scan finds an empty directory and would restart at 0 —
        colliding with records the snapshot already covers.  The snapshot
        meta's ``wal_seq`` is the durable authority; never rewinds."""
        with self._mu:
            self._next_seq = max(self._next_seq, int(next_seq))

    @property
    def next_seq(self) -> int:
        return self._next_seq

    @property
    def last_seq(self) -> int:
        return self._next_seq - 1
