"""Durability & elasticity for MCPrioQ chains (DESIGN.md §10).

Three pieces compose into crash recovery and N -> M elastic restore:

* :mod:`repro.persist.snapshot` — epoch-consistent snapshots of ``MCState``
  (single or shard-stacked), reusing the ``checkpoint/ckpt.py`` manifest+npz
  layout plus a ``chain.json`` sidecar (config, shard count, WAL position).
* :mod:`repro.persist.wal` — append-only segmented write-ahead log of
  observed ``(src, dst, w)`` batches with CRC-framed records, torn-tail
  detection and an explicit fsync policy.
* :mod:`repro.persist.reshard` — restores a snapshot taken at N shards onto
  M shards by extracting the live edges host-side and re-routing them
  through the pre-aggregated ``slab_update`` path under the two-level
  :class:`repro.sharding.ownership.Ownership` map.

Recovery contract: ``state = restore(latest complete snapshot)`` then replay
WAL records with ``seq > snapshot.wal_seq`` through the same (deterministic)
update pipeline — bit-exact on the unsharded path, exact-modulo-approximate-
order on an elastic reshard.
"""

from repro.persist.snapshot import (  # noqa: F401
    latest_complete_step,
    load_meta,
    restore_snapshot,
    save_snapshot,
    save_snapshot_async,
)
from repro.persist.wal import WriteAheadLog  # noqa: F401
from repro.persist.reshard import (  # noqa: F401
    extract_edges,
    plan_batches,
    settle_order,
)
