"""N -> M elastic restore: re-route a snapshot's live edges (DESIGN.md §10).

Arrays snapshotted at N shards cannot be `device_put` onto M shards: row
placement is a function of the ownership map, so changing the shard count
moves *every node whose bucket moved* — the slabs must be rebuilt, not
resliced.  The trick is that an MCPrioQ is fully described by its live edge
multiset: ``(src, dst, cnt)`` triples.  Extraction walks the snapshot
host-side (the src hash table's reverse map labels rows), and re-ingestion
feeds the triples back through the **existing pre-aggregated slab_update
path** — the routed update pipeline itself is the reshard engine, so the
restored chain obeys every routing/capacity invariant by construction.

Two invariants make this exact (tested):

* **Counts are conserved.**  Each unique ``(src, dst)`` appears once with
  weight ``cnt``; pre-aggregation passes it through untouched and the slow
  path inserts it with that exact count, so ``cnt``/``tot`` on the restored
  chain equal the snapshot's wherever capacity suffices (drops are counted,
  as everywhere else).
* **Zero routing drops by planning.**  Bucket capacity is per-batch fixed;
  a Zipf-skewed edge list fed naively can overflow one owner's bucket.
  :func:`plan_batches` packs each batch with at most ``bucket_capacity``
  items per destination shard, so the all_to_all provably never drops.

The order permutation is *not* conserved — it is approximate state by the
paper's own contract (A2).  :func:`settle_order` restores the exact
descending order after ingestion, which every settled chain converges to.
"""

from __future__ import annotations

from typing import Iterator, Tuple

import jax
import numpy as np

from repro.core import mcprioq as mc
from repro.core import slab as sl


def extract_edges(state: mc.MCState
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Live edges of a (possibly shard-stacked) ``MCState``, host-side.

    Returns ``(src, dst, cnt)`` int32 arrays in deterministic
    (shard, row, slot) order.  Rows whose src id cannot be recovered from
    the hash table are skipped (cannot happen while the src-table invariant
    holds; defensive for corrupted snapshots).
    """
    host = jax.tree_util.tree_map(np.asarray, jax.device_get(state))
    keys, vals = host.src_table.keys, host.src_table.vals
    dst, cnt = host.slabs.dst, host.slabs.cnt
    if keys.ndim == 1:  # unsharded: treat as one shard
        keys, vals = keys[None], vals[None]
        dst, cnt = dst[None], cnt[None]
    srcs, dsts, cnts = [], [], []
    num_rows = dst.shape[1]
    for s in range(keys.shape[0]):
        row_src = np.full((num_rows,), -1, np.int32)
        valid = (keys[s] >= 0) & (vals[s] >= 0) & (vals[s] < num_rows)
        row_src[vals[s][valid]] = keys[s][valid]
        live = (cnt[s] > 0) & (row_src >= 0)[:, None]
        rows, slots = np.nonzero(live)
        srcs.append(row_src[rows])
        dsts.append(dst[s][rows, slots])
        cnts.append(cnt[s][rows, slots])
    return (np.concatenate(srcs).astype(np.int32),
            np.concatenate(dsts).astype(np.int32),
            np.concatenate(cnts).astype(np.int32))


def plan_batches(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 owner: np.ndarray, num_shards: int, slice_len: int,
                 bucket_capacity: int
                 ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Pack edges into routed-update batches that provably never drop.

    The routed path slices a global batch into ``num_shards`` contiguous
    per-shard slices of ``slice_len`` items and enforces bucket capacity
    **per (source slice, destination shard)** pair, so that pair count is
    the constraint to plan against.  Greedy fill: each slice draws at most
    ``bucket_capacity`` items per destination queue (round-robin start so
    skewed owners don't monopolise slice 0) and at most ``slice_len``
    total; slice tails pad with inactive (-1) items, which consume no
    bucket capacity.  Yields ``(src, dst, w)`` global batches of exactly
    ``num_shards * slice_len`` items — already a shard multiple, so the
    engine's host-side padding is a no-op and slice alignment is preserved.

    Covers every edge exactly once; terminates because every non-empty
    round moves at least one item.
    """
    queues = [list(np.nonzero(owner == d)[0]) for d in range(num_shards)]
    heads = [0] * num_shards
    wave = 0
    while any(heads[d] < len(queues[d]) for d in range(num_shards)):
        g_src = np.full((num_shards, slice_len), -1, np.int32)
        g_dst = np.zeros((num_shards, slice_len), np.int32)
        g_w = np.zeros((num_shards, slice_len), np.int32)
        for s in range(num_shards):
            fill = 0
            for j in range(num_shards):
                d = (s + wave + j) % num_shards
                room = min(bucket_capacity, slice_len - fill)
                take = min(room, len(queues[d]) - heads[d])
                if take <= 0:
                    continue
                idx = queues[d][heads[d]:heads[d] + take]
                heads[d] += take
                g_src[s, fill:fill + take] = src[idx]
                g_dst[s, fill:fill + take] = dst[idx]
                g_w[s, fill:fill + take] = w[idx]
                fill += take
                if fill >= slice_len:
                    break
        wave += 1
        yield g_src.reshape(-1), g_dst.reshape(-1), g_w.reshape(-1)


def settle_order(state: mc.MCState) -> mc.MCState:
    """Exact descending order on every row (stable argsort, ties to the
    lower slot id — the same tie-break a fully settled odd-even network
    reaches from slot order).  Applied once after re-ingestion; subsequent
    updates resume the normal approximate odd-even maintenance."""
    cnt = state.slabs.cnt
    flat = cnt.reshape(-1, cnt.shape[-1])
    order = sl.full_sort(flat, None).reshape(cnt.shape)
    slabs = state.slabs._replace(order=order.astype(state.slabs.order.dtype))
    return state._replace(slabs=slabs)
