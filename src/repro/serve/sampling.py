"""Sampling from LM logits — including the paper's cumulative-threshold
semantics as top-p (the CDF^-1(t) query applied to the model distribution)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(rng, logits: jax.Array, temp: float = 1.0) -> jax.Array:
    return jax.random.categorical(rng, logits / max(temp, 1e-6)).astype(jnp.int32)


def top_p(rng, logits: jax.Array, p: float = 0.9, temp: float = 1.0
          ) -> jax.Array:
    """Nucleus sampling == the paper's threshold query on the model's own
    distribution: keep items in descending probability until cumsum >= p."""
    logits = logits / max(temp, 1e-6)
    probs = jax.nn.softmax(logits, axis=-1)
    sorted_p, sorted_idx = jax.lax.top_k(probs, probs.shape[-1])
    cum = jnp.cumsum(sorted_p, axis=-1)
    keep = (cum - sorted_p) < p          # same "before < t" rule as cdf_query
    masked = jnp.where(keep, sorted_p, 0.0)
    masked = masked / jnp.sum(masked, axis=-1, keepdims=True)
    pick = jax.random.categorical(rng, jnp.log(masked + 1e-30))
    return jnp.take_along_axis(sorted_idx, pick[..., None],
                               axis=-1)[..., 0].astype(jnp.int32)
