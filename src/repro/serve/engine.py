"""Serving engine: prefill/decode loop with the MCPrioQ speculative drafter.

The paper's structure is a first-class serving feature here (DESIGN.md
§Arch-applicability):
  * an **online n-gram drafter** (core/speculative.py) continuously learns
    token transitions from the engine's own emitted tokens — an online sparse
    Markov chain exactly as §II of the paper describes — and proposes draft
    chains; a draft is ONE fused walk-kernel dispatch against the snapshot
    (``ops.draft_walk``), not k round trips of lookup + gather + cdf_query;
  * the **target model** verifies a K-token draft in ONE ``extend_step``
    forward (vs K sequential decodes); rejection rollback is free because
    cache pytrees are immutable — the engine just keeps the pre-extend
    caches and re-extends with the accepted prefix (recurrent-state-safe
    for SSM/RG-LRU archs);
  * the chain lives behind an :class:`EpochStore` snapshot (the RCU
    analogue): the learner publishes new versions while serving reads.

Acceptance is conservative (batch-wide longest common prefix) to keep
shapes static; greedy outputs are bit-identical to plain decoding.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mcprioq as mc
from repro.core import speculative as spec
from repro.core.epoch import EpochStore
from repro.models.model import Model
from repro.serve import sampling

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 64
    max_cache_len: int = 512
    draft_len: int = 4            # speculation depth (0 = disabled)
    ngram: spec.NGramConfig = spec.NGramConfig()
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    """Host-side orchestration; all device work is jitted, static-shaped."""

    def __init__(self, model: Model, params: PyTree, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.drafter_store = EpochStore(spec.init(cfg.ngram))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_cache_len))
        self._decode = jax.jit(model.decode_step)
        self._extend = jax.jit(model.extend_step)
        self._observe = jax.jit(
            lambda st, toks: spec.observe(st, toks, cfg=cfg.ngram))
        self._maintain = functools.partial(spec.maintain, cfg=cfg.ngram)
        self._draft = jax.jit(
            lambda st, ctx: spec.draft(st, ctx, cfg=cfg.ngram,
                                       k=max(cfg.draft_len, 1)))
        # The learner side is a read-modify-write on the EpochStore
        # (acquire -> observe -> publish): without serialisation two
        # overlapping generate() calls publish from the same base and the
        # second silently discards the first's counts.  Readers (drafting)
        # stay lock-free; only the single-writer invariant is enforced.
        self._learn_lock = threading.Lock()
        # model_calls counts decode+extend forwards (the latency metric);
        # plain greedy needs exactly max_new_tokens-1 of them
        self.stats = {"model_calls": 0, "accepted": 0, "drafted": 0,
                      "rounds": 0, "draft_calls": 0, "decay_steps": 0,
                      "dh_rebuilds": 0, "dh_tombstones": 0}

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array], rng: jax.Array
                 ) -> np.ndarray:
        """Generate max_new_tokens per sequence. Returns int32 [B, N]."""
        cfg = self.cfg
        tokens = np.asarray(batch["tokens"])
        b, s = tokens.shape
        logits, caches = self._prefill(self.params, batch)
        out = np.zeros((b, cfg.max_new_tokens), np.int32)
        rng, sub = jax.random.split(rng)
        cur = self._sample(logits, sub)          # first new token
        pos = jnp.full((b,), s, jnp.int32)       # cache position of `cur`
        n_done = 0
        history = tokens.copy()

        while n_done < cfg.max_new_tokens:
            out[:, n_done] = np.asarray(cur)
            history = np.concatenate([history, np.asarray(cur)[:, None]], 1)
            n_done += 1
            if n_done >= cfg.max_new_tokens:
                break
            rng, sub = jax.random.split(rng)
            budget = cfg.max_new_tokens - n_done
            if cfg.draft_len > 0 and budget > 1 and cfg.greedy:
                cur, pos, emitted = self._speculative_round(
                    caches, cur, pos, history, min(cfg.draft_len, budget - 1),
                    sub)
                caches = self._caches  # updated by the round
                for t in emitted:
                    out[:, n_done] = t
                    history = np.concatenate([history, t[:, None]], 1)
                    n_done += 1
                    if n_done >= cfg.max_new_tokens:
                        break
            else:
                logits, caches = self._decode(self.params, caches,
                                              cur[:, None], pos)
                self.stats["model_calls"] += 1
                cur = self._sample(logits, sub)
                pos = pos + 1

        # online learning: feed emitted tokens back into the chain and
        # publish a new RCU snapshot for subsequent requests
        self._learn(history)
        return out

    # ------------------------------------------------------------------
    def _learn(self, history) -> None:
        """Serialised learner step: observe emitted tokens, run §II.C
        maintenance (rolling decay + dst-hash repair behind the snapshot),
        publish, and surface the maintenance counters in ``stats``."""
        toks = jnp.asarray(history)
        with self._learn_lock:
            snap = self.drafter_store.acquire()
            try:
                new_state = self._observe(snap.state, toks)
                new_state = self._maintain(new_state)
            finally:
                self.drafter_store.release(snap)
            self.drafter_store.publish(new_state)
            # inside the lock: a stale snapshot's counters must not
            # overwrite a newer learner's in stats
            self.stats.update(
                {k: v for k, v in mc.maintenance_stats(new_state.chain).items()
                 if k in self.stats})

    # ------------------------------------------------------------------
    def _speculative_round(self, caches, cur, pos, history, k, rng
                           ) -> Tuple[jax.Array, jax.Array, list]:
        """One draft-verify round.

        Feeds [cur, draft_0..draft_{k-2}] (k tokens) through extend_step;
        logits[i] is the model's choice after consuming token i.  Batch-wide
        longest-prefix acceptance; on partial acceptance the pre-extend
        caches are kept (free rollback) and re-extended with the accepted
        tokens only — exact for recurrent state too.
        Returns (next cur, next pos, [emitted token arrays]).
        """
        snap = self.drafter_store.acquire()
        try:
            ctx = jnp.asarray(history[:, -max(self.cfg.ngram.order, 2):])
            draft, ok = self._draft(snap.state, ctx)
            self.stats["draft_calls"] += 1    # one fused dispatch per round
        finally:
            self.drafter_store.release(snap)
        draft = np.asarray(draft)[:, : k - 1] if k > 1 else \
            np.zeros((cur.shape[0], 0), np.int32)
        ok = np.asarray(ok)[:, : k - 1] if k > 1 else \
            np.zeros((cur.shape[0], 0), bool)
        n_drafted = int(ok.all(axis=0).cumprod().sum()) if ok.size else 0
        draft = draft[:, :n_drafted]

        if n_drafted == 0:  # nothing usable: plain decode step
            logits, self._caches = self._decode(self.params, caches,
                                                cur[:, None], pos)
            self.stats["model_calls"] += 1
            nxt = self._sample(logits, rng)
            return nxt, pos + 1, []

        self.stats["rounds"] += 1
        self.stats["drafted"] += int(draft.size)
        feed = jnp.concatenate(
            [cur[:, None], jnp.asarray(draft)], axis=1)       # [B, 1+n]
        logits, ext_caches = self._extend(self.params, caches, feed, pos)
        self.stats["model_calls"] += 1
        model_toks = np.asarray(self._sample_all(logits, rng))  # [B, 1+n]

        # longest batch-wide prefix where model agrees with the draft
        agree = (model_toks[:, :-1] == draft).all(axis=0) if draft.size \
            else np.zeros((0,), bool)
        n_acc = int(np.cumprod(agree).sum()) if agree.size else 0
        self.stats["accepted"] += n_acc * draft.shape[0]

        emitted = [model_toks[:, j] for j in range(n_acc)]
        if n_acc == draft.shape[1]:
            # fully accepted: keep the extended caches; bonus token is the
            # model's continuation after the last draft token
            self._caches = ext_caches
            nxt = jnp.asarray(model_toks[:, n_acc])
            return nxt, pos + n_acc + 1, emitted
        # partial: roll back (keep pre-extend caches) and re-extend with the
        # accepted prefix only; the correction token came from the verify
        accepted_feed = feed[:, : n_acc + 1]
        _, self._caches = self._extend(self.params, caches, accepted_feed,
                                       pos)
        self.stats["model_calls"] += 1
        nxt = jnp.asarray(model_toks[:, n_acc])
        return nxt, pos + n_acc + 1, emitted

    # ------------------------------------------------------------------
    def _sample(self, logits, rng):
        if self.cfg.greedy:
            return sampling.greedy(logits)
        return sampling.temperature(rng, logits, self.cfg.temperature)

    def _sample_all(self, logits, rng):
        """logits [B, K, V] -> tokens [B, K] (greedy only for speculation)."""
        return sampling.greedy(logits)

    @property
    def acceptance_rate(self) -> float:
        return self.stats["accepted"] / max(1, self.stats["drafted"])
