"""Serving engine: prefill/decode loop with the MCPrioQ speculative drafter.

The paper's structure is a first-class serving feature here (DESIGN.md
§Arch-applicability):
  * an **online n-gram drafter** (core/speculative.py) continuously learns
    token transitions from the engine's own emitted tokens — an online sparse
    Markov chain exactly as §II of the paper describes — and proposes draft
    chains; a draft is ONE fused walk-kernel dispatch against the snapshot
    (``ops.draft_walk``), not k round trips of lookup + gather + cdf_query;
  * the **target model** verifies a K-token draft in ONE ``extend_step``
    forward (vs K sequential decodes); rejection rollback is free because
    cache pytrees are immutable — the engine just keeps the pre-extend
    caches and re-extends with the accepted prefix (recurrent-state-safe
    for SSM/RG-LRU archs);
  * the chain lives behind an :class:`EpochStore` snapshot (the RCU
    analogue): the learner publishes new versions while serving reads.

Acceptance is conservative (batch-wide longest common prefix) to keep
shapes static; greedy outputs are bit-identical to plain decoding.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.analysis.invariants import requires_lock
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.core import speculative as spec
from repro.core.epoch import EpochStore
from repro.faults import arm_from_env, failpoint
from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.persist import reshard as rs
from repro.persist import snapshot as snapshot_io
from repro.persist.wal import WriteAheadLog
from repro.runtime.fault_tolerance import (EngineWriteUnavailable,
                                           RetryPolicy, ShardHealth,
                                           StepWatchdog, WatchdogConfig,
                                           call_with_retry,
                                           shard_from_exception)
from repro.serve import sampling
from repro.sharding.ownership import Ownership

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 64
    max_cache_len: int = 512
    draft_len: int = 4            # speculation depth (0 = disabled)
    ngram: spec.NGramConfig = spec.NGramConfig()
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    """Host-side orchestration; all device work is jitted, static-shaped."""

    # normative lock order + protection map (DESIGN.md §11, checked by
    # tools/mcqlint): the learner lock serialises publish AND the
    # maintenance-gauge view derived from the published state
    _MCQ_LOCK_ORDER = ("_learn_lock",)
    _MCQ_LOCK_PROTECTS = {
        "_learn_lock": ("drafter_store.publish", "_maint"),
    }

    def __init__(self, model: Model, params: PyTree, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.drafter_store = EpochStore(spec.init(cfg.ngram))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.max_cache_len))
        self._decode = jax.jit(model.decode_step)
        self._extend = jax.jit(model.extend_step)
        self._observe = jax.jit(
            lambda st, toks: spec.observe(st, toks, cfg=cfg.ngram))
        self._maintain = functools.partial(spec.maintain, cfg=cfg.ngram)
        self._draft = jax.jit(
            lambda st, ctx: spec.draft(st, ctx, cfg=cfg.ngram,
                                       k=max(cfg.draft_len, 1)))
        # The learner side is a read-modify-write on the EpochStore
        # (acquire -> observe -> publish): without serialisation two
        # overlapping generate() calls publish from the same base and the
        # second silently discards the first's counts.  Readers (drafting)
        # stay lock-free; only the single-writer invariant is enforced.
        self._learn_lock = threading.Lock()
        # telemetry (DESIGN.md §13): counters go straight into the
        # lock-free obs registry — concurrent generate() calls each
        # increment their own thread shard, so the undercount race the
        # old shared dict needed a lock for cannot happen at all.
        # model_calls counts decode+extend forwards (the latency metric);
        # plain greedy needs exactly max_new_tokens-1 of them.
        self.metrics = obs_metrics.Registry()
        # maintenance gauges are absolute values read off the freshly
        # published chain (not increments); surfaced through a provider so
        # scrapes and the stats view share one source of truth
        self._maint = {"decay_steps": 0, "dh_rebuilds": 0,
                       "dh_tombstones": 0}
        self.metrics.register_provider(lambda: dict(self._maint))

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array], rng: jax.Array
                 ) -> np.ndarray:
        """Generate max_new_tokens per sequence. Returns int32 [B, N]."""
        cfg = self.cfg
        tokens = np.asarray(batch["tokens"])
        b, s = tokens.shape
        logits, caches = self._prefill(self.params, batch)
        out = np.zeros((b, cfg.max_new_tokens), np.int32)
        rng, sub = jax.random.split(rng)
        cur = self._sample(logits, sub)          # first new token
        pos = jnp.full((b,), s, jnp.int32)       # cache position of `cur`
        n_done = 0
        history = tokens.copy()

        while n_done < cfg.max_new_tokens:
            out[:, n_done] = np.asarray(cur)
            history = np.concatenate([history, np.asarray(cur)[:, None]], 1)
            n_done += 1
            if n_done >= cfg.max_new_tokens:
                break
            rng, sub = jax.random.split(rng)
            budget = cfg.max_new_tokens - n_done
            if cfg.draft_len > 0 and budget > 1 and cfg.greedy:
                cur, pos, emitted = self._speculative_round(
                    caches, cur, pos, history, min(cfg.draft_len, budget - 1),
                    sub)
                caches = self._caches  # updated by the round
                for t in emitted:
                    out[:, n_done] = t
                    history = np.concatenate([history, t[:, None]], 1)
                    n_done += 1
                    if n_done >= cfg.max_new_tokens:
                        break
            else:
                logits, caches = self._decode(self.params, caches,
                                              cur[:, None], pos)
                self.metrics.counter_add("model_calls")
                cur = self._sample(logits, sub)
                pos = pos + 1

        # online learning: feed emitted tokens back into the chain and
        # publish a new RCU snapshot for subsequent requests
        self._learn(history)
        return out

    # ------------------------------------------------------------------
    def _learn(self, history) -> None:
        """Serialised learner step: observe emitted tokens, run §II.C
        maintenance (rolling decay + dst-hash repair behind the snapshot),
        publish, and surface the maintenance counters in ``stats``."""
        toks = jnp.asarray(history)
        with self._learn_lock, self.metrics.span("engine.learn"):
            failpoint("engine.learn", tokens=int(toks.shape[-1]))
            snap = self.drafter_store.acquire()
            try:
                new_state = self._observe(snap.state, toks)
                new_state = self._maintain(new_state)
            finally:
                self.drafter_store.release(snap)
            self.drafter_store.publish(new_state)
            # inside the learn lock: a stale snapshot's counters must not
            # overwrite a newer learner's view
            self._maint = {k: int(v) for k, v
                           in mc.maintenance_stats(new_state.chain).items()
                           if k in self._maint}

    # ------------------------------------------------------------------
    def _speculative_round(self, caches, cur, pos, history, k, rng
                           ) -> Tuple[jax.Array, jax.Array, list]:
        """One draft-verify round.

        Feeds [cur, draft_0..draft_{k-2}] (k tokens) through extend_step;
        logits[i] is the model's choice after consuming token i.  Batch-wide
        longest-prefix acceptance; on partial acceptance the pre-extend
        caches are kept (free rollback) and re-extended with the accepted
        tokens only — exact for recurrent state too.
        Returns (next cur, next pos, [emitted token arrays]).
        """
        snap = self.drafter_store.acquire()
        try:
            ctx = jnp.asarray(history[:, -max(self.cfg.ngram.order, 2):])
            draft, ok = self._draft(snap.state, ctx)
            self.metrics.counter_add("draft_calls")  # one fused dispatch
        finally:
            self.drafter_store.release(snap)
        draft = (np.asarray(draft)[:, : k - 1] if k > 1
                 else np.zeros((cur.shape[0], 0), np.int32))
        ok = (np.asarray(ok)[:, : k - 1] if k > 1
              else np.zeros((cur.shape[0], 0), bool))
        n_drafted = int(ok.all(axis=0).cumprod().sum()) if ok.size else 0
        draft = draft[:, :n_drafted]

        if n_drafted == 0:  # nothing usable: plain decode step
            logits, self._caches = self._decode(self.params, caches,
                                                cur[:, None], pos)
            self.metrics.counter_add("model_calls")
            nxt = self._sample(logits, rng)
            return nxt, pos + 1, []

        self.metrics.counter_add("rounds")
        self.metrics.counter_add("drafted", int(draft.size))
        feed = jnp.concatenate(
            [cur[:, None], jnp.asarray(draft)], axis=1)       # [B, 1+n]
        logits, ext_caches = self._extend(self.params, caches, feed, pos)
        self.metrics.counter_add("model_calls")
        model_toks = np.asarray(self._sample_all(logits, rng))  # [B, 1+n]

        # longest batch-wide prefix where model agrees with the draft
        agree = ((model_toks[:, :-1] == draft).all(axis=0) if draft.size
                 else np.zeros((0,), bool))
        n_acc = int(np.cumprod(agree).sum()) if agree.size else 0
        self.metrics.counter_add("accepted", n_acc * draft.shape[0])

        emitted = [model_toks[:, j] for j in range(n_acc)]
        if n_acc == draft.shape[1]:
            # fully accepted: keep the extended caches; bonus token is the
            # model's continuation after the last draft token
            self._caches = ext_caches
            nxt = jnp.asarray(model_toks[:, n_acc])
            return nxt, pos + n_acc + 1, emitted
        # partial: roll back (keep pre-extend caches) and re-extend with the
        # accepted prefix only; the correction token came from the verify
        accepted_feed = feed[:, : n_acc + 1]
        _, self._caches = self._extend(self.params, caches, accepted_feed,
                                       pos)
        self.metrics.counter_add("model_calls")
        nxt = jnp.asarray(model_toks[:, n_acc])
        return nxt, pos + n_acc + 1, emitted

    # ------------------------------------------------------------------
    def _sample(self, logits, rng):
        if self.cfg.greedy:
            return sampling.greedy(logits)
        return sampling.temperature(rng, logits, self.cfg.temperature)

    def _sample_all(self, logits, rng):
        """logits [B, K, V] -> tokens [B, K] (greedy only for speculation)."""
        return sampling.greedy(logits)

    @property
    def stats(self) -> Dict[str, int]:
        """Backward-compat dict view over the obs registry (the registry
        is the one source of truth; this is a point-in-time copy, so
        mutate metrics through ``self.metrics``, not this dict)."""
        scalars = self.metrics.scalars()
        keys = ("model_calls", "accepted", "drafted", "rounds",
                "draft_calls", "decay_steps", "dh_rebuilds",
                "dh_tombstones")
        return {k: int(scalars.get(k, 0)) for k in keys}

    @property
    def acceptance_rate(self) -> float:
        st = self.stats
        return st["accepted"] / max(1, st["drafted"])


# ---------------------------------------------------------------------------
# sharded chain serving (DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedServeConfig:
    """Serving-side knobs around a :class:`repro.core.sharded.ShardedConfig`."""

    sharded: sh.ShardedConfig
    decay_threshold: int = 1 << 18   # row-total that triggers §II.C decay
    threshold: float = 0.9           # default cumulative-probability target
    max_items: int = 16              # per-query emission window
    topn: int = 16                   # global top-n read size
    # durability & elasticity (DESIGN.md §10): a snapshot dir arms
    # checkpoint()/restore(); snapshot_every > 0 snapshots in the background
    # every that many observe() calls; a WAL dir makes recovery exact
    # (snapshot + deterministic replay of the batches logged after it)
    snapshot_dir: Optional[str] = None
    snapshot_every: int = 0
    wal_dir: Optional[str] = None
    wal_fsync: str = "rotate"        # always | rotate | never (A11)
    observe_deadline_s: float = 60.0  # StepWatchdog budget per observe()
    reingest_slice_len: int = 256    # per-shard batch slice during reshard
    # fault model (DESIGN.md §12): retry ladder for transient IO/dispatch
    # faults, bounded re-route of skew-dropped routed items, degradation
    # knobs.  The retry budgets default to 0 (tier off) so the fault-free
    # pipeline — and WAL-replay determinism against logs written without
    # the tier — is unchanged unless explicitly enabled.
    retry: RetryPolicy = RetryPolicy()
    route_retry_budget: int = 0      # re-route attempts per dropped update
    route_retry_slice: int = 128     # retry items drained per observe()
    query_retry_budget: int = 0      # in-call re-dispatch rounds per query
    health_strikes: int = 3          # consecutive failures -> shard down
    deferred_cap: int = 4096         # max deferred write items (total)
    # telemetry (DESIGN.md §13): where armed flight-recorder incidents
    # dump; MCQ_METRICS_INCIDENT_DIR overrides when set in the process env
    incident_dir: Optional[str] = None


def _hash_u32_np(x: np.ndarray) -> np.ndarray:
    """Vectorised numpy mirror of ``core.hashtable.hash_u32`` (splitmix32)
    so the telemetry traffic tally can bucket a batch host-side without a
    device dispatch."""
    x = x.astype(np.uint32)
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    return x ^ (x >> np.uint32(16))


def _bucket_of_np(src: np.ndarray, num_buckets: int) -> np.ndarray:
    """Host-side twin of ``Ownership.bucket_of``."""
    h = _hash_u32_np(np.asarray(src))
    return ((h >> np.uint32(8)) % np.uint32(num_buckets)).astype(np.int64)


class ShardedEngine:
    """Shard-parallel MCPrioQ behind the serving boundary.

    The pod-scale analogue of the paper's lock-free single-host design
    (DESIGN.md §9): node-space shards with fixed-capacity all_to_all routing,
    every per-shard body dispatching the kernel layer.  The host-side
    contract mirrors :class:`Engine`'s learner: ``observe`` runs the
    single-writer acquire -> observe -> maintain -> publish cycle behind the
    ``EpochStore`` under a writer lock (rolling per-shard decay keeps the
    maintain step O(block) on every shard), while ``query``/``topn`` readers
    stay lock-free on their snapshots.  Routing/overflow counters are
    surfaced in ``stats`` — drops are the measurable price of static shapes,
    the paper's "approximately correct" contract.

    Batches are padded host-side to a multiple of ``num_shards`` with
    inactive (-1) items, which consume no bucket capacity.
    """

    # Normative lock order + protection map (DESIGN.md §11; enforced by
    # tools/mcqlint).  Outermost first; EpochStore._lock is a global leaf
    # below all of these (it is only ever taken inside store calls).  The
    # WAL append rides under the write lock so append-then-apply is atomic
    # with respect to other writers (write-ahead ordering, invariant I3).
    _MCQ_LOCK_ORDER = ("_write_lock", "_route_lock", "_compile_lock",
                       "_stats_lock")
    _MCQ_LOCK_PROTECTS = {
        "_write_lock": ("store.publish", "wal.append", "_seq", "_io_threads",
                        "_retry_queue", "_poisoned"),
        # the (program, snapshot) pairing: _rebind swaps all three together
        "_route_lock": ("cfg", "_update", "_maintain"),
        "_compile_lock": ("_query_fns", "_topn_fns"),
        "_stats_lock": ("stats",),
    }

    def __init__(self, cfg: ShardedServeConfig,
                 mesh: Optional[jax.sharding.Mesh] = None):
        scfg = cfg.sharded
        if mesh is None:
            if scfg.num_shards > jax.device_count():
                raise ValueError(
                    f"num_shards={scfg.num_shards} exceeds the "
                    f"{jax.device_count()} visible devices; set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count="
                    f"{scfg.num_shards} before importing jax to fake them")
            mesh = compat.make_mesh((scfg.num_shards,), (scfg.axis,))
        self.cfg = cfg
        self.mesh = mesh
        self.store = EpochStore(sh.init_sharded(scfg, mesh))
        self._update = sh.make_update_fn(scfg, mesh)
        self._maintain = sh.make_maintain_fn(
            scfg, mesh, total_threshold=cfg.decay_threshold)
        # bounded, insertion-ordered caches of routed read programs keyed by
        # their static args; guarded by a lock so concurrent first-time
        # readers build one program, and capped so per-request float
        # thresholds cannot grow executables without bound
        self._query_fns: Dict[Tuple[float, int], Any] = {}
        self._topn_fns: Dict[int, Any] = {}
        self._fn_cache_max = 8
        self._compile_lock = threading.Lock()
        # single-writer invariant (same reasoning as Engine._learn): two
        # overlapping observe() calls must not publish from the same base
        self._write_lock = threading.Lock()
        # routing-consistency lock: readers hold it only while pairing a
        # routed program with a snapshot (microseconds — never during the
        # device compute), and rebalance/restore hold it while swapping
        # (rebind + publish) so a reader can never combine the NEW
        # ownership's routing with the OLD state's row placement (or vice
        # versa).  Reads stay lock-free with respect to the learner; they
        # briefly serialise only against a rebalance swap.
        self._route_lock = threading.Lock()
        # readers are lock-free on their snapshots, but the stats dict is
        # shared by all of them — unguarded read-modify-write of the drop
        # counters would silently undercount, defeating the observability
        # contract the counters exist for
        self._stats_lock = threading.Lock()
        self.stats = {"updates": 0, "queries": 0, "topn_calls": 0,
                      "query_dropped": 0, "topn_dropped": 0, "snapshots": 0,
                      # fault-model counters (DESIGN.md §12): the retry
                      # ladder, the overflow-retry tier and degraded reads
                      # are only observable through these
                      "route_retried": 0, "route_lost": 0,
                      "query_retried": 0, "query_lost": 0,
                      "degraded_answers": 0, "deferred_writes": 0,
                      "shards_down": 0, "wal_errors": 0, "wal_retries": 0,
                      "apply_retries": 0, "dispatch_retries": 0,
                      "write_errors": 0, "snapshot_failures": 0}
        snap = self.store.acquire()
        try:
            self.stats.update(mc.counter_stats(snap.state))
        finally:
            self.store.release(snap)
        # telemetry (DESIGN.md §13): a per-engine lock-free registry; the
        # stats dict stays the collector (the explorer instruments it) and
        # feeds the registry through a provider, so scrapes, serve.py and
        # tests read one consistent source of truth.  MCQ_METRICS in the
        # env arms histograms/spans/incidents for subprocess harnesses
        # (tools/chaos), same contract as the failpoint arming below.
        own0 = scfg.resolved_ownership()
        env_incident_dir = obs_metrics.arm_from_env()
        self.metrics = obs_metrics.Registry(
            vectors={"bucket_traffic": own0.num_buckets,
                     "shard_traffic": scfg.num_shards},
            incident_dir=env_incident_dir or cfg.incident_dir)
        self.metrics.register_provider(self.stats_snapshot)
        # durability (DESIGN.md §10): WAL position of the published state;
        # -1 = nothing applied.  The WAL resumes its sequence from disk, so
        # an engine pointed at an existing log must restore() before
        # observing or the snapshot/WAL positions drift apart.
        self._seq = -1
        self.wal = (WriteAheadLog(cfg.wal_dir, fsync=cfg.wal_fsync,
                                  metrics=self.metrics)
                    if cfg.wal_dir else None)
        # outstanding background snapshot IO threads (non-daemon: a
        # "committed" snapshot must never be torn by process exit); joined
        # by close() and pruned as they finish
        self._io_threads: list = []
        # straggler escalation -> checkpoint-now, so a kill after a stall
        # loses nothing (runtime/fault_tolerance.py contract)
        self.watchdog = (StepWatchdog(
            WatchdogConfig(deadline_s=cfg.observe_deadline_s),
            on_escalate=self._escalate_snapshot)
            if cfg.snapshot_dir else None)
        # graceful degradation (DESIGN.md §12): per-shard health map — down
        # shards are excluded from routed reads, their writes defer bounded
        self.health = ShardHealth(scfg.num_shards,
                                  strike_limit=cfg.health_strikes,
                                  deferred_cap=cfg.deferred_cap)
        # write-path poisoning (A13): set when an escalated WAL/apply fault
        # leaves durability and applied state out of agreement; observe()
        # raises EngineWriteUnavailable until restore() heals
        self._poisoned: Optional[str] = None
        # carry-over of skew-dropped update items (route_retry_budget > 0):
        # chunks of (src, dst, w, tries) arrays drained at the head of
        # later observe() calls, bounded by the per-item retry budget
        self._retry_queue: list = []
        # failpoints armed via MCQ_FAILPOINTS follow the process, not the
        # engine: arming here makes subprocess harnesses (tools/chaos) work
        # without an API call into the serving process
        arm_from_env()

    # ------------------------------------------------------------------
    def _cached_fn(self, cache: Dict, key, build):
        """Bounded get-or-build of a routed read program (FIFO eviction —
        jit recompiles transparently if an evicted key returns)."""
        with self._compile_lock:
            fn = cache.get(key)
            if fn is None:
                if len(cache) >= self._fn_cache_max:
                    cache.pop(next(iter(cache)))
                fn = build()
                cache[key] = fn
        return fn

    # ------------------------------------------------------------------
    def _pad(self, *arrays):
        """Pad 1-D arrays to a multiple of num_shards with inactive items
        (src = -1 never routes).  Returns (padded..., original_len)."""
        n = self.cfg.sharded.num_shards
        b = arrays[0].shape[0]
        pad = (-b) % n
        out = []
        for i, a in enumerate(arrays):
            a = jnp.asarray(a)
            fill = -1 if i == 0 else 0   # first array is always src
            if pad:
                a = jnp.concatenate(
                    [a, jnp.full((pad,), fill, a.dtype)])
            out.append(a)
        return (*out, b)

    # ------------------------------------------------------------------
    def observe(self, src, dst, weights=None) -> None:
        """Route one transition batch to its owner shards and learn from it.

        Serialised writer: WAL append (write-AHEAD: the batch is durable
        before it is applied) -> acquire -> update (kernel-routed
        all_to_all dispatch) -> maintain (rolling per-shard decay) ->
        publish -> cadence snapshot.  The watchdog observes the step
        duration outside the lock; escalation checkpoints immediately.

        Fault ladder (DESIGN.md §12): transient IO/dispatch faults retry
        under ``cfg.retry`` (capped exponential backoff + jitter);
        persistent faults and exhausted budgets escalate — the write path
        poisons (readers keep serving the last published epoch, writes
        raise :class:`EngineWriteUnavailable` until ``restore()`` heals)
        and a best-effort checkpoint-now captures what is already
        consistent.  ``_seq`` only advances once the batch is both durable
        AND applied, so a mid-step fault can never leave the WAL position
        pointing past unapplied state.  Cadence-snapshot failures are
        counted, never raised: a lost snapshot costs replay time, not
        correctness.
        """
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        w = (np.ones(src.shape, np.int32) if weights is None
             else np.asarray(weights, np.int32))
        t0 = time.monotonic()
        with self.metrics.span("engine.observe", items=int(src.size)):
            with self._write_lock:
                if self._poisoned is not None:
                    raise EngineWriteUnavailable(self._poisoned)
                if self.wal is not None:
                    seq = self._append_wal_locked(src, dst, w)
                    if self.wal.io_errors:
                        with self._stats_lock:
                            self.stats["wal_errors"] = self.wal.io_errors
                else:
                    seq = self._seq + 1
                self._apply_with_retry_locked(src, dst, w)
                self._seq = seq
                every = self.cfg.snapshot_every
                if (every and self.cfg.snapshot_dir
                        and (self._seq + 1) % every == 0):
                    try:
                        self._snapshot_locked(sync=False)
                    except Exception:
                        with self._stats_lock:
                            self.stats["snapshot_failures"] += 1
        if self.watchdog is not None:
            self.watchdog.observe(time.monotonic() - t0)

    def _count_retry(self, key: str):
        """An ``on_retry`` hook that tallies backoff rounds into stats."""
        def bump(attempt, exc):
            with self._stats_lock:
                self.stats[key] += 1
        return bump

    def stats_snapshot(self) -> Dict[str, int]:
        """One consistent image of every stats surface (satellite of
        DESIGN.md §13): the host counters AND the device ``counter_stats``
        sums are copied under a single ``_stats_lock`` hold (they commit
        together in ``_apply_locked``, so the copy can never capture a
        half-applied batch — no ``route_retried > route_dropped``-style
        impossible states), then the health map's and WAL's own counters
        overlay.  This is the registry provider — the metrics endpoint,
        ``serve.py``'s stats line and tests all read this one method."""
        # health/WAL counters are read OUTSIDE _stats_lock: _apply_locked
        # nests health._mu inside _stats_lock, so nesting them here in the
        # opposite order would be a lock cycle
        health = self.health.stats()
        wal_errors = self.wal.io_errors if self.wal is not None else None
        with self._stats_lock:
            out = dict(self.stats)
        out.update(health)
        if wal_errors is not None:
            out["wal_errors"] = wal_errors
        return out

    def _record_traffic(self, src: np.ndarray) -> None:
        """Armed-only per-bucket/per-shard tally of a dispatched batch.
        Mirrors the routing hash host-side; inactive (-1) padding never
        counts."""
        active = np.asarray(src)
        active = active[active >= 0]
        if active.size == 0:
            return
        own = self.cfg.sharded.resolved_ownership()
        buckets = _bucket_of_np(active, own.num_buckets)
        counts = np.bincount(buckets, minlength=own.num_buckets)
        self.metrics.vector_add("bucket_traffic", counts)
        assign = np.asarray(own.resolved_assignment(), np.int64)
        self.metrics.vector_add(
            "shard_traffic",
            np.bincount(assign[buckets],
                        minlength=self.cfg.sharded.num_shards))

    def _record_dispatch_failure(self, exc: BaseException) -> None:
        """Strike the owning shard when an escalated dispatch fault names
        one (a :class:`ShardDispatchError` anywhere in the cause chain —
        per-shard RPC timeout, lost device).  After ``health_strikes``
        consecutive escalations the shard goes down automatically: reads
        mask it, writes defer — the same state ``mark_shard_down``
        reaches administratively.  Unattributable faults strike nobody
        (one bad dispatch says nothing about WHICH shard is sick)."""
        shard = shard_from_exception(exc)
        if shard is None or not 0 <= shard < self.cfg.sharded.num_shards:
            return
        if self.health.record_failure(shard):
            with self._stats_lock:
                self.stats["shards_down"] = \
                    self.health.stats()["shards_down"]
            # flight-recorder incident (armed-only): a shard just struck
            # out — snapshot the spans + metric deltas that led here
            self.metrics.incident("strike_out", shard=shard,
                                  error=repr(exc))

    @requires_lock("_write_lock")
    def _append_wal_locked(self, src, dst, w) -> int:
        """Durably log one batch under the retry ladder.

        On escalation (persistent errno or exhausted budget) nothing is
        durable and nothing was applied — the engine state is still
        consistent, so poison the write path (checkpoint-now inside) and
        surface :class:`EngineWriteUnavailable` to the caller."""
        try:
            return call_with_retry(
                lambda: self.wal.append(src, dst, w),
                policy=self.cfg.retry,
                on_retry=self._count_retry("wal_retries"),
                metrics=self.metrics)
        except Exception as exc:
            self._poison_locked(f"WAL append failed: {exc!r}")
            raise EngineWriteUnavailable(
                f"write path poisoned: WAL append failed: {exc!r}") from exc

    @requires_lock("_write_lock")
    def _apply_with_retry_locked(self, src, dst, w) -> None:
        """Dispatch one batch under the retry ladder.

        ``_apply_locked`` commits nothing host-side until its publish
        succeeds, so re-invoking it after a fault re-runs an identical
        plan.  Exhausted WITH a WAL, the batch is durable but unapplied —
        letting callers continue would fork the chain from its own log,
        so poison; ``restore()`` replays the ghost record and heals.
        Without a WAL the state is simply unchanged: re-raise."""
        try:
            call_with_retry(
                lambda: self._apply_locked(src, dst, w),
                policy=self.cfg.retry,
                on_retry=self._count_retry("apply_retries"),
                metrics=self.metrics)
            self.health.record_success_all()
        except Exception as exc:
            self._record_dispatch_failure(exc)
            if self.wal is not None:
                self._poison_locked(
                    f"apply failed after durable append: {exc!r}")
                raise EngineWriteUnavailable(
                    f"write path poisoned: apply failed: {exc!r}") from exc
            raise

    @requires_lock("_write_lock")
    def _poison_locked(self, reason: str) -> None:
        """Escalation terminus for write-path faults (A13): writes raise
        until ``restore()`` heals, readers keep serving the last published
        epoch, and a best-effort checkpoint-now preserves everything that
        is already consistent (its failure is counted, not raised — the
        disk that poisoned us is likely still broken)."""
        self._poisoned = reason
        with self._stats_lock:
            self.stats["write_errors"] += 1
        # flight-recorder incident (armed-only): the write path just died;
        # dump the spans + metric deltas leading up to the poison BEFORE
        # the best-effort checkpoint below touches the broken disk
        self.metrics.incident("poison", why=reason)
        if self.cfg.snapshot_dir:
            try:
                self._snapshot_locked(sync=False)
            except Exception:
                with self._stats_lock:
                    self.stats["snapshot_failures"] += 1

    @property
    def write_available(self) -> bool:
        """False while the write path is poisoned (reads still serve)."""
        return self._poisoned is None

    def _drain_plan(self, queue):
        """FIFO split of the retry queue into ``(drained, remaining)``
        chunk lists, taking at most ``route_retry_slice`` items.  Pure —
        the caller commits the remainder only after its dispatch succeeds,
        so a retried dispatch re-plans identically."""
        take, rest = [], []
        room = max(1, self.cfg.route_retry_slice)
        for chunk in queue:
            size = int(chunk[0].size)
            if room >= size:
                take.append(chunk)
                room -= size
            elif room > 0:
                take.append(tuple(a[:room] for a in chunk))
                rest.append(tuple(a[room:] for a in chunk))
                room = 0
            else:
                rest.append(chunk)
        return take, rest

    @requires_lock("_write_lock")
    def _apply_locked(self, src, dst, w) -> None:
        """One learner cycle against the published state (caller holds the
        write lock).  Shared verbatim by observe(), WAL replay and
        heal_shard() — the recovery determinism contract is 'same batches
        through the same pipeline', so there must only be one pipeline.

        Failure atomicity: every host-side plan (retry-queue drain,
        down-shard deferral, overflow prediction) is computed into locals
        and committed only after the publish succeeds, so a raising
        dispatch leaves the queue, the health map and the published state
        exactly as they were — the caller's retry re-runs an identical
        plan, and a non-retried fault changes nothing.
        """
        scfg = self.cfg.sharded
        n_shards = scfg.num_shards
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        w = np.asarray(w, np.int32).reshape(-1)
        tries = np.zeros(src.shape, np.int32)
        budget = self.cfg.route_retry_budget
        remaining = self._retry_queue
        if budget > 0 and remaining:
            drained, remaining = self._drain_plan(remaining)
            src = np.concatenate([src] + [c[0] for c in drained])
            dst = np.concatenate([dst] + [c[1] for c in drained])
            w = np.concatenate([w] + [c[2] for c in drained])
            tries = np.concatenate([tries] + [c[3] for c in drained])
        defer_plan, lost_down = [], 0
        down = self.health.down
        if down:
            owner = np.asarray(scfg.resolved_ownership().owner_of(
                jnp.asarray(src, jnp.int32)))
            hit = np.isin(owner, list(down)) & (src >= 0)
            if hit.any():
                for s_id in sorted(int(x) for x in set(owner[hit])):
                    sel = hit & (owner == s_id)
                    defer_plan.append((s_id, src[sel].copy(),
                                       dst[sel].copy(), w[sel].copy()))
                src = np.where(hit, -1, src).astype(np.int32)
                dst = np.where(hit, 0, dst).astype(np.int32)
                w = np.where(hit, 0, w).astype(np.int32)
        pad = (-src.size) % n_shards
        if pad:
            src = np.concatenate([src, np.full(pad, -1, np.int32)])
            dst = np.concatenate([dst, np.zeros(pad, np.int32)])
            w = np.concatenate([w, np.zeros(pad, np.int32)])
            tries = np.concatenate([tries, np.zeros(pad, np.int32)])
        requeue, retried, lost_skew = None, 0, 0
        if budget > 0:
            drop = sh.predict_route_overflow(scfg, src)
            if drop.any():
                again = drop & (tries < budget)
                dead = drop & ~again
                retried = int(again.sum())
                lost_skew = int(dead.sum())
                if retried:
                    requeue = (src[again].copy(), dst[again].copy(),
                               w[again].copy(), tries[again] + 1)
                src = np.where(drop, -1, src).astype(np.int32)
                dst = np.where(drop, 0, dst).astype(np.int32)
                w = np.where(drop, 0, w).astype(np.int32)
        failpoint("engine.apply", items=int(src.size))
        with self.metrics.span("engine.apply"):
            snap = self.store.acquire()
            try:
                state = self._update(snap.state, jnp.asarray(src),
                                     jnp.asarray(dst), jnp.asarray(w))
                state = self._maintain(state)
            finally:
                self.store.release(snap)
            failpoint("engine.publish")
            self.store.publish(state)
        self.metrics.gauge_set("store_version", self.store.version)
        if obs_metrics.is_armed():
            # per-virtual-bucket / per-shard traffic tally of the batch
            # that actually dispatched (the ROADMAP rebalancer's input)
            self._record_traffic(src)
        # the dispatch succeeded: commit the host-side plans
        if budget > 0:
            self._retry_queue = remaining + (
                [requeue] if requeue is not None else [])
        deferred = 0
        for s_id, qsrc, qdst, qw in defer_plan:
            if self.health.defer(s_id, qsrc, qdst, qw):
                deferred += int(qsrc.size)
            else:
                lost_down += int(qsrc.size)
        counters = mc.counter_stats(state)
        with self._stats_lock:
            self.stats["updates"] += 1
            self.stats.update(counters)
            if retried:
                self.stats["route_retried"] += retried
            if lost_skew or lost_down:
                self.stats["route_lost"] += lost_skew + lost_down
            if deferred or down:
                health = self.health.stats()
                self.stats["deferred_writes"] = health["deferred_writes"]
                self.stats["shards_down"] = health["shards_down"]

    # ------------------------------------------------------------------
    def query(self, src, threshold: Optional[float] = None,
              max_items: Optional[int] = None):
        """Per-src cumulative-threshold read (the paper's §II.B query),
        answered by the owner shards.  Returns ``(dsts[B, k], probs[B, k],
        n_needed[B])``; routing drops land in ``stats['query_dropped']``.

        Degraded reads (DESIGN.md §12): items owned by a down shard are
        masked out before dispatch and answered empty (counted in
        ``degraded_answers``); a faulting dispatch retries under
        ``cfg.retry`` and, exhausted, the whole call degrades to empty
        answers instead of failing the read path.  With
        ``query_retry_budget > 0``, items the router would drop for skew
        re-dispatch against the same snapshot (spread round-robin across
        sender slices, so each round shrinks the per-slice owner groups);
        items still dropped after the budget count into ``query_lost``.
        """
        t = float(self.cfg.threshold if threshold is None else threshold)
        k = int(self.cfg.max_items if max_items is None else max_items)
        span = self.metrics.span("engine.query")
        with span:
            with self._route_lock:   # pair the program with its snapshot
                fn = self._cached_fn(
                    self._query_fns, (t, k),
                    lambda: sh.make_query_fn(self.cfg.sharded, self.mesh,
                                             threshold=t, max_items=k))
                snap = self.store.acquire()
            # freshness gauge: how many epochs this read's snapshot lags
            # the latest publish — the quantitative handle on the paper's
            # "approximately correct during concurrent updates" semantics
            self.metrics.gauge_set("read_epoch_lag",
                                   self.store.version - snap.version)
            src = jnp.asarray(src, jnp.int32)
            src, b = self._pad(src)
            degraded = retried = lost = 0
            down = self.health.down
            if down:
                src_np = np.asarray(src)
                owner = np.asarray(self.cfg.sharded.resolved_ownership()
                                   .owner_of(jnp.asarray(src_np)))
                hit = np.isin(owner, list(down)) & (src_np >= 0)
                if hit.any():
                    degraded = int(hit[:b].sum())
                    src = jnp.asarray(
                        np.where(hit, -1, src_np).astype(np.int32))
            try:
                try:
                    d, p, n, dropped = call_with_retry(
                        lambda: self._dispatch_query(fn, snap, src),
                        policy=self.cfg.retry,
                        on_retry=self._count_retry("dispatch_retries"),
                        metrics=self.metrics)
                    n_dropped = int(jnp.sum(dropped))
                    self.health.record_success_all()
                except Exception as exc:
                    # the read path never raises for dispatch faults: the
                    # whole call degrades to empty answers from zero shards
                    # (counted) — still sorted-descending, trivially.  A
                    # shard-attributable fault strikes its shard: after
                    # health_strikes consecutive escalations it goes down
                    # and later reads degrade without paying the dispatch.
                    self._record_dispatch_failure(exc)
                    bpad = int(np.asarray(src).shape[0])
                    d = jnp.full((bpad, k), -1, jnp.int32)
                    p = jnp.zeros((bpad, k), jnp.float32)
                    n = jnp.zeros((bpad,), jnp.int32)
                    n_dropped = 0
                    degraded = b
                    self.metrics.incident("degraded_read", op="query",
                                          error=repr(exc))
                if self.cfg.query_retry_budget > 0 and n_dropped:
                    d, p, n, retried, lost = self._query_overflow_retry(
                        fn, snap, src, b, d, p, n)
            finally:
                self.store.release(snap)
            with self._stats_lock:
                self.stats["queries"] += 1
                self.stats["query_dropped"] += n_dropped
                if degraded:
                    self.stats["degraded_answers"] += degraded
                if retried:
                    self.stats["query_retried"] += retried
                if lost:
                    self.stats["query_lost"] += lost
            return d[:b], p[:b], n[:b]

    def _dispatch_query(self, fn, snap, src):
        """Single routed query dispatch; the failpoint sits inside so a
        retry round re-traverses it (nth-hit triggers model transients)."""
        failpoint("engine.query_dispatch", items=int(src.shape[0]))
        return fn(snap.state, src)

    def _query_overflow_retry(self, fn, snap, src, b, d, p, n):
        """In-call overflow retry: re-dispatch the items the router would
        drop for skew against the SAME snapshot.  Retry item j lands at
        slice ``j % S``, slot ``j // S`` — round-robin across sender
        slices, so every round splits the over-capacity owner groups.
        Returns merged ``(d, p, n, retried, lost)``."""
        scfg = self.cfg.sharded
        n_shards = scfg.num_shards
        src_np = np.asarray(src)
        total = src_np.size
        local = total // n_shards
        d_np, p_np, n_np = (np.asarray(d).copy(), np.asarray(p).copy(),
                            np.asarray(n).copy())
        drop = sh.predict_route_overflow(scfg, src_np)
        drop[b:] = False
        retried = 0
        rounds = self.cfg.query_retry_budget
        while rounds > 0 and drop.any():
            idx = np.flatnonzero(drop)
            j = np.arange(idx.size)
            pos = (j % n_shards) * local + (j // n_shards)
            retry_src = np.full(total, -1, np.int32)
            retry_src[pos] = src_np[idx]
            try:
                rd, rp, rn, _ = call_with_retry(
                    lambda: self._dispatch_query(fn, snap,
                                                 jnp.asarray(retry_src)),
                    policy=self.cfg.retry,
                    on_retry=self._count_retry("dispatch_retries"),
                    metrics=self.metrics)
            except Exception as exc:
                self._record_dispatch_failure(exc)
                break   # keep what we have; the rest counts as lost
            retried += int(idx.size)
            rdrop = sh.predict_route_overflow(scfg, retry_src)
            ok = ~rdrop[pos]
            d_np[idx[ok]] = np.asarray(rd)[pos[ok]]
            p_np[idx[ok]] = np.asarray(rp)[pos[ok]]
            n_np[idx[ok]] = np.asarray(rn)[pos[ok]]
            drop = np.zeros_like(drop)
            drop[idx[~ok]] = True
            rounds -= 1
        return (jnp.asarray(d_np), jnp.asarray(p_np), jnp.asarray(n_np),
                retried, int(drop.sum()))

    # ------------------------------------------------------------------
    def topn(self, n: Optional[int] = None):
        """Globally descending top-n edges across every shard (the
        cross-shard merge read).  Returns ``(srcs[n], dsts[n], probs[n])``;
        candidates the shards could not expose are counted in
        ``stats['topn_dropped']`` (last call's value is kept — it is a
        property of the current state, not a running total).  Rows owned
        by down shards are filtered from the merge (degraded reads,
        DESIGN.md §12); a dispatch fault retries and, exhausted, the call
        degrades to an empty merge rather than raising."""
        n = int(self.cfg.topn if n is None else n)
        with self.metrics.span("engine.topn"):
            return self._topn_inner(n)

    def _topn_inner(self, n: int):
        with self._route_lock:   # pair the program with its snapshot
            fn = self._cached_fn(
                self._topn_fns, n,
                lambda: sh.make_topn_fn(self.cfg.sharded, self.mesh, n))
            snap = self.store.acquire()
        self.metrics.gauge_set("read_epoch_lag",
                               self.store.version - snap.version)
        degraded = 0
        try:
            try:
                srcs, dsts, probs, dropped = call_with_retry(
                    lambda: self._dispatch_topn(fn, snap),
                    policy=self.cfg.retry,
                    on_retry=self._count_retry("dispatch_retries"),
                    metrics=self.metrics)
                n_dropped = int(dropped)
                self.health.record_success_all()
            except Exception as exc:
                # read path never raises for dispatch faults: empty merge
                self._record_dispatch_failure(exc)
                srcs = jnp.full((n,), -1, jnp.int32)
                dsts = jnp.full((n,), -1, jnp.int32)
                probs = jnp.zeros((n,), jnp.float32)
                n_dropped = 0
                degraded = n
                self.metrics.incident("degraded_read", op="topn",
                                      error=repr(exc))
        finally:
            self.store.release(snap)
        down = self.health.down
        if down and not degraded:
            # degraded merge: filter rows owned by down shards out of the
            # answer (order among survivors preserved — still globally
            # descending), pad the tail with empties and count the holes
            s_np, d_np, p_np = (np.asarray(srcs), np.asarray(dsts),
                                np.asarray(probs))
            owner = np.asarray(self.cfg.sharded.resolved_ownership()
                               .owner_of(jnp.asarray(s_np)))
            hit = np.isin(owner, list(down)) & (s_np >= 0)
            if hit.any():
                degraded = int(hit.sum())
                keep = ~hit
                kept = int(keep.sum())
                out_s = np.full_like(s_np, -1)
                out_d = np.full_like(d_np, -1)
                out_p = np.zeros_like(p_np)
                out_s[:kept] = s_np[keep]
                out_d[:kept] = d_np[keep]
                out_p[:kept] = p_np[keep]
                srcs, dsts, probs = (jnp.asarray(out_s), jnp.asarray(out_d),
                                     jnp.asarray(out_p))
        with self._stats_lock:
            self.stats["topn_calls"] += 1
            self.stats["topn_dropped"] = n_dropped
            if degraded:
                self.stats["degraded_answers"] += degraded
        return srcs, dsts, probs

    def _dispatch_topn(self, fn, snap):
        """Single cross-shard merge dispatch (failpoint inside: retries
        re-traverse it)."""
        failpoint("engine.topn_dispatch")
        return fn(snap.state)

    # ------------------------------------------------------------------
    # durability & elasticity (DESIGN.md §10)
    # ------------------------------------------------------------------

    def checkpoint(self, step: Optional[int] = None, sync: bool = True) -> str:
        """Snapshot the published chain inside the writer-lock publish cycle.

        The captured state is always a published epoch (immutable pytree)
        and ``wal_seq`` is captured under the same lock, so snapshot and
        log position can never disagree.  ``sync=False`` runs the file IO
        on a worker thread (the device->host gather still happens here).
        """
        if not self.cfg.snapshot_dir:
            raise ValueError("ShardedServeConfig.snapshot_dir not set")
        with self._write_lock:
            return self._snapshot_locked(step=step, sync=sync)

    @requires_lock("_write_lock")
    def _snapshot_locked(self, step: Optional[int] = None,
                         sync: bool = True) -> str:
        scfg = self.cfg.sharded
        own = scfg.resolved_ownership()
        wal_seq = self._seq
        step = wal_seq + 1 if step is None else step
        meta = {
            "wal_seq": wal_seq,
            "num_shards": scfg.num_shards,
            "bucket_factor": scfg.bucket_factor,
            "ownership": {"num_buckets": own.num_buckets,
                          "assignment": list(own.resolved_assignment())},
            "base_cfg": dataclasses.asdict(scfg.base),
            "store_version": self.store.version,
            # the overflow-retry carry-over is part of the recovery state:
            # replay determinism is 'same batches through the same
            # pipeline', and the pipeline's plan depends on the queue
            "retry_queue": [[c[0].tolist(), c[1].tolist(), c[2].tolist(),
                             c[3].tolist()] for c in self._retry_queue],
            # so is the health map (A15): the down-set and deferred queue
            # must survive the crash, because WAL GC below may unlink the
            # deferred batches' original records — after this commit the
            # snapshot meta is their only durable copy
            "health": self.health.dump(),
        }
        # WAL GC rides the snapshot cadence: once a snapshot at wal_seq is
        # COMMITTED (manifest renamed), every record with seq <= wal_seq is
        # redundant for recovery, so closed segments up to it are unlinked
        # (truncate_through is conservative and internally locked).  For the
        # async path the truncation must wait for the commit, not the
        # capture — it runs as the worker's completion callback.
        gc = (functools.partial(self.wal.truncate_through, wal_seq)
              if self.wal is not None else None)
        snap = self.store.acquire()
        try:
            if sync:
                path = snapshot_io.save_snapshot(
                    snap.state, self.cfg.snapshot_dir, step, meta,
                    metrics=self.metrics)
                if gc is not None:
                    gc()
            else:
                self._io_threads = [t for t in self._io_threads
                                    if t.is_alive()]
                self._io_threads.append(snapshot_io.save_snapshot_async(
                    snap.state, self.cfg.snapshot_dir, step, meta,
                    on_complete=gc, on_error=self._snapshot_io_error,
                    metrics=self.metrics))
                path = snapshot_io.step_dir(self.cfg.snapshot_dir, step)
        finally:
            self.store.release(snap)
        with self._stats_lock:
            self.stats["snapshots"] += 1
        return path

    def _snapshot_io_error(self, exc) -> None:
        """Worker-thread snapshot IO fault: count it and move on — the
        cadence retries at the next interval, and an aborted step directory
        is invisible to ``latest_complete_step``.  Without this hook the
        worker would die with only a stderr traceback (a silently dead IO
        thread that looks like progress)."""
        with self._stats_lock:
            self.stats["snapshot_failures"] += 1

    def _escalate_snapshot(self) -> None:
        # watchdog escalation fires outside the write lock (observe() calls
        # watchdog.observe after releasing it), so taking it here is safe
        self.checkpoint()

    # ------------------------------------------------------------------
    # graceful degradation (DESIGN.md §12)
    # ------------------------------------------------------------------

    def mark_shard_down(self, shard: int) -> None:
        """Administratively exclude ``shard``: routed reads mask its items
        (counted in ``degraded_answers``), its share of the top-n merge is
        filtered, and its writes defer (bounded by ``deferred_cap``) until
        :meth:`heal_shard` re-admits it.  The strike path
        (``health.record_failure``) reaches the same state automatically
        after ``health_strikes`` consecutive dispatch failures."""
        if not 0 <= shard < self.cfg.sharded.num_shards:
            raise ValueError(
                f"shard {shard} out of range for "
                f"{self.cfg.sharded.num_shards} shards")
        self.health.mark_down(shard)
        with self._stats_lock:
            self.stats["shards_down"] = self.health.stats()["shards_down"]

    def heal_shard(self, shard: int) -> int:
        """Re-admit ``shard`` and re-apply its deferred writes through the
        one observe pipeline.  Deferred batches are NOT re-logged: they
        are recovery state already — their original WAL records exist
        until snapshot GC, and every snapshot persists the health map
        (down-set + deferred queue) in its meta, which ``restore()``
        reinstates before replay — so heal-vs-crash never double-counts a
        batch (A15).  Each batch re-applies under the ``cfg.retry``
        ladder; if one still fails, the shard is re-marked down and the
        unapplied remainder (failed batch included) is requeued before
        the fault propagates — a mid-heal fault never drops writes.
        Returns the number of re-applied batches."""
        with self._write_lock:
            batches = self.health.heal(shard)
            done = 0
            try:
                for bsrc, bdst, bw in batches:
                    call_with_retry(
                        functools.partial(
                            self._apply_locked, bsrc, bdst,
                            bw if bw is not None else np.ones_like(bsrc)),
                        policy=self.cfg.retry,
                        on_retry=self._count_retry("apply_retries"),
                        metrics=self.metrics)
                    done += 1
            except Exception:
                self.health.mark_down(shard)
                self.health.requeue(shard, batches[done:])
                raise
            finally:
                health = self.health.stats()
                with self._stats_lock:
                    self.stats["shards_down"] = health["shards_down"]
                    self.stats["deferred_writes"] = health["deferred_writes"]
        return len(batches)

    def close(self) -> None:
        """Shutdown path: drain outstanding snapshot IO and close the WAL.

        Background snapshot workers are non-daemon threads, so even an
        unclosed engine cannot tear a committed snapshot at interpreter
        exit — but ``close()`` makes the drain explicit and bounded: it
        joins every outstanding worker (their completion callbacks, e.g.
        WAL truncation, included) and then flushes/fsyncs the open WAL
        segment.  Idempotent; the engine object must not be used after.
        """
        with self._write_lock:
            threads, self._io_threads = self._io_threads, []
        for t in threads:
            t.join()
        if self.wal is not None:
            self.wal.close()

    def __enter__(self) -> "ShardedEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def restore(self, step: Optional[int] = None, replay: bool = True) -> dict:
        """Recover from the newest complete snapshot (+ WAL replay).

        Same shard count: exact array restore — bit-identical state,
        including the ownership map (the engine rebinds its routing
        programs if the snapshot's assignment differs).  Different shard
        count: elastic reshard — the snapshot's live edges re-route
        through the pre-aggregated update path under this engine's
        ownership map (``persist/reshard.py``), then the order settles
        exactly.  Either way, WAL records with ``seq > wal_seq`` replay
        through the one observe pipeline.  A successful restore also
        heals a poisoned write path (DESIGN.md §12): durable-but-unapplied
        ghost records are replayed here, re-aligning log and state.
        """
        directory = self.cfg.snapshot_dir
        if not directory:
            raise ValueError("ShardedServeConfig.snapshot_dir not set")
        # drain in-flight cadence/poison checkpoints first: the newest
        # snapshot may still be committing on a worker thread (a poison's
        # best-effort checkpoint-now races an immediate restore), and
        # latest_complete_step must not scan past it
        with self._write_lock:
            pending, self._io_threads = self._io_threads, []
        for t in pending:
            t.join()
        if step is None:
            step = snapshot_io.latest_complete_step(directory)
            if step is None:
                raise FileNotFoundError(
                    f"no complete snapshot under {directory}")
        meta = snapshot_io.load_meta(directory, step)
        base_old = mc.MCConfig(**meta["base_cfg"])
        n_old = int(meta["num_shards"])
        replayed = 0
        # one write-lock hold end to end: a concurrent observe() slipping
        # between publish and replay would be WAL-appended AND re-read by
        # the replay generator — applied twice
        with self._write_lock:
            scfg = self.cfg.sharded
            new_scfg = None
            if n_old == scfg.num_shards:
                mode = "exact"
                snap_own = Ownership(
                    num_shards=n_old,
                    num_buckets=int(meta["ownership"]["num_buckets"]),
                    assignment=tuple(meta["ownership"]["assignment"]))
                own_now = scfg.resolved_ownership()
                if (snap_own.resolved_assignment()
                        != own_now.resolved_assignment()
                        or dataclasses.asdict(base_old)
                        != dataclasses.asdict(scfg.base)):
                    # rows live where the snapshot's map routed them; the
                    # engine must route future traffic the same way
                    new_scfg = dataclasses.replace(
                        scfg, base=base_old, ownership=snap_own)
                like = self._stacked_like(base_old, n_old)
                shardings = jax.tree_util.tree_map(
                    lambda _: NamedSharding(self.mesh, P(scfg.axis)), like)
                state, _, _ = snapshot_io.restore_snapshot(
                    like, directory, step, shardings,
                    metrics=self.metrics)
            else:
                mode = "reshard"
                like = self._stacked_like(base_old, n_old)
                old_state, _, _ = snapshot_io.restore_snapshot(
                    like, directory, step, metrics=self.metrics)
                state = self._reingest(old_state, scfg)
            # swap: readers must never pair the new routing with the old
            # snapshot (or vice versa), so rebind + publish are atomic
            # with respect to their (program, snapshot) pairing
            with self._route_lock:
                if new_scfg is not None:
                    self._rebind(new_scfg)
                self.store.publish(state)
            self._seq = int(meta["wal_seq"])
            # the overflow-retry carry-over is recovery state: the replay
            # below re-plans each step from the same queue the pre-crash
            # pipeline saw (snapshots from older builds simply have none)
            self._retry_queue = [
                tuple(np.asarray(a, np.int32) for a in chunk)
                for chunk in meta.get("retry_queue", [])]
            # so is the health map (A15): the snapshot's down-set and
            # deferred queue replace the live one BEFORE replay — an
            # in-process restore must not replay down-shard records on
            # top of deferrals the snapshot already captured (that would
            # double-apply them on heal), and the deferred batches'
            # original WAL records may be GC'd, so the meta image is
            # authoritative.  Replayed tail records owned by a restored
            # down shard re-defer exactly as they did pre-crash.
            health_image = meta.get("health", {})
            self.health.load(health_image if mode == "exact" else {})
            hstats = self.health.stats()
            with self._stats_lock:
                self.stats.update(mc.counter_stats(state))
                self.stats["shards_down"] = hstats["shards_down"]
                self.stats["deferred_writes"] = hstats["deferred_writes"]
            if mode != "exact":
                # reshard: old shard ids are meaningless under the new
                # topology — start healthy (loaded empty above) and fold
                # the snapshot's deferred batches straight into the state
                # (they precede every tail record in seq order)
                for _, dsrc, ddst, dw in health_image.get("deferred", ()):
                    dsrc = np.asarray(dsrc, np.int32)
                    self._apply_locked(
                        dsrc, np.asarray(ddst, np.int32),
                        np.ones_like(dsrc) if dw is None
                        else np.asarray(dw, np.int32))
            if replay and self.wal is not None:
                for seq, src, dst, w in self.wal.replay(
                        after_seq=self._seq):
                    # apply BEFORE advancing: a fault mid-replay must not
                    # leave _seq past unapplied records (same contract as
                    # observe)
                    self._apply_locked(src, dst, w)
                    self._seq = seq
                    replayed += 1
            if self.wal is not None:
                # snapshot GC may have unlinked every segment: a fresh
                # process's WAL scan then restarts at 0, colliding with
                # seqs the snapshot covers — the meta wal_seq is the
                # durable authority
                self.wal.resume_at(self._seq + 1)
            # restore is the escalation ladder's terminus: snapshot + log
            # agree with the published state again, so writes re-open
            self._poisoned = None
        return {"step": step, "mode": mode, "replayed": replayed,
                "wal_seq": self._seq}

    def reassign(self, ownership: Ownership) -> dict:
        """Live rebalancing: install a new bucket -> shard assignment and
        migrate by re-routing the live edges — the same machinery as
        elastic restore, at a constant shard count (ROADMAP "cross-shard
        rebalancing").  Readers keep serving the pre-migration snapshot
        until the re-ingested state publishes."""
        scfg = self.cfg.sharded
        if ownership.num_shards != scfg.num_shards:
            raise ValueError(
                f"reassign keeps the shard count: map has "
                f"{ownership.num_shards}, engine has {scfg.num_shards}")
        new_scfg = dataclasses.replace(scfg, ownership=ownership)
        with self._write_lock:
            snap = self.store.acquire()
            try:
                old_state = jax.device_get(snap.state)
            finally:
                self.store.release(snap)
            # migrate FIRST, against local programs for the new map;
            # readers keep pairing the old routing with the old snapshot
            # until the atomic swap below
            state = self._reingest(old_state, new_scfg)
            with self._route_lock:
                self._rebind(new_scfg)
                self.store.publish(state)
            with self._stats_lock:
                self.stats.update(mc.counter_stats(state))
        return {"num_buckets": ownership.num_buckets,
                "version": self.store.version}

    # -- internals ------------------------------------------------------

    @requires_lock("_route_lock")
    def _rebind(self, scfg: sh.ShardedConfig) -> None:
        """Swap the static sharded config and rebuild every routed program
        (ownership/base changes are baked into them as constants)."""
        self.cfg = dataclasses.replace(self.cfg, sharded=scfg)
        self._update = sh.make_update_fn(scfg, self.mesh)
        self._maintain = sh.make_maintain_fn(
            scfg, self.mesh, total_threshold=self.cfg.decay_threshold)
        with self._compile_lock:
            self._query_fns.clear()
            self._topn_fns.clear()

    def _stacked_like(self, base: mc.MCConfig, num_shards: int):
        """Host-side template with the stacked [num_shards, ...] shapes a
        snapshot at that config was written with."""
        one = mc.init(base)
        return jax.tree_util.tree_map(
            lambda x: np.broadcast_to(np.asarray(x)[None],
                                      (num_shards,) + x.shape), one)

    def _reingest(self, old_state: mc.MCState,
                  scfg: sh.ShardedConfig) -> mc.MCState:
        """Re-route a state's live edges into a fresh chain under
        ``scfg``'s ownership map, through the routed pre-aggregated update
        path, with drop-free batch planning; settle the order exactly.
        Builds its own programs — deliberately independent of the
        engine's installed routing, so callers can migrate before
        swapping."""
        src, dst, cnt = rs.extract_edges(old_state)
        owner = np.asarray(
            scfg.resolved_ownership().owner_of(jnp.asarray(src)))
        slice_len = max(scfg.num_shards, self.cfg.reingest_slice_len)
        cap = scfg.bucket_capacity(slice_len)
        # every re-ingested item is a new edge; a bounded slow path would
        # defer (= silently drop) everything past the prefix, so ingestion
        # gets its own program with the bound lifted (shapes are identical)
        ingest_scfg = dataclasses.replace(
            scfg, base=dataclasses.replace(scfg.base, max_new_per_batch=0))
        ingest = sh.make_update_fn(ingest_scfg, self.mesh)
        state = sh.init_sharded(scfg, self.mesh)
        for bsrc, bdst, bw in rs.plan_batches(
                src, dst, cnt, owner, scfg.num_shards, slice_len, cap):
            state = ingest(state, jnp.asarray(bsrc),
                           jnp.asarray(bdst), jnp.asarray(bw))
        state = rs.settle_order(state)
        sharding = NamedSharding(self.mesh, P(scfg.axis))
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, sharding), state)
