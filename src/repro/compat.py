"""Version-portability shims for JAX APIs that moved between releases.

The repo targets current JAX (``jax.shard_map``, ``jax.sharding.AxisType``,
``check_vma``), but the container pins an older release where ``shard_map``
still lives in ``jax.experimental`` (with ``check_rep`` instead of
``check_vma``) and ``make_mesh`` has no ``axis_types`` parameter.  Every
mesh/shard_map construction in the repo goes through these two wrappers so
the version split lives in exactly one file.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # jax >= 0.6
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None

_HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit ``Auto`` axis types where supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AxisType is not None:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any JAX version.

    All call sites in this repo disable the check (``check_vma=False`` /
    ``check_rep=False``): the collectives inside are hand-written and the
    checker rejects valid manual patterns like the all_to_all routing.
    """
    if _HAS_JAX_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any JAX version.

    Older releases return a one-element list of per-computation dicts;
    newer ones return the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca
