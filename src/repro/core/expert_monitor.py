"""Expert-popularity monitor: MCPrioQ tracking MoE router decisions online.

The (layer -> expert) choice stream is itself a sparse Markov-ish counter
workload — exactly the paper's structure (DESIGN.md §Arch-applicability):
src nodes are layer ids, dst nodes are expert ids, the counter is the
routing frequency.  The EP load-balance monitor then asks the paper's
query: "which experts serve a cumulative ``t`` of this layer's traffic?" —
few experts at high t == imbalance; decay (§II.C) keeps the view fresh as
routing drifts during training.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import mcprioq as mc


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    num_layers: int
    num_experts: int
    sort_passes: int = 2
    decay_threshold: int = 1 << 20

    def mc_config(self) -> mc.MCConfig:
        cap = 1
        while cap < self.num_experts:
            cap *= 2
        return mc.MCConfig(num_rows=max(2 * self.num_layers, 8),
                           capacity=cap, sort_passes=self.sort_passes)


def init(cfg: MonitorConfig) -> mc.MCState:
    return mc.init(cfg.mc_config())


def observe(state: mc.MCState, layer: int, expert_counts: jax.Array,
            cfg: MonitorConfig) -> mc.MCState:
    """Fold one layer's router histogram (aux['moe_expert_counts']) in."""
    e = cfg.num_experts
    src = jnp.full((e,), layer, jnp.int32)
    dst = jnp.arange(e, dtype=jnp.int32)
    state = mc.update_batch(state, src, dst,
                            weights=expert_counts.astype(jnp.int32),
                            mask=expert_counts > 0, cfg=cfg.mc_config())
    return mc.maybe_decay(state, cfg=cfg.mc_config(),
                          total_threshold=cfg.decay_threshold)


def hot_experts(state: mc.MCState, layer: int, t: float,
                cfg: MonitorConfig) -> Tuple[jax.Array, jax.Array, int]:
    """Experts carrying cumulative traffic >= t for a layer, hottest first.
    Returns (expert_ids, load_fractions, n_needed) — n_needed close to
    num_experts*t means balanced routing; small n_needed flags collapse."""
    dsts, probs, n = mc.query_threshold(
        state, jnp.asarray([layer], jnp.int32), t,
        cfg=cfg.mc_config(), max_items=cfg.num_experts)
    return dsts[0], probs[0], int(n[0])


def balance_report(state: mc.MCState, cfg: MonitorConfig,
                   t: float = 0.9) -> Dict[int, int]:
    """n_needed per layer at threshold t (the imbalance dashboard)."""
    out = {}
    for layer in range(cfg.num_layers):
        _, _, n = hot_experts(state, layer, t, cfg)
        out[layer] = n
    return out
