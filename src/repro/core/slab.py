"""Edge slabs: the TPU-native form of the paper's sorted doubly-linked list.

Key adaptation (DESIGN.md §2): in the paper, the dst hash table points at
*list nodes*, and a bubble swap re-links the nodes without moving them — so
pointers stay valid.  In array land, position is identity, so instead we keep
edge *slots* stable (``dst``/``cnt`` never move once allocated) and maintain a
separate permutation ``order[r, :]`` listing slot ids in (approximately)
descending count order.  The paper's lock-free adjacent-node swap becomes a
vectorised **odd-even transposition pass over the permutation** — one
compare-exchange on even-aligned pairs, one on odd-aligned pairs.  Slots never
move, so slot references (the optional dst hash) survive every swap, exactly
like the paper's pointers survive an RCU swap.

Invariants (checked in tests):
  * ``cnt >= 0``;  ``cnt[r, s] == 0  <=>`` slot ``s`` of row ``r`` is free
    (``dst == EMPTY``).
  * ``order[r]`` is a permutation of ``range(C)`` at all times.
  * ``tot[r] == sum(cnt[r])`` after every public op.
  * k odd-even passes never increase the number of inversions.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.hashtable import EMPTY


class Slabs(NamedTuple):
    dst: jax.Array  # int32[N, C]  dst node-id per slot, EMPTY if free
    cnt: jax.Array  # int32[N, C]  transition counter per slot (0 == free)
    tot: jax.Array  # int32[N]     per-row total transitions (paper's 2nd counter)
    order: jax.Array  # int32[N, C] slot ids, approx. descending by cnt


def make(num_rows: int, capacity: int) -> Slabs:
    return Slabs(
        dst=jnp.full((num_rows, capacity), EMPTY, dtype=jnp.int32),
        cnt=jnp.zeros((num_rows, capacity), dtype=jnp.int32),
        tot=jnp.zeros((num_rows,), dtype=jnp.int32),
        order=jnp.broadcast_to(
            jnp.arange(capacity, dtype=jnp.int32), (num_rows, capacity)
        ),
    )


# ---------------------------------------------------------------------------
# odd-even transposition: the lock-free bubble sort of the paper, vectorised
# ---------------------------------------------------------------------------


def _half_pass(cnt: jax.Array, order: jax.Array, start: int) -> jax.Array:
    """One compare-exchange sweep over pairs (start, start+1), (start+2, ...).

    Descending order target: swap when left < right. Operates on the
    permutation only; the slabs themselves never move (stable slots).
    """
    c = jnp.take_along_axis(cnt, order, axis=1)
    left_o = order[:, start:-1:2]
    right_o = order[:, start + 1 :: 2]
    # align shapes (odd start on even C leaves a trailing unpaired element)
    m = min(left_o.shape[1], right_o.shape[1])
    left_o, right_o = left_o[:, :m], right_o[:, :m]
    left_c = c[:, start:-1:2][:, :m]
    right_c = c[:, start + 1 :: 2][:, :m]
    swap = left_c < right_c
    new_left = jnp.where(swap, right_o, left_o)
    new_right = jnp.where(swap, left_o, right_o)
    order = order.at[:, start : start + 2 * m : 2].set(new_left)
    order = order.at[:, start + 1 : start + 1 + 2 * m : 2].set(new_right)
    return order


def oddeven_passes(cnt: jax.Array, order: jax.Array, passes: int) -> jax.Array:
    """``passes`` full odd-even passes (each = even sweep + odd sweep).

    C passes sort fully; 1 pass fixes the "single small increment" case that
    the paper argues is the normal case.  Between passes the order is
    *approximately correct* — the paper's own reader-visible guarantee.
    """
    for _ in range(passes):
        order = _half_pass(cnt, order, 0)
        order = _half_pass(cnt, order, 1)
    return order


def full_sort(cnt: jax.Array, order: jax.Array) -> jax.Array:
    """Exact descending argsort (used by decay/compaction, not the hot path).

    Stable sort on -cnt keeps free slots (cnt 0) at the tail deterministically.
    """
    del order
    return jnp.argsort(-cnt, axis=1, stable=True).astype(jnp.int32)


def inversions(cnt: jax.Array, order: jax.Array) -> jax.Array:
    """Number of adjacent inversions per row (0 == perfectly sorted)."""
    c = jnp.take_along_axis(cnt, order, axis=1)
    return jnp.sum((c[:, :-1] < c[:, 1:]).astype(jnp.int32), axis=1)


def sorted_fraction(cnt: jax.Array, order: jax.Array) -> jax.Array:
    """Fraction of adjacent pairs in correct (non-increasing) order."""
    c = jnp.take_along_axis(cnt, order, axis=1)
    ok = (c[:, :-1] >= c[:, 1:]).astype(jnp.float32)
    return jnp.mean(ok)


# ---------------------------------------------------------------------------
# row-level find / allocate (vectorised over a batch of rows)
# ---------------------------------------------------------------------------


def find_slot(slabs: Slabs, row: jax.Array, dst: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Scan row ``row`` for ``dst``; returns ``(slot, found)``.

    O(C) work but a single vector compare — the paper's observation that "a
    hash table is hard to beat, but practically the choice may not be that
    obvious" (§II.2) is exactly this: on TPU a C-lane compare is one VPU op.
    """
    hits = slabs.dst[row] == dst
    slot = jnp.argmax(hits).astype(jnp.int32)
    return slot, jnp.any(hits)


def free_slot(slabs: Slabs, row: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """First free slot (cnt == 0) of ``row``; ``(slot, has_free)``."""
    free = slabs.cnt[row] == 0
    slot = jnp.argmax(free).astype(jnp.int32)
    return slot, jnp.any(free)


def tail_slot(slabs: Slabs, row: jax.Array) -> jax.Array:
    """Slot currently holding the (approximate) minimum count: order tail."""
    return slabs.order[row, -1]


# ---------------------------------------------------------------------------
# decay (paper §II.C): halve counters, evict zeros, compact via sort
# ---------------------------------------------------------------------------


def decay(slabs: Slabs) -> Tuple[Slabs, jax.Array]:
    """Multiply every counter by 0.5 (integer shift), evict cnt==0 edges.

    Semantic oracle for the fused kernel path (``ops.decay_sort``), which the
    hot path (``mcprioq.decay``) dispatches through — stop-the-world over the
    whole table or rolling over one ``decay_block_rows`` block per call
    (DESIGN.md §6).  Kept as the ground truth for equivalence tests.

    Returns ``(slabs, n_evicted)``.  ``tot`` is recomputed as the exact row sum
    so the two-counter probability stays consistent (the paper keeps the ratio
    invariant; integer halving of both sides does too, up to rounding — we
    re-sum to make it exact).  Compaction = one exact sort, putting the newly
    freed slots at the order tail where allocation finds them.
    """
    new_cnt = slabs.cnt >> 1
    died = (new_cnt == 0) & (slabs.dst != EMPTY)
    new_dst = jnp.where(new_cnt == 0, EMPTY, slabs.dst)
    new_tot = jnp.sum(new_cnt, axis=1).astype(slabs.tot.dtype)
    new_order = full_sort(new_cnt, slabs.order)
    return (
        Slabs(dst=new_dst, cnt=new_cnt, tot=new_tot, order=new_order),
        jnp.sum(died.astype(jnp.int32)),
    )
