"""Online n-gram drafter: MCPrioQ as a first-class LM serving feature.

The paper's target workload — "recommend items in descending probability until
cumulative probability >= t" — is precisely the draft-proposal problem of
speculative decoding: given the current context, propose the most probable
next tokens.  We maintain an MCPrioQ whose src nodes are rolling hashes of the
last ``n`` tokens and whose dst nodes are next tokens, learned *online* from
the very tokens the target model emits (continuous learning, §II.C decay keeps
it adaptive).  Drafting a chain of k tokens = k greedy top-1 queries; the
cumulative-threshold query supplies candidate *sets* for tree-style
verification.

This module is architecture-agnostic (DESIGN.md §Arch-applicability): it only
sees token streams.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import mcprioq as mc
from repro.core.hashtable import EMPTY
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class NGramConfig:
    order: int = 2                 # context length n
    mc: mc.MCConfig = mc.MCConfig(num_rows=8192, capacity=64, sort_passes=1)
    decay_threshold: int = 1 << 18


class DrafterState(NamedTuple):
    chain: mc.MCState


def init(cfg: NGramConfig) -> DrafterState:
    return DrafterState(chain=mc.init(cfg.mc))


def context_ids(tokens: jax.Array, order: int) -> jax.Array:
    """Rolling hash of the last ``order`` tokens at every position.

    tokens: int32[..., S] -> ctx: int32[..., S] where ctx[..., i] hashes
    tokens[..., i-order+1 : i+1].  Non-negative (top bit cleared) so ids are
    valid hash-table keys.
    """
    h = jnp.zeros_like(tokens, dtype=jnp.uint32)
    for k in range(order):
        # positions before the context window see rolled garbage; mask below
        h = ht.ctx_hash_fold(h, jnp.roll(tokens, k, axis=-1))
    idx = jnp.arange(tokens.shape[-1])
    valid = idx >= (order - 1)
    ctx = (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
    return jnp.where(valid, ctx, -1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def observe(state: DrafterState, tokens: jax.Array, *, cfg: NGramConfig) -> DrafterState:
    """Learn from a batch of token sequences. tokens: int32[B, S].

    Pure learning — §II.C maintenance lives in :func:`maintain` so the
    serving learner (``Engine._learn``) can trigger it explicitly behind the
    epoch store and surface the maintenance counters.
    """
    ctx = context_ids(tokens, cfg.order)        # [B, S]
    src = ctx[:, :-1].reshape(-1)
    dst = tokens[:, 1:].reshape(-1)
    chain = mc.update_batch(state.chain, src, dst, cfg=cfg.mc)
    return DrafterState(chain=chain)


@functools.partial(jax.jit, static_argnames=("cfg",))
def maintain(state: DrafterState, *, cfg: NGramConfig) -> DrafterState:
    """Learner-side §II.C maintenance: decay once any row total crosses
    ``cfg.decay_threshold``.  With ``cfg.mc.decay_block_rows`` set this is a
    rolling block halve (bounded per-call work) plus incremental dst-hash
    repair; stop-the-world otherwise."""
    chain = mc.maybe_decay(state.chain, cfg=cfg.mc,
                           total_threshold=cfg.decay_threshold)
    return DrafterState(chain=chain)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def draft(state: DrafterState, context: jax.Array, *, cfg: NGramConfig,
          k: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Greedy draft of k tokens per sequence — one kernel dispatch.

    context: int32[B, >=order] recent tokens.  Returns (draft[B, k],
    ok[B, k]) — ok False where the chain had no transition (caller stops
    speculation there).  The chain snapshot is immutable during a draft
    (EpochStore contract), so the whole k-step walk of (rolling hash ->
    src probe -> top-1 gather) runs as ONE fused dispatch
    (:func:`repro.kernels.ops.draft_walk`) instead of k round trips through
    lookup + gather + cdf_query; lanes whose walk dies stop doing work
    (token 0 / ok False thereafter).  :func:`draft_reference` keeps the
    k-dispatch scan as the semantic oracle.
    """
    chain = state.chain
    window = context[:, -cfg.order:]
    return ops.draft_walk(
        window, chain.src_table.keys, chain.src_table.vals,
        chain.slabs.cnt, chain.slabs.dst, chain.slabs.order[:, 0],
        k=k, max_probes=cfg.mc.max_probes, impl=cfg.mc.impl)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def draft_reference(state: DrafterState, context: jax.Array, *,
                    cfg: NGramConfig, k: int = 4
                    ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for :func:`draft`: the k-dispatch lax.scan over ``query_topk``
    (the pre-kernel shape of the walk), with the same dead-lane stop —
    a lane that fails emits token 0 / ok False for every later step.  Must
    match the walk kernel token-for-token."""
    order = cfg.order

    def step(carry, _):
        ctx_window, alive = carry             # ctx_window: int32[B, order]
        src = context_ids(ctx_window, order)[:, -1]
        dsts, probs = mc.query_topk(state.chain, src, cfg=cfg.mc, k=1)
        nxt = dsts[:, 0]
        ok = alive & (nxt != EMPTY) & (probs[:, 0] > 0)
        nxt = jnp.where(ok, nxt, 0)
        new_window = jnp.concatenate([ctx_window[:, 1:], nxt[:, None]], axis=1)
        return (new_window, ok), (nxt, ok)

    window = context[:, -order:]
    alive0 = jnp.ones((window.shape[0],), bool)
    _, (toks, oks) = jax.lax.scan(step, (window, alive0), None, length=k)
    return toks.T, oks.T


@functools.partial(jax.jit, static_argnames=("cfg", "max_items"))
def candidates(state: DrafterState, context: jax.Array, threshold: float,
               *, cfg: NGramConfig, max_items: int = 8):
    """Cumulative-probability candidate set for the next token — the paper's
    headline query, used for tree-style speculation or top-p style pruning."""
    src = context_ids(context[:, -cfg.order:], cfg.order)[:, -1]
    return mc.query_threshold(state.chain, src, threshold,
                              cfg=cfg.mc, max_items=max_items)


def acceptance_rate(draft_tokens: jax.Array, target_tokens: jax.Array,
                    ok: jax.Array) -> jax.Array:
    """Fraction of drafted tokens accepted by the target (prefix match)."""
    match = (draft_tokens == target_tokens) & ok
    accepted = jnp.cumprod(match.astype(jnp.int32), axis=1)
    return jnp.mean(jnp.sum(accepted, axis=1) / jnp.maximum(1, jnp.sum(ok, axis=1)))
