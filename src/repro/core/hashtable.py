"""Functional open-addressing hash table on JAX arrays.

TPU adaptation of the paper's RCU hash tables (DESIGN.md §2): there are no
pointers or CAS on a TPU, so the table is a pair of fixed-shape arrays
(``keys``, ``vals``) and every operation is a pure function
``table -> table``.  Linear probing with a bounded, *static* probe count makes
every lookup/insert a fixed-trip-count loop — the TPU-idiomatic reading of the
paper's "wait-free" guarantee (no retries, ever).

Sentinels: ``EMPTY = -1`` (never written), ``TOMB = -2`` (deleted; probe
continues through it, insert may reuse it). Keys must be non-negative int32.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

EMPTY = -1
TOMB = -2


class HashTable(NamedTuple):
    """Open-addressing table. ``size`` must be a power of two."""

    keys: jax.Array  # int32[size]
    vals: jax.Array  # int32[size]


def make(size: int) -> HashTable:
    if size & (size - 1):
        raise ValueError(f"hash table size must be a power of two, got {size}")
    return HashTable(
        keys=jnp.full((size,), EMPTY, dtype=jnp.int32),
        vals=jnp.full((size,), EMPTY, dtype=jnp.int32),
    )


def hash_u32(x: jax.Array) -> jax.Array:
    """splitmix32-style avalanche; int32 in, uint32 out."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def ctx_hash_fold(h: jax.Array, tok: jax.Array) -> jax.Array:
    """One step of the rolling n-gram context hash: ``h*M + hash_u32(tok)``.

    The single definition of the recurrence shared by
    ``speculative.context_ids``, the draft-walk kernel and its oracle — the
    three must hash identically or drafts silently stop matching what
    ``observe`` learned."""
    return h * jnp.uint32(1000003) + hash_u32(tok)


def ctx_window_hash(window: jax.Array) -> jax.Array:
    """Context id of a ``[..., W]`` token window: fold the W tokens newest
    first (the order ``context_ids`` produces at the last position) and
    clear the top bit so the id is a valid table key."""
    w = window.shape[-1]
    h = jnp.zeros(window.shape[:-1], jnp.uint32)
    for j in range(w):
        h = ctx_hash_fold(h, window[..., w - 1 - j])
    return (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)


def _slot0(key: jax.Array, size: int) -> jax.Array:
    return (hash_u32(key) & jnp.uint32(size - 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_probes",))
def lookup(table: HashTable, key: jax.Array, max_probes: int = 64) -> Tuple[jax.Array, jax.Array]:
    """Return ``(val, found)``. ``val`` is EMPTY when not found.

    Fixed ``max_probes`` trip count; with load factor <= 0.5 the probability of
    a chain longer than 64 is negligible (overflow shows up as a miss and is
    tracked by the caller's overflow counter).
    """
    size = table.keys.shape[0]
    h0 = _slot0(key, size)

    def body(i, carry):
        val, done = carry
        idx = (h0 + i) & (size - 1)
        k = table.keys[idx]
        hit = (k == key) & ~done
        val = jnp.where(hit, table.vals[idx], val)
        done = done | (k == key) | (k == EMPTY)
        return val, done

    val, _ = jax.lax.fori_loop(0, max_probes, body, (jnp.int32(EMPTY), jnp.bool_(False)))
    return val, val != EMPTY


def lookup_batch(table: HashTable, keys: jax.Array, max_probes: int = 64,
                 impl: str = "vmap"):
    """Batched read-only probe: ``(vals[B], found[B])``.

    ``impl='vmap'`` (default) keeps the historical vmapped scalar probe.
    Any kernel impl (``auto``/``ref``/``pallas``) routes through the shared
    open-addressing probe kernel (``ops.ht_find`` — the flat table is the
    N = 1 case of the per-row probe), so the src lookup at the head of every
    query is one fused dispatch instead of B scalar probe loops.  Imported
    lazily: this module is a leaf the kernel layer itself depends on.
    """
    if impl == "vmap":
        return jax.vmap(lambda k: lookup(table, k, max_probes))(keys)
    from repro.kernels import ops
    return ops.ht_find(keys, table.keys, table.vals, max_probes=max_probes,
                       impl=impl)


@functools.partial(jax.jit, static_argnames=("max_probes",))
def insert(
    table: HashTable, key: jax.Array, val: jax.Array, max_probes: int = 64
) -> Tuple[HashTable, jax.Array, jax.Array]:
    """Insert or update ``key -> val``.

    Returns ``(table, slot, ok)``; ``ok`` False means the probe window was
    exhausted (caller should count it as an overflow drop).  The first TOMB
    seen is reused only if the key is not found further down the chain, which
    keeps the chain invariant intact.
    """
    size = table.keys.shape[0]
    h0 = _slot0(key, size)

    def body(i, carry):
        slot, tomb_slot, done = carry
        idx = (h0 + i) & (size - 1)
        k = table.keys[idx]
        is_hit = (k == key) & ~done
        is_empty = (k == EMPTY) & ~done
        is_tomb = (k == TOMB) & ~done & (tomb_slot < 0)
        tomb_slot = jnp.where(is_tomb, idx, tomb_slot)
        # land on the key itself, or on the first EMPTY (end of chain)
        slot = jnp.where(is_hit, idx, jnp.where(is_empty, idx, slot))
        done = done | (k == key) | (k == EMPTY)
        return slot, tomb_slot, done

    slot, tomb_slot, done = jax.lax.fori_loop(
        0, max_probes, body, (jnp.int32(-1), jnp.int32(-1), jnp.bool_(False))
    )
    # Prefer a reusable TOMB slot when (a) we stopped at EMPTY without the
    # key, or (b) the probe window exhausted without the key or an EMPTY —
    # a tombstone-saturated chain.  In both cases the key is provably absent
    # (it could only live inside the window), so reuse keeps the chain
    # invariant intact.  Case (b) previously dropped the key (slot -1,
    # ok False) even though tomb_slot was reusable.
    landed_key = jnp.where(slot >= 0, table.keys[jnp.maximum(slot, 0)], EMPTY)
    use_tomb = (tomb_slot >= 0) & ((slot < 0) | (landed_key == EMPTY))
    slot = jnp.where(use_tomb, tomb_slot, slot)
    ok = slot >= 0
    widx = jnp.maximum(slot, 0)
    new_keys = table.keys.at[widx].set(jnp.where(ok, key, table.keys[widx]))
    new_vals = table.vals.at[widx].set(jnp.where(ok, val, table.vals[widx]))
    return HashTable(new_keys, new_vals), slot, ok


@functools.partial(jax.jit, static_argnames=("max_probes",))
def delete(table: HashTable, key: jax.Array, max_probes: int = 64) -> Tuple[HashTable, jax.Array]:
    """Tombstone ``key``. Returns ``(table, deleted)``."""
    size = table.keys.shape[0]
    h0 = _slot0(key, size)

    def body(i, carry):
        slot, done = carry
        idx = (h0 + i) & (size - 1)
        k = table.keys[idx]
        hit = (k == key) & ~done
        slot = jnp.where(hit, idx, slot)
        done = done | (k == key) | (k == EMPTY)
        return slot, done

    slot, _ = jax.lax.fori_loop(0, max_probes, body, (jnp.int32(-1), jnp.bool_(False)))
    ok = slot >= 0
    widx = jnp.maximum(slot, 0)
    new_keys = table.keys.at[widx].set(jnp.where(ok, TOMB, table.keys[widx]))
    return HashTable(new_keys, table.vals), ok


@functools.partial(jax.jit, static_argnames=("max_probes",))
def insert_batch_sequential(
    table: HashTable,
    keys: jax.Array,
    vals: jax.Array,
    active: jax.Array,
    max_probes: int = 64,
) -> Tuple[HashTable, jax.Array, jax.Array]:
    """Sequentially insert a batch (lax.scan). Deterministic: batch order wins.

    Returns ``(table, slots[B], n_dropped)``.  This is the RCU "writer side";
    batched readers (:func:`lookup_batch`) never conflict with it because the
    caller sequences update and query steps (DESIGN.md: epoch snapshots).
    """

    def step(carry, item):
        tab, dropped = carry
        k, v, a = item
        new_tab, slot, ok = insert(tab, k, v, max_probes)
        tab = jax.tree_util.tree_map(
            lambda n, o: jnp.where(a, n, o), new_tab, tab
        )
        dropped = dropped + jnp.where(a & ~ok, 1, 0)
        slot = jnp.where(a, slot, -1)
        return (tab, dropped), slot

    (table, n_dropped), slots = jax.lax.scan(
        step, (table, jnp.int32(0)), (keys, vals, active)
    )
    return table, slots, n_dropped


def load_factor(table: HashTable) -> jax.Array:
    return jnp.mean((table.keys >= 0).astype(jnp.float32))
