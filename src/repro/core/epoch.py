"""Epoch snapshots: the RCU grace-period analogue (DESIGN.md §2).

In the paper, readers run inside RCU read-side critical sections; writers
mutate concurrently and reclamation waits for a grace period.  In an SPMD
functional runtime there is no shared mutable heap: a *published snapshot* (an
immutable pytree) plays the role of the RCU-protected structure, and the
"grace period" is the moment no consumer can reference version ``v-1`` any
more — trivially the publish of ``v`` for program-ordered steps, and a
versioned buffer hand-off across hosts.

``EpochStore`` is the host-side coordinator: serving threads ``acquire()`` a
snapshot (read-side critical section enter), while the learner thread
``publish()``-es new versions.  Python reference assignment is atomic under
the GIL, so readers never observe a torn snapshot — the lock-free property.
``retired_versions`` mirrors RCU's deferred reclamation: a version is retired
once its reader count drops to zero AND a newer version exists; on device this
lets the buffer be donated.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, NamedTuple, Optional

from repro.analysis.invariants import requires_lock


class Snapshot(NamedTuple):
    version: int
    state: Any  # immutable pytree (e.g. MCState)


class EpochStore:
    """Single-writer / many-reader snapshot store with reader accounting."""

    # Concurrency contract (DESIGN.md §11, checked by tools/mcqlint):
    # ``_lock`` guards the reader accounting only.  ``_snap`` is deliberately
    # NOT declared protected — the single atomic reference swap under the GIL
    # is the lock-free read path the whole design rests on.  Globally,
    # ``_lock`` ranks below every engine lock (it is only ever taken inside
    # store calls and never holds while calling out).
    _MCQ_LOCK_ORDER = ("_lock",)
    _MCQ_LOCK_PROTECTS = {
        "_lock": ("_readers", "retired_versions"),
    }

    def __init__(self, state: Any):
        self._snap = Snapshot(0, state)
        self._readers: dict[int, int] = {}
        self._lock = threading.Lock()  # protects accounting only, never reads
        self._on_retire: Optional[Callable[[Snapshot], None]] = None
        self.retired_versions: list[int] = []

    # -- read side -------------------------------------------------------
    def acquire(self) -> Snapshot:
        """Enter a read-side critical section: pin the current snapshot."""
        snap = self._snap  # atomic ref read (GIL)
        with self._lock:
            self._readers[snap.version] = self._readers.get(snap.version, 0) + 1
        return snap

    def release(self, snap: Snapshot) -> None:
        """Leave the read-side critical section; may trigger reclamation."""
        with self._lock:
            self._readers[snap.version] -= 1
            self._maybe_retire_locked()

    # -- write side ------------------------------------------------------
    def publish(self, state: Any) -> int:
        """Publish a new version. Readers acquired before this keep seeing the
        old snapshot until they release — never a torn state."""
        new = Snapshot(self._snap.version + 1, state)
        old = self._snap
        self._snap = new  # the single atomic "pointer swap"
        with self._lock:
            self._readers.setdefault(old.version, self._readers.get(old.version, 0))
            self._maybe_retire_locked()
        return new.version

    def synchronize(self, poll_interval: float = 1e-4) -> None:
        """Block until every reader of pre-current versions has released —
        the literal ``synchronize_rcu()``.  Polls with a short exponential
        backoff: a tight loop re-acquiring ``self._lock`` would starve the
        very readers it waits on under the GIL (they need the lock to
        release), turning a one-inference-step grace period into a livelock.
        """
        cur = self._snap.version
        delay = poll_interval
        while True:
            with self._lock:
                if all(n == 0 for v, n in self._readers.items() if v < cur):
                    return
            time.sleep(delay)
            delay = min(delay * 2, 0.01)

    # -- reclamation -----------------------------------------------------
    @requires_lock("_lock")
    def _maybe_retire_locked(self) -> None:
        cur = self._snap.version
        for v in sorted(self._readers):
            if v < cur and self._readers[v] == 0:
                del self._readers[v]
                self.retired_versions.append(v)

    @property
    def version(self) -> int:
        return self._snap.version
