"""MCPrioQ: online sparse Markov chain with priority-ordered edge queries.

This is the paper's contribution as a composable JAX module (DESIGN.md §1-2).

Data layout
-----------
  * src hash table  : node-id -> row index into the slabs (open addressing)
  * slabs           : per-row stable edge slots (dst, cnt) + ``order`` perm
  * two counters    : per-edge ``cnt`` and per-row ``tot``; probability is
                      ``cnt/tot`` computed at query time (paper §II.3)
  * optional dst hash: per-row open-addressing table dst -> slot ("optional
                      optimization", paper §II.2); slots are stable so the
                      hash survives reordering, like the paper's pointers.

Update semantics (paper §II.A, TPU-batched)
-------------------------------------------
A batch of B transitions runs through a three-stage pipeline:
  * **pre-aggregation**: the batch is sorted by (src, dst) and duplicate
    edges are segment-summed into one item each, so B raw transitions
    collapse to U unique edges before either path runs — the batched
    analogue of contended atomics coalescing on one cache line (and the
    relaxed-batching insight of the MultiQueues line of work).
  * **update of edge** (normal case): the edge already exists — a fused
    batched increment via :func:`repro.kernels.ops.slab_update` (the
    paper's "O(1) lookup + atomic increment" as one kernel dispatch).
  * **new edge** (rare case): new-edge items are stable-partitioned to a
    static ``max_new_per_batch`` prefix and handled by a deterministic
    sequential pass (lax.scan) that allocates rows/slots and applies
    Space-Saving tail replacement when a row is full (DESIGN.md assumption
    log).  The scan is wrapped in ``lax.cond`` so a batch with zero new
    edges skips it entirely: slow-path cost is O(new edges), not O(B).
    Edges past the prefix are counted in ``deferred_new`` (the caller may
    resubmit; DESIGN.md §2 observability).
Afterwards ``sort_passes`` odd-even passes (``ops.oddeven_sort``) restore
approximate order — the paper's lock-free bubble sort.

Kernel dispatch is selected by ``MCConfig.impl`` (``auto``/``ref``/
``pallas``); ``core``, ``sharded`` and ``serve`` all inherit the fused paths
through this module.  ``update_batch_reference`` keeps the pre-kernel
O(B)-scan semantics as an oracle for equivalence tests and benchmarks.

Inference (paper §II.B, DESIGN.md §8)
-------------------------------------
``query_threshold`` walks the order permutation accumulating probability until
the cumulative sum crosses ``t``: complexity O(CDF^-1(t)) items touched.  By
default (``MCConfig.fused_query``) the kernel layer owns the whole read:
:func:`repro.kernels.ops.cdf_query_fused` gathers only the queried rows
(scalar-prefetch DMA on TPU) and runs the chunked early-exit walk in-kernel;
``fused_query=False`` keeps the unfused ``_ordered_rows`` +
:func:`repro.kernels.ops.cdf_query` baseline, bit-identical by the
integer-walk contract.  ``query_topk`` is the kernel's ``threshold=None``
mode.

Maintenance (paper §II.C, DESIGN.md §6)
---------------------------------------
``decay`` dispatches through :func:`repro.kernels.ops.decay_sort` (halve,
evict, odd-even compaction).  ``MCConfig.decay_block_rows`` selects rolling
mode: each call halves one row block and repairs that block's dst hashes
incrementally (tombstones, not rebuilds), so per-call maintenance cost is
bounded by the block size; a full rebuild runs only when accumulated
tombstones cross ``dh_rebuild_fraction`` of the hash capacity.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.core.hashtable import EMPTY, TOMB, HashTable
from repro.core.slab import Slabs
from repro.kernels import ops


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class MCConfig:
    """Static configuration (hashable; safe as a jit static arg)."""

    num_rows: int = 1024          # max distinct src nodes tracked
    capacity: int = 128           # max out-degree tracked per src (C)
    table_size: int = 0           # src hash slots; 0 -> 4 * num_rows pow2
    max_probes: int = 64
    sort_passes: int = 1          # odd-even passes per update batch
    use_dst_hash: bool = False    # paper's optional dst->slot hash table
    dst_table_size: int = 0       # per-row; 0 -> 4 * capacity pow2
    max_new_per_batch: int = 0    # slow-path prefix; 0 = unbounded (batch)
    impl: str = "auto"            # kernel dispatch: auto | ref | pallas
    # inference path (DESIGN.md §8): fused in-kernel row gather vs the
    # unfused _ordered_rows host-side gather; 0 = auto-pick early-exit
    # chunks from capacity and the lane width
    fused_query: bool = True
    query_chunks: int = 0
    # maintenance (DESIGN.md §6): 0 = stop-the-world decay; R > 0 = rolling
    # decay that halves one R-row block per call (bounded per-call work)
    decay_block_rows: int = 0
    # full dst-hash rebuild once decay tombstones exceed this fraction of
    # the total dst-hash capacity (num_rows * dst_table_size)
    dh_rebuild_fraction: float = 0.25

    def resolved_table_size(self) -> int:
        return self.table_size or _next_pow2(4 * self.num_rows)

    def resolved_dst_table_size(self) -> int:
        return self.dst_table_size or _next_pow2(4 * self.capacity)

    def resolved_max_new(self, batch: int) -> int:
        if self.max_new_per_batch <= 0:
            return batch
        return min(self.max_new_per_batch, batch)

    def resolved_decay_rows(self) -> int:
        """Rows decayed per call: the block size, clamped to the table."""
        if self.decay_block_rows <= 0:
            return self.num_rows
        return min(self.decay_block_rows, self.num_rows)


class MCState(NamedTuple):
    src_table: HashTable   # node-id -> row
    slabs: Slabs
    n_rows: jax.Array      # int32[]   allocated rows
    # optional per-row dst hash (zero-size arrays when disabled)
    dh_keys: jax.Array     # int32[N, H]
    dh_vals: jax.Array     # int32[N, H]
    # observability counters (drops are the price of fixed shapes; DESIGN §2)
    dropped_rows: jax.Array    # srcs dropped because num_rows exhausted
    dropped_probes: jax.Array  # items dropped on probe-window overflow
    evictions: jax.Array       # Space-Saving tail replacements
    deferred_new: jax.Array    # new edges past the max_new_per_batch prefix
    route_dropped: jax.Array   # items dropped on all_to_all bucket overflow
    # maintenance state + observability (DESIGN.md §6)
    decay_cursor: jax.Array    # next row block for rolling decay
    decay_steps: jax.Array     # decay calls applied (blocks, not full sweeps)
    dh_rebuilds: jax.Array     # full dst-hash rebuilds triggered
    dh_tombstones: jax.Array   # live decay tombstones across all row hashes


def init(cfg: MCConfig) -> MCState:
    n, c = cfg.num_rows, cfg.capacity
    h = cfg.resolved_dst_table_size() if cfg.use_dst_hash else 1
    return MCState(
        src_table=ht.make(cfg.resolved_table_size()),
        slabs=sl.make(n, c),
        n_rows=jnp.int32(0),
        dh_keys=jnp.full((n, h), EMPTY, dtype=jnp.int32),
        dh_vals=jnp.full((n, h), EMPTY, dtype=jnp.int32),
        dropped_rows=jnp.int32(0),
        dropped_probes=jnp.int32(0),
        evictions=jnp.int32(0),
        deferred_new=jnp.int32(0),
        route_dropped=jnp.int32(0),
        decay_cursor=jnp.int32(0),
        decay_steps=jnp.int32(0),
        dh_rebuilds=jnp.int32(0),
        dh_tombstones=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# per-row dst hash helpers (optional optimisation path)
# ---------------------------------------------------------------------------


def _dh_set(state: MCState, row: jax.Array, key: jax.Array, slot: jax.Array,
            active: jax.Array, cfg: MCConfig) -> MCState:
    tab = HashTable(state.dh_keys[row], state.dh_vals[row])
    new_tab, _, _ = ht.insert(tab, key, slot, cfg.max_probes)
    dh_keys = state.dh_keys.at[row].set(
        jnp.where(active, new_tab.keys, state.dh_keys[row]))
    dh_vals = state.dh_vals.at[row].set(
        jnp.where(active, new_tab.vals, state.dh_vals[row]))
    return state._replace(dh_keys=dh_keys, dh_vals=dh_vals)


def _dh_del(state: MCState, row: jax.Array, key: jax.Array,
            active: jax.Array, cfg: MCConfig) -> MCState:
    tab = HashTable(state.dh_keys[row], state.dh_vals[row])
    new_tab, _ = ht.delete(tab, key, cfg.max_probes)
    dh_keys = state.dh_keys.at[row].set(
        jnp.where(active, new_tab.keys, state.dh_keys[row]))
    return state._replace(dh_keys=dh_keys)


def _dh_rebuild_all(state: MCState, cfg: MCConfig) -> MCState:
    """Vectorised rebuild of every row hash from the slabs (used after decay).

    Rows are independent, so a vmap over rows of a sequential slot-insert loop
    is conflict-free.
    """
    if not cfg.use_dst_hash:
        return state
    h = cfg.resolved_dst_table_size()

    def rebuild_row(dsts, cnts):
        tab = ht.make(h)

        def body(i, tab):
            new_tab, _, _ = ht.insert(tab, dsts[i], jnp.int32(i), cfg.max_probes)
            live = cnts[i] > 0
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(live, n, o), new_tab, tab)

        return jax.lax.fori_loop(0, dsts.shape[0], body, tab)

    tabs = jax.vmap(rebuild_row)(state.slabs.dst, state.slabs.cnt)
    return state._replace(dh_keys=tabs.keys, dh_vals=tabs.vals)


# ---------------------------------------------------------------------------
# lookups
# ---------------------------------------------------------------------------


def lookup_rows(state: MCState, src: jax.Array, cfg: MCConfig):
    """Batched src -> row. Returns ``(rows[B], found[B])``; row 0 when missing.

    Routed through the shared open-addressing probe kernel (``ops.ht_find``
    via ``lookup_batch``) so the src lookup at the head of every query and
    update is one fused dispatch on the selected backend.
    """
    rows, found = ht.lookup_batch(state.src_table, src, cfg.max_probes,
                                  impl=cfg.impl)
    return jnp.where(found, rows, 0), found


def _find_slots(state: MCState, rows: jax.Array, dst: jax.Array, cfg: MCConfig):
    """Batched (row, dst) -> slot via dst-hash or row scan (paper §II.2).

    The hash path is a fused kernel dispatch (``ops.dh_find``): one grid
    over row-blocks instead of a vmapped scalar probe loop per item.
    """
    if cfg.use_dst_hash:
        slots, found = ops.dh_find(rows, dst, state.dh_keys, state.dh_vals,
                                   max_probes=cfg.max_probes, impl=cfg.impl)
        return jnp.where(found, slots, 0), found
    slots, found = jax.vmap(
        lambda r, d: sl.find_slot(state.slabs, r, d))(rows, dst)
    return slots, found


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def _aggregate_batch(src, dst, w, active):
    """Collapse in-batch duplicates: B items -> U unique (src, dst) edges.

    Sorts the batch by (inactive, src, dst) — inactive items sink to the
    tail — and segment-sums weights into the first occurrence (*head*) of
    each unique edge.  Returns ``(src, dst, w, head, pos)`` in sorted order
    where ``head`` marks the unique-edge representatives, ``pos`` is each
    head edge's first-occurrence position in the original batch (for
    arrival-order tie-breaks downstream); non-head slots carry
    ``src = dst = -1`` and ``w = 0``.
    """
    b = src.shape[0]
    inactive = (~active).astype(jnp.int32)
    idx = jnp.arange(b, dtype=jnp.int32)
    inact_s, src_s, dst_s, w_s, idx_s = jax.lax.sort(
        (inactive, src, dst, w, idx), num_keys=3, is_stable=True)
    act_s = inact_s == 0
    first = jnp.concatenate([
        jnp.ones((1,), bool),
        (src_s[1:] != src_s[:-1]) | (dst_s[1:] != dst_s[:-1])])
    head = act_s & first
    # segment id of each item = index of its head; heads are in ascending
    # order so the cumsum is sorted (segment_sum fast path)
    seg = jnp.clip(jnp.cumsum(head.astype(jnp.int32)) - 1, 0, b - 1)
    sums = jax.ops.segment_sum(jnp.where(act_s, w_s, 0), seg,
                               num_segments=b, indices_are_sorted=True)
    mins = jax.ops.segment_min(jnp.where(act_s, idx_s, b), seg,
                               num_segments=b, indices_are_sorted=True)
    u_w = jnp.where(head, sums[seg], 0).astype(w.dtype)
    u_src = jnp.where(head, src_s, -1)
    u_dst = jnp.where(head, dst_s, -1)
    u_pos = jnp.where(head, mins[seg], b).astype(jnp.int32)
    return u_src, u_dst, u_w, head, u_pos


def _take_new_prefix(src, dst, w, pos, new_mask, limit: int):
    """Stable-partition new-edge items to the front, truncated to ``limit``.

    Ties inside the partition break by ``pos`` (original arrival order), so
    a tight ``max_new_per_batch`` admits the earliest-arriving new edges
    instead of starving high node-ids (the seed's "batch order wins" rule).
    Returns ``(src[limit], dst[limit], w[limit], mask[limit], overflow)``
    where ``overflow`` counts new edges that did not fit in the prefix.
    """
    key = (~new_mask).astype(jnp.int32)
    key_s, _, p_src, p_dst, p_w = jax.lax.sort(
        (key, pos, src, dst, w), num_keys=2, is_stable=True)
    p_mask = key_s[:limit] == 0
    overflow = (jnp.sum(new_mask.astype(jnp.int32))
                - jnp.sum(p_mask.astype(jnp.int32)))
    return p_src[:limit], p_dst[:limit], p_w[:limit], p_mask, overflow


def _slow_path(state: MCState, src, dst, w, active, cfg: MCConfig) -> MCState:
    """Sequential insert pass for new edges / new rows (the paper's rare case).

    Deterministic (batch order), fully masked — inactive items are no-ops.
    """
    n_cap = cfg.num_rows

    def step(state: MCState, item):
        s, d, wi, a = item
        # --- src row (lookup or allocate) -------------------------------
        row0, found_src = ht.lookup(state.src_table, s, cfg.max_probes)
        can_alloc = state.n_rows < n_cap
        do_alloc = a & ~found_src & can_alloc
        row = jnp.where(found_src, row0, state.n_rows)
        new_tab, _, ins_ok = ht.insert(state.src_table, s, row, cfg.max_probes)
        take_ins = do_alloc & ins_ok
        src_table = jax.tree_util.tree_map(
            lambda n, o: jnp.where(take_ins, n, o), new_tab, state.src_table)
        n_rows = state.n_rows + jnp.where(take_ins, 1, 0)
        dropped_rows = state.dropped_rows + jnp.where(a & ~found_src & ~can_alloc, 1, 0)
        dropped_probes = state.dropped_probes + jnp.where(do_alloc & ~ins_ok, 1, 0)
        have_row = found_src | take_ins
        act = a & have_row
        row = jnp.where(have_row, row, 0)

        # --- dst slot (find / free / Space-Saving tail replace) ---------
        slabs = state.slabs
        slot_eq, found_d = sl.find_slot(slabs, row, d)
        slot_free, has_free = sl.free_slot(slabs, row)
        victim = sl.tail_slot(slabs, row)
        slot = jnp.where(found_d, slot_eq, jnp.where(has_free, slot_free, victim))
        replace = act & ~found_d & ~has_free
        evicted_dst = slabs.dst[row, slot]
        # Space-Saving: the newcomer inherits the evicted count (overestimate)
        base = jnp.where(found_d, slabs.cnt[row, slot],
                         jnp.where(has_free, 0, slabs.cnt[row, slot]))
        new_c = base + wi
        cnt = slabs.cnt.at[row, slot].set(jnp.where(act, new_c, slabs.cnt[row, slot]))
        dstv = slabs.dst.at[row, slot].set(jnp.where(act, d, slabs.dst[row, slot]))
        tot = slabs.tot.at[row].add(jnp.where(act, wi, 0))
        slabs = Slabs(dst=dstv, cnt=cnt, tot=tot, order=slabs.order)
        state = state._replace(
            src_table=src_table, slabs=slabs, n_rows=n_rows,
            dropped_rows=dropped_rows, dropped_probes=dropped_probes,
            evictions=state.evictions + jnp.where(replace, 1, 0))
        if cfg.use_dst_hash:
            state = _dh_del(state, row, evicted_dst, replace, cfg)
            state = _dh_set(state, row, d, slot, act & ~found_d, cfg)
        return state, None

    state, _ = jax.lax.scan(step, state, (src, dst, w, active))
    return state


def update_batch_impl(
    state: MCState,
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    cfg: MCConfig,
) -> MCState:
    """Traced body of :func:`update_batch` — the full kernel-routed pipeline
    with no jit boundary of its own.

    Call this (not ``update_batch``) from inside another traced context such
    as the shard_map bodies in ``core/sharded.py``: the kernel dispatches
    (``ops.slab_update`` / ``ops.ht_find`` / ``ops.oddeven_sort``) then inline
    directly into the caller's program instead of nesting a jit call.
    """
    b = src.shape[0]
    w = jnp.ones((b,), jnp.int32) if weights is None else weights.astype(jnp.int32)
    m = jnp.ones((b,), bool) if mask is None else mask
    m = m & (src >= 0) & (dst >= 0)

    # (1) pre-aggregate: B items -> U unique edges (duplicates never pay a
    # slow-path step again)
    u_src, u_dst, u_w, u_act, u_pos = _aggregate_batch(src, dst, w, m)

    # (2) classify against the pre-state: edge exists <=> fast
    rows0, found_src0 = lookup_rows(state, u_src, cfg)
    _, found_d0 = _find_slots(state, rows0, u_dst, cfg)
    fast = u_act & found_src0 & found_d0

    # (3) fast path: fused batched increment through the kernel layer (the
    # batched equivalent of the paper's atomic fetch-add)
    slabs = state.slabs
    cnt, tot = ops.slab_update(
        jnp.where(fast, rows0, -1), u_dst, u_w,
        slabs.dst, slabs.cnt, slabs.tot, impl=cfg.impl)
    state = state._replace(slabs=Slabs(slabs.dst, cnt, tot, slabs.order))

    # (4) slow path: new edges only, partitioned to a static prefix so the
    # sequential scan is O(max_new), and skipped entirely when empty.  A
    # second, 4x-shorter scan tier handles the common "a few new edges"
    # case so the cost tracks the actual new-edge count, not the bound.
    new_mask = u_act & ~fast
    limit = cfg.resolved_max_new(b)
    p_src, p_dst, p_w, p_mask, overflow = _take_new_prefix(
        u_src, u_dst, u_w, u_pos, new_mask, limit)
    state = state._replace(deferred_new=state.deferred_new + overflow)
    n_new = jnp.sum(p_mask.astype(jnp.int32))
    small = max(limit // 4, 1)

    def run_prefix(n):
        return lambda st: _slow_path(
            st, p_src[:n], p_dst[:n], p_w[:n], p_mask[:n], cfg)

    if small < limit:
        state = jax.lax.cond(
            n_new == 0, lambda st: st,
            lambda st: jax.lax.cond(
                n_new <= small, run_prefix(small), run_prefix(limit), st),
            state)
    else:
        state = jax.lax.cond(
            n_new == 0, lambda st: st, run_prefix(limit), state)

    # (5) lock-free bubble sort, through the kernel layer
    if cfg.sort_passes:
        slabs = state.slabs
        order = ops.oddeven_sort(slabs.cnt, slabs.order,
                                 passes=cfg.sort_passes, impl=cfg.impl)
        state = state._replace(
            slabs=Slabs(slabs.dst, slabs.cnt, slabs.tot, order))
    return state


@functools.partial(jax.jit, static_argnames=("cfg",))
def update_batch(
    state: MCState,
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    cfg: MCConfig,
) -> MCState:
    """Apply a batch of transitions ``src[i] -> dst[i]`` (paper §II.A).

    Pipeline: pre-aggregate duplicates, fused fast-path increment
    (``ops.slab_update``), bounded sequential slow path for new edges
    (skipped via ``lax.cond`` when the batch has none), then
    ``cfg.sort_passes`` odd-even passes (``ops.oddeven_sort``).
    jit wrapper over :func:`update_batch_impl`.
    """
    return update_batch_impl(state, src, dst, weights, mask, cfg=cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def update_batch_reference(
    state: MCState,
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    cfg: MCConfig,
) -> MCState:
    """Pre-kernel oracle for :func:`update_batch` (the seed implementation).

    Inline scatter-add fast path + an O(B) sequential slow path that walks
    every batch item.  Kept as the semantic ground truth for equivalence
    tests and as the benchmark baseline; ``max_new_per_batch``/``impl`` are
    deliberately ignored here.
    """
    b = src.shape[0]
    w = jnp.ones((b,), jnp.int32) if weights is None else weights.astype(jnp.int32)
    m = jnp.ones((b,), bool) if mask is None else mask
    m = m & (src >= 0) & (dst >= 0)

    # classify against the pre-state: edge exists <=> fast
    rows0, found_src0 = lookup_rows(state, src, cfg)
    slots0, found_d0 = _find_slots(state, rows0, dst, cfg)
    fast = m & found_src0 & found_d0

    # fast path: scatter-add (duplicates aggregate, like contended atomics)
    add_w = jnp.where(fast, w, 0)
    slabs = state.slabs
    cnt = slabs.cnt.at[rows0, slots0].add(add_w)
    tot = slabs.tot.at[rows0].add(add_w)
    state = state._replace(slabs=Slabs(slabs.dst, cnt, tot, slabs.order))

    # slow path: everything else, sequential + masked
    state = _slow_path(state, src, dst, w, m & ~fast, cfg)

    # lock-free bubble sort, vectorised
    slabs = state.slabs
    order = sl.oddeven_passes(slabs.cnt, slabs.order, cfg.sort_passes)
    return state._replace(slabs=Slabs(slabs.dst, slabs.cnt, slabs.tot, order))


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------


def _ordered_rows(state: MCState, src: jax.Array, cfg: MCConfig):
    """Gather counts/dsts of each queried row in priority order.

    The **unfused** layout transform (three O(B*C) host-side gathers) kept
    as the baseline the fused path must match bit-for-bit
    (``cfg.fused_query=False``; DESIGN.md §8): counts of unknown srcs are
    zeroed so downstream liveness tests (``c > 0``) subsume the ``found``
    mask.
    """
    rows, found = lookup_rows(state, src, cfg)
    order = state.slabs.order[rows]                       # [B, C]
    c = jnp.take_along_axis(state.slabs.cnt[rows], order, axis=1)
    d = jnp.take_along_axis(state.slabs.dst[rows], order, axis=1)
    c = jnp.where(found[:, None], c, 0)
    return c, d, state.slabs.tot[rows], found


def query_impl(state: MCState, src: jax.Array, threshold, cfg: MCConfig,
               max_items: int):
    """Shared inference dispatch: fused in-kernel row gather by default
    (``ops.ht_find`` probe + ``ops.cdf_query_fused``), the unfused
    ``_ordered_rows`` + ``cdf_query`` pipeline otherwise.  ``threshold=None``
    is top-k mode (every live item).  Un-jitted traced body — the shard_map
    bodies in ``core/sharded.py`` call it directly."""
    if cfg.fused_query:
        rows, found = lookup_rows(state, src, cfg)
        return ops.cdf_query_fused(
            rows, found, state.slabs.cnt, state.slabs.dst, state.slabs.order,
            state.slabs.tot, threshold, max_items=max_items,
            chunks=cfg.query_chunks, impl=cfg.impl)
    c, d, tot, _ = _ordered_rows(state, src, cfg)
    return ops.cdf_query(c, d, tot, threshold, max_items=max_items,
                         chunks=cfg.query_chunks, impl=cfg.impl)


@functools.partial(jax.jit, static_argnames=("cfg", "max_items"))
def query_threshold(
    state: MCState,
    src: jax.Array,
    threshold: float,
    *,
    cfg: MCConfig,
    max_items: int = 16,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Items in descending probability until cumulative prob >= threshold.

    Returns ``(dsts[B, max_items], probs[B, max_items], n_needed[B])`` where
    entries past ``n_needed`` are EMPTY/0.  ``n_needed`` is the paper's
    CDF^-1(t): how many items a reader must touch.  Unknown srcs yield 0.
    Runs through the kernel layer (``ops.cdf_query_fused`` /
    ``ops.cdf_query`` per ``cfg.fused_query``; DESIGN.md §8).
    """
    return query_impl(state, src, threshold, cfg, max_items)


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def query_topk(state: MCState, src: jax.Array, *, cfg: MCConfig, k: int = 8):
    """Top-k edges by (approximate) probability. ``(dsts[B,k], probs[B,k])``.

    Top-k is the kernel's explicit ``threshold=None`` mode (keep every live
    item), sharing the fused CDF walk.
    """
    dk, pk, _ = query_impl(state, src, None, cfg, k)
    return dk, pk


# ---------------------------------------------------------------------------
# decay (paper §II.C) — incremental maintenance subsystem (DESIGN.md §6)
# ---------------------------------------------------------------------------


def _dh_repair_rows(state: MCState, row0: jax.Array, block_rows: int,
                    cfg: MCConfig) -> MCState:
    """Incremental dst-hash repair after a block decay.

    Every dst-hash entry stores the slot it points at, so repair is one
    vectorised gather over the touched block: tombstone each occupied lane
    whose slot died (cnt == 0).  No probe loops, no per-row rebuild —
    O(block_rows * H) VPU work.  Tombstones accumulate in ``dh_tombstones``
    (decay-side only; probes walk through TOMB so lookups stay correct, just
    gradually slower) and a full rebuild runs once they cross
    ``dh_rebuild_fraction`` of the total hash capacity.
    """
    if not cfg.use_dst_hash:
        return state
    h = cfg.resolved_dst_table_size()
    keys_b = jax.lax.dynamic_slice(state.dh_keys, (row0, 0), (block_rows, h))
    vals_b = jax.lax.dynamic_slice(state.dh_vals, (row0, 0), (block_rows, h))
    cnt_b = jax.lax.dynamic_slice(state.slabs.cnt, (row0, 0),
                                  (block_rows, cfg.capacity))
    occupied = keys_b >= 0
    pointed_cnt = jnp.take_along_axis(
        cnt_b, jnp.clip(vals_b, 0, cfg.capacity - 1), axis=1)
    dead = occupied & (pointed_cnt == 0)
    keys_b = jnp.where(dead, TOMB, keys_b)
    state = state._replace(
        dh_keys=jax.lax.dynamic_update_slice(state.dh_keys, keys_b, (row0, 0)),
        dh_tombstones=state.dh_tombstones + jnp.sum(dead.astype(jnp.int32)))

    threshold = jnp.int32(cfg.dh_rebuild_fraction * cfg.num_rows * h)

    def rebuild(s):
        s = _dh_rebuild_all(s, cfg)
        return s._replace(dh_tombstones=jnp.int32(0),
                          dh_rebuilds=s.dh_rebuilds + 1)

    return jax.lax.cond(state.dh_tombstones > threshold,
                        rebuild, lambda s: s, state)


def decay_impl(state: MCState, *, cfg: MCConfig) -> MCState:
    """Traced body of :func:`decay` (no jit boundary — shard bodies call it
    directly, so every shard keeps its own rolling ``decay_cursor``)."""
    n, c = cfg.num_rows, cfg.capacity
    r = cfg.resolved_decay_rows()
    slabs = state.slabs
    if r >= n:  # stop-the-world: one fused full-table dispatch
        cnt, dst, order, tot = ops.decay_sort(
            slabs.cnt, slabs.dst, slabs.order, impl=cfg.impl)
        state = state._replace(
            slabs=Slabs(dst, cnt, tot, order),
            decay_steps=state.decay_steps + 1)
        return _dh_repair_rows(state, jnp.int32(0), n, cfg)

    n_blocks = -(-n // r)
    cur = jnp.remainder(state.decay_cursor, n_blocks)
    # last block is clamped so slices stay static-shaped (it overlaps the
    # previous block when r does not divide n; halving is not idempotent per
    # row but each call still touches exactly r rows — bounded work wins)
    row0 = jnp.minimum(cur * r, n - r).astype(jnp.int32)
    cnt_b = jax.lax.dynamic_slice(slabs.cnt, (row0, 0), (r, c))
    dst_b = jax.lax.dynamic_slice(slabs.dst, (row0, 0), (r, c))
    ord_b = jax.lax.dynamic_slice(slabs.order, (row0, 0), (r, c))
    cnt2, dst2, ord2, tot2 = ops.decay_sort(cnt_b, dst_b, ord_b, impl=cfg.impl)
    state = state._replace(
        slabs=Slabs(
            dst=jax.lax.dynamic_update_slice(slabs.dst, dst2, (row0, 0)),
            cnt=jax.lax.dynamic_update_slice(slabs.cnt, cnt2, (row0, 0)),
            tot=jax.lax.dynamic_update_slice(slabs.tot, tot2, (row0,)),
            order=jax.lax.dynamic_update_slice(slabs.order, ord2, (row0, 0))),
        decay_cursor=cur + 1,
        decay_steps=state.decay_steps + 1)
    return _dh_repair_rows(state, row0, r, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def decay(state: MCState, *, cfg: MCConfig) -> MCState:
    """§II.C decay through the kernel layer (``ops.decay_sort``).

    Stop-the-world (``decay_block_rows == 0``): halve every counter, evict
    dead edges and compact in one fused dispatch.  Rolling mode
    (``decay_block_rows == R``): halve only the cursor's R-row block and
    advance the cursor, so a serving system amortises maintenance across
    steps — per-call cost scales with R, not ``num_rows``, and readers see
    the paper's approximately-correct mid-maintenance state (some rows
    decayed, some not) instead of a stop-the-world stall.  The dst hash is
    repaired incrementally for the touched block only (``_dh_repair_rows``).
    jit wrapper over :func:`decay_impl`.
    """
    return decay_impl(state, cfg=cfg)


def maybe_decay_impl(state: MCState, *, cfg: MCConfig,
                     total_threshold: int) -> MCState:
    """Traced body of :func:`maybe_decay` (the per-shard maintenance step of
    ``core/sharded.py`` runs this under shard_map)."""
    should = jnp.any(state.slabs.tot > total_threshold)
    return jax.lax.cond(
        should, lambda s: decay_impl(s, cfg=cfg), lambda s: s, state)


def maybe_decay(state: MCState, *, cfg: MCConfig, total_threshold: int) -> MCState:
    """Decay when any row total exceeds ``total_threshold`` (paper §II.C
    suggests decaying "at some threshold over the number of total
    transitions").  In rolling mode each trigger halves one block; the
    threshold keeps firing until the offending row's block comes around, so
    pressure drains over a few calls instead of one stall."""
    return maybe_decay_impl(state, cfg=cfg, total_threshold=total_threshold)


# ---------------------------------------------------------------------------
# invariant checks (used by tests and the property suite)
# ---------------------------------------------------------------------------


def _dh_consistent(state: MCState, cfg: MCConfig) -> jax.Array:
    """Dst-hash invariant: every live slot is reachable through the hash and
    every occupied hash lane points at a live slot holding its key (no stale
    entries after decay/repair)."""
    n, c = state.slabs.dst.shape
    rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32), c)
    dsts = state.slabs.dst.reshape(-1)
    live = state.slabs.cnt.reshape(-1) > 0
    slots, found = ops.dh_find(
        jnp.where(live, rows, -1), jnp.maximum(dsts, 0),
        state.dh_keys, state.dh_vals,
        max_probes=cfg.max_probes, impl=cfg.impl)
    expect = jnp.tile(jnp.arange(c, dtype=jnp.int32), n)
    live_ok = jnp.all(jnp.where(live, found & (slots == expect), True))
    occupied = state.dh_keys >= 0
    v = jnp.clip(state.dh_vals, 0, c - 1)
    pointed_dst = jnp.take_along_axis(state.slabs.dst, v, axis=1)
    pointed_cnt = jnp.take_along_axis(state.slabs.cnt, v, axis=1)
    stale_ok = jnp.all(jnp.where(
        occupied, (pointed_dst == state.dh_keys) & (pointed_cnt > 0), True))
    return live_ok & stale_ok


def check_invariants(state: MCState, cfg: Optional[MCConfig] = None) -> dict:
    slabs = state.slabs
    order_ok = jnp.all(
        jnp.sort(slabs.order, axis=1)
        == jnp.arange(slabs.order.shape[1], dtype=jnp.int32)[None, :])
    tot_ok = jnp.all(slabs.tot == jnp.sum(slabs.cnt, axis=1))
    free_ok = jnp.all((slabs.cnt == 0) == (slabs.dst == EMPTY))
    nonneg = jnp.all(slabs.cnt >= 0)
    out = {
        "order_is_permutation": bool(order_ok),
        "tot_matches_cnt_sum": bool(tot_ok),
        "free_slots_consistent": bool(free_ok),
        "counts_nonnegative": bool(nonneg),
        "sorted_fraction": float(sl.sorted_fraction(slabs.cnt, slabs.order)),
    }
    if cfg is not None and cfg.use_dst_hash:
        out["dst_hash_consistent"] = bool(_dh_consistent(state, cfg))
    return out


def maintenance_stats(state: MCState) -> dict:
    """Maintenance observability counters (DESIGN.md §6), host-side ints."""
    return {
        "decay_steps": int(state.decay_steps),
        "decay_cursor": int(state.decay_cursor),
        "dh_rebuilds": int(state.dh_rebuilds),
        "dh_tombstones": int(state.dh_tombstones),
    }


_COUNTER_FIELDS = ("n_rows", "dropped_rows", "dropped_probes", "evictions",
                   "deferred_new", "route_dropped", "decay_steps",
                   "dh_rebuilds", "dh_tombstones")


@jax.jit
def _counter_stack(state: MCState) -> jax.Array:
    return jnp.stack([jnp.sum(getattr(state, f)) for f in _COUNTER_FIELDS])


def counter_stats(state: MCState) -> dict:
    """Every additive observability counter as a host-side int.

    Counters are summed over any leading dims, so the same helper reads a
    local ``MCState`` and the stacked per-shard state of ``core/sharded.py``
    (where each counter is ``int32[num_shards]``).  ``decay_cursor`` is a
    position, not a count, and is deliberately excluded.  The sums are one
    fused dispatch and ONE device->host transfer — callers sit on serving
    hot paths (``ShardedEngine.observe`` reads this per batch, inside its
    writer lock).
    """
    vals = jax.device_get(_counter_stack(state))
    return {f: int(v) for f, v in zip(_COUNTER_FIELDS, vals)}
