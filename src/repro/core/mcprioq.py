"""MCPrioQ: online sparse Markov chain with priority-ordered edge queries.

This is the paper's contribution as a composable JAX module (DESIGN.md §1-2).

Data layout
-----------
  * src hash table  : node-id -> row index into the slabs (open addressing)
  * slabs           : per-row stable edge slots (dst, cnt) + ``order`` perm
  * two counters    : per-edge ``cnt`` and per-row ``tot``; probability is
                      ``cnt/tot`` computed at query time (paper §II.3)
  * optional dst hash: per-row open-addressing table dst -> slot ("optional
                      optimization", paper §II.2); slots are stable so the
                      hash survives reordering, like the paper's pointers.

Update semantics (paper §II.A, TPU-batched)
-------------------------------------------
A batch of transitions is split into the paper's two cases:
  * **update of edge** (normal case): the edge already exists — a pure
    conflict-free scatter-add on (row, slot), exactly the paper's "O(1) lookup
    + atomic increment".  In-batch duplicates aggregate in the scatter.
  * **new edge** (rare case): handled by a deterministic sequential pass
    (lax.scan) that allocates rows/slots and applies Space-Saving tail
    replacement when a row is full (DESIGN.md assumption log).
Afterwards ``sort_passes`` odd-even passes restore approximate order — the
paper's lock-free bubble sort.

Inference (paper §II.B)
-----------------------
``query_threshold`` walks the order permutation accumulating probability until
the cumulative sum crosses ``t``: complexity O(CDF^-1(t)) items touched.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import hashtable as ht
from repro.core import slab as sl
from repro.core.hashtable import EMPTY, HashTable
from repro.core.slab import Slabs


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


@dataclasses.dataclass(frozen=True)
class MCConfig:
    """Static configuration (hashable; safe as a jit static arg)."""

    num_rows: int = 1024          # max distinct src nodes tracked
    capacity: int = 128           # max out-degree tracked per src (C)
    table_size: int = 0           # src hash slots; 0 -> 4 * num_rows pow2
    max_probes: int = 64
    sort_passes: int = 1          # odd-even passes per update batch
    use_dst_hash: bool = False    # paper's optional dst->slot hash table
    dst_table_size: int = 0       # per-row; 0 -> 4 * capacity pow2

    def resolved_table_size(self) -> int:
        return self.table_size or _next_pow2(4 * self.num_rows)

    def resolved_dst_table_size(self) -> int:
        return self.dst_table_size or _next_pow2(4 * self.capacity)


class MCState(NamedTuple):
    src_table: HashTable   # node-id -> row
    slabs: Slabs
    n_rows: jax.Array      # int32[]   allocated rows
    # optional per-row dst hash (zero-size arrays when disabled)
    dh_keys: jax.Array     # int32[N, H]
    dh_vals: jax.Array     # int32[N, H]
    # observability counters (drops are the price of fixed shapes; DESIGN §2)
    dropped_rows: jax.Array    # srcs dropped because num_rows exhausted
    dropped_probes: jax.Array  # items dropped on probe-window overflow
    evictions: jax.Array       # Space-Saving tail replacements


def init(cfg: MCConfig) -> MCState:
    n, c = cfg.num_rows, cfg.capacity
    h = cfg.resolved_dst_table_size() if cfg.use_dst_hash else 1
    return MCState(
        src_table=ht.make(cfg.resolved_table_size()),
        slabs=sl.make(n, c),
        n_rows=jnp.int32(0),
        dh_keys=jnp.full((n, h), EMPTY, dtype=jnp.int32),
        dh_vals=jnp.full((n, h), EMPTY, dtype=jnp.int32),
        dropped_rows=jnp.int32(0),
        dropped_probes=jnp.int32(0),
        evictions=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# per-row dst hash helpers (optional optimisation path)
# ---------------------------------------------------------------------------


def _dh_lookup(state: MCState, row: jax.Array, key: jax.Array, cfg: MCConfig):
    tab = HashTable(state.dh_keys[row], state.dh_vals[row])
    return ht.lookup(tab, key, cfg.max_probes)


def _dh_set(state: MCState, row: jax.Array, key: jax.Array, slot: jax.Array,
            active: jax.Array, cfg: MCConfig) -> MCState:
    tab = HashTable(state.dh_keys[row], state.dh_vals[row])
    new_tab, _, _ = ht.insert(tab, key, slot, cfg.max_probes)
    dh_keys = state.dh_keys.at[row].set(
        jnp.where(active, new_tab.keys, state.dh_keys[row]))
    dh_vals = state.dh_vals.at[row].set(
        jnp.where(active, new_tab.vals, state.dh_vals[row]))
    return state._replace(dh_keys=dh_keys, dh_vals=dh_vals)


def _dh_del(state: MCState, row: jax.Array, key: jax.Array,
            active: jax.Array, cfg: MCConfig) -> MCState:
    tab = HashTable(state.dh_keys[row], state.dh_vals[row])
    new_tab, _ = ht.delete(tab, key, cfg.max_probes)
    dh_keys = state.dh_keys.at[row].set(
        jnp.where(active, new_tab.keys, state.dh_keys[row]))
    return state._replace(dh_keys=dh_keys)


def _dh_rebuild_all(state: MCState, cfg: MCConfig) -> MCState:
    """Vectorised rebuild of every row hash from the slabs (used after decay).

    Rows are independent, so a vmap over rows of a sequential slot-insert loop
    is conflict-free.
    """
    if not cfg.use_dst_hash:
        return state
    h = cfg.resolved_dst_table_size()

    def rebuild_row(dsts, cnts):
        tab = ht.make(h)

        def body(i, tab):
            new_tab, _, _ = ht.insert(tab, dsts[i], jnp.int32(i), cfg.max_probes)
            live = cnts[i] > 0
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(live, n, o), new_tab, tab)

        return jax.lax.fori_loop(0, dsts.shape[0], body, tab)

    tabs = jax.vmap(rebuild_row)(state.slabs.dst, state.slabs.cnt)
    return state._replace(dh_keys=tabs.keys, dh_vals=tabs.vals)


# ---------------------------------------------------------------------------
# lookups
# ---------------------------------------------------------------------------


def lookup_rows(state: MCState, src: jax.Array, cfg: MCConfig):
    """Batched src -> row. Returns ``(rows[B], found[B])``; row 0 when missing."""
    rows, found = ht.lookup_batch(state.src_table, src, cfg.max_probes)
    return jnp.where(found, rows, 0), found


def _find_slots(state: MCState, rows: jax.Array, dst: jax.Array, cfg: MCConfig):
    """Batched (row, dst) -> slot via dst-hash or row scan (paper §II.2)."""
    if cfg.use_dst_hash:
        slots, found = jax.vmap(
            lambda r, d: _dh_lookup(state, r, d, cfg))(rows, dst)
        return jnp.where(found, slots, 0), found
    slots, found = jax.vmap(
        lambda r, d: sl.find_slot(state.slabs, r, d))(rows, dst)
    return slots, found


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def _slow_path(state: MCState, src, dst, w, active, cfg: MCConfig) -> MCState:
    """Sequential insert pass for new edges / new rows (the paper's rare case).

    Deterministic (batch order), fully masked — inactive items are no-ops.
    """
    n_cap = cfg.num_rows

    def step(state: MCState, item):
        s, d, wi, a = item
        # --- src row (lookup or allocate) -------------------------------
        row0, found_src = ht.lookup(state.src_table, s, cfg.max_probes)
        can_alloc = state.n_rows < n_cap
        do_alloc = a & ~found_src & can_alloc
        row = jnp.where(found_src, row0, state.n_rows)
        new_tab, _, ins_ok = ht.insert(state.src_table, s, row, cfg.max_probes)
        take_ins = do_alloc & ins_ok
        src_table = jax.tree_util.tree_map(
            lambda n, o: jnp.where(take_ins, n, o), new_tab, state.src_table)
        n_rows = state.n_rows + jnp.where(take_ins, 1, 0)
        dropped_rows = state.dropped_rows + jnp.where(a & ~found_src & ~can_alloc, 1, 0)
        dropped_probes = state.dropped_probes + jnp.where(do_alloc & ~ins_ok, 1, 0)
        have_row = found_src | take_ins
        act = a & have_row
        row = jnp.where(have_row, row, 0)

        # --- dst slot (find / free / Space-Saving tail replace) ---------
        slabs = state.slabs
        slot_eq, found_d = sl.find_slot(slabs, row, d)
        slot_free, has_free = sl.free_slot(slabs, row)
        victim = sl.tail_slot(slabs, row)
        slot = jnp.where(found_d, slot_eq, jnp.where(has_free, slot_free, victim))
        replace = act & ~found_d & ~has_free
        evicted_dst = slabs.dst[row, slot]
        # Space-Saving: the newcomer inherits the evicted count (overestimate)
        base = jnp.where(found_d, slabs.cnt[row, slot],
                         jnp.where(has_free, 0, slabs.cnt[row, slot]))
        new_c = base + wi
        cnt = slabs.cnt.at[row, slot].set(jnp.where(act, new_c, slabs.cnt[row, slot]))
        dstv = slabs.dst.at[row, slot].set(jnp.where(act, d, slabs.dst[row, slot]))
        tot = slabs.tot.at[row].add(jnp.where(act, wi, 0))
        slabs = Slabs(dst=dstv, cnt=cnt, tot=tot, order=slabs.order)
        state = state._replace(
            src_table=src_table, slabs=slabs, n_rows=n_rows,
            dropped_rows=dropped_rows, dropped_probes=dropped_probes,
            evictions=state.evictions + jnp.where(replace, 1, 0))
        if cfg.use_dst_hash:
            state = _dh_del(state, row, evicted_dst, replace, cfg)
            state = _dh_set(state, row, d, slot, act & ~found_d, cfg)
        return state, None

    state, _ = jax.lax.scan(step, state, (src, dst, w, active))
    return state


@functools.partial(jax.jit, static_argnames=("cfg",))
def update_batch(
    state: MCState,
    src: jax.Array,
    dst: jax.Array,
    weights: Optional[jax.Array] = None,
    mask: Optional[jax.Array] = None,
    *,
    cfg: MCConfig,
) -> MCState:
    """Apply a batch of transitions ``src[i] -> dst[i]`` (paper §II.A).

    Fast path (existing edges): one conflict-free scatter-add — the batched
    equivalent of the paper's atomic fetch-add.  Slow path (new edges): the
    sequential pass above.  Then ``cfg.sort_passes`` odd-even passes.
    """
    b = src.shape[0]
    w = jnp.ones((b,), jnp.int32) if weights is None else weights.astype(jnp.int32)
    m = jnp.ones((b,), bool) if mask is None else mask
    m = m & (src >= 0) & (dst >= 0)

    # classify against the pre-state: edge exists <=> fast
    rows0, found_src0 = lookup_rows(state, src, cfg)
    slots0, found_d0 = _find_slots(state, rows0, dst, cfg)
    fast = m & found_src0 & found_d0

    # fast path: scatter-add (duplicates aggregate, like contended atomics)
    add_w = jnp.where(fast, w, 0)
    slabs = state.slabs
    cnt = slabs.cnt.at[rows0, slots0].add(add_w)
    tot = slabs.tot.at[rows0].add(add_w)
    state = state._replace(slabs=Slabs(slabs.dst, cnt, tot, slabs.order))

    # slow path: everything else, sequential + masked
    state = _slow_path(state, src, dst, w, m & ~fast, cfg)

    # lock-free bubble sort, vectorised
    slabs = state.slabs
    order = sl.oddeven_passes(slabs.cnt, slabs.order, cfg.sort_passes)
    return state._replace(slabs=Slabs(slabs.dst, slabs.cnt, slabs.tot, order))


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "max_items"))
def query_threshold(
    state: MCState,
    src: jax.Array,
    threshold: float,
    *,
    cfg: MCConfig,
    max_items: int = 16,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Items in descending probability until cumulative prob >= threshold.

    Returns ``(dsts[B, max_items], probs[B, max_items], n_needed[B])`` where
    entries past ``n_needed`` are EMPTY/0.  ``n_needed`` is the paper's
    CDF^-1(t): how many items a reader must touch.  Unknown srcs yield 0.
    """
    rows, found = lookup_rows(state, src, cfg)
    order = state.slabs.order[rows]                       # [B, C]
    c = jnp.take_along_axis(state.slabs.cnt[rows], order, axis=1)
    d = jnp.take_along_axis(state.slabs.dst[rows], order, axis=1)
    tot = jnp.maximum(state.slabs.tot[rows], 1).astype(jnp.float32)
    p = c.astype(jnp.float32) / tot[:, None]
    cum = jnp.cumsum(p, axis=1)
    # item i is needed if the cumulative sum *before* it is < t and it is live
    before = cum - p
    needed = (before < threshold) & (c > 0) & found[:, None]
    n_needed = jnp.sum(needed.astype(jnp.int32), axis=1)
    k = max_items
    dk, pk, nk = d[:, :k], p[:, :k], needed[:, :k]
    dk = jnp.where(nk, dk, EMPTY)
    pk = jnp.where(nk, pk, 0.0)
    return dk, pk, n_needed


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def query_topk(state: MCState, src: jax.Array, *, cfg: MCConfig, k: int = 8):
    """Top-k edges by (approximate) probability. ``(dsts[B,k], probs[B,k])``."""
    rows, found = lookup_rows(state, src, cfg)
    order = state.slabs.order[rows][:, :k]
    c = jnp.take_along_axis(state.slabs.cnt[rows], order, axis=1)
    d = jnp.take_along_axis(state.slabs.dst[rows], order, axis=1)
    tot = jnp.maximum(state.slabs.tot[rows], 1).astype(jnp.float32)
    p = c.astype(jnp.float32) / tot[:, None]
    live = (c > 0) & found[:, None]
    return jnp.where(live, d, EMPTY), jnp.where(live, p, 0.0)


# ---------------------------------------------------------------------------
# decay (paper §II.C)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def decay(state: MCState, *, cfg: MCConfig) -> MCState:
    """Halve all counters, evict dead edges, compact, rebuild dst hashes."""
    slabs, _ = sl.decay(state.slabs)
    state = state._replace(slabs=slabs)
    return _dh_rebuild_all(state, cfg)


def maybe_decay(state: MCState, *, cfg: MCConfig, total_threshold: int) -> MCState:
    """Decay when any row total exceeds ``total_threshold`` (paper §II.C
    suggests decaying "at some threshold over the number of total
    transitions")."""
    should = jnp.any(state.slabs.tot > total_threshold)
    return jax.lax.cond(
        should, lambda s: decay(s, cfg=cfg), lambda s: s, state)


# ---------------------------------------------------------------------------
# invariant checks (used by tests and the property suite)
# ---------------------------------------------------------------------------


def check_invariants(state: MCState) -> dict:
    slabs = state.slabs
    order_ok = jnp.all(
        jnp.sort(slabs.order, axis=1)
        == jnp.arange(slabs.order.shape[1], dtype=jnp.int32)[None, :])
    tot_ok = jnp.all(slabs.tot == jnp.sum(slabs.cnt, axis=1))
    free_ok = jnp.all((slabs.cnt == 0) == (slabs.dst == EMPTY))
    nonneg = jnp.all(slabs.cnt >= 0)
    return {
        "order_is_permutation": bool(order_ok),
        "tot_matches_cnt_sum": bool(tot_ok),
        "free_slots_consistent": bool(free_ok),
        "counts_nonnegative": bool(nonneg),
        "sorted_fraction": float(sl.sorted_fraction(slabs.cnt, slabs.order)),
    }
