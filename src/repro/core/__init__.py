"""MCPrioQ core: the paper's contribution as a composable JAX library.

Public API:
  * :mod:`repro.core.mcprioq` — single-shard structure (init/update/query/decay)
  * :mod:`repro.core.sharded` — mesh-sharded variant (all_to_all routing)
  * :mod:`repro.core.epoch` — RCU-analogue snapshot store for serving
  * :mod:`repro.core.speculative` — online n-gram drafter for LM serving
"""

from repro.core.mcprioq import (  # noqa: F401
    MCConfig,
    MCState,
    decay,
    init,
    maybe_decay,
    query_threshold,
    query_topk,
    update_batch,
    update_batch_reference,
)
