"""Mesh-sharded MCPrioQ: node-space partitioning with all_to_all routing.

The paper scales by lock-free concurrency on one cache-coherent host.  On a
TPU pod the equivalent scale-out axis is *node-space sharding*: every shard
owns ``hash(src) % num_shards`` of the graph, a global update batch is routed
to owner shards with a fixed-capacity ``all_to_all`` (the same dispatch shape
as MoE expert-parallel routing), and each shard applies its local
``update_batch``.  Queries route the same way and the answers are routed back.

Fixed per-destination bucket capacity keeps shapes static (overflowed items
are dropped and counted, like the paper's "approximately correct" reads —
the observability counter makes the approximation measurable).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mcprioq as mc
from repro.core.hashtable import EMPTY, hash_u32


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    base: mc.MCConfig
    num_shards: int
    axis: str = "shard"
    bucket_factor: float = 2.0  # capacity = factor * fair share

    def bucket_capacity(self, local_batch: int) -> int:
        fair = max(1, local_batch // self.num_shards)
        return int(self.bucket_factor * fair)


def owner_of(src: jax.Array, num_shards: int) -> jax.Array:
    """Owner shard of a node id. Uses the high mix bits so the src hash table
    inside each shard (which uses the low bits) stays well distributed."""
    return ((hash_u32(src) >> jnp.uint32(8)) % jnp.uint32(num_shards)).astype(jnp.int32)


def init_sharded(cfg: ShardedConfig, mesh: jax.sharding.Mesh) -> mc.MCState:
    """Global state: every array gains a leading ``num_shards`` dim, sharded
    over ``cfg.axis``. Inside shard_map each shard sees its own MCState."""
    one = mc.init(cfg.base)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_shards,) + x.shape), one)
    sharding = jax.sharding.NamedSharding(mesh, P(cfg.axis))
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sharding), stacked)


# ---------------------------------------------------------------------------
# bucket building (per-shard local work)
# ---------------------------------------------------------------------------


def _build_buckets(vals_list, owner: jax.Array, num_shards: int, cap: int):
    """Scatter items into [num_shards, cap] send buckets grouped by owner.

    Returns (buckets..., pos, dropped) where ``pos[i]`` is the in-bucket slot
    of item i (>= cap means dropped). Deterministic: stable sort by owner.
    """
    b = owner.shape[0]
    sort_idx = jnp.argsort(owner, stable=True)
    owner_s = owner[sort_idx]
    starts = jnp.searchsorted(owner_s, jnp.arange(num_shards, dtype=owner.dtype))
    pos_s = jnp.arange(b, dtype=jnp.int32) - starts[owner_s]
    outs = []
    for v in vals_list:
        buf = jnp.full((num_shards, cap) + v.shape[1:], EMPTY, v.dtype)
        # out-of-capacity positions fall off via mode="drop"
        buf = buf.at[owner_s, pos_s].set(v[sort_idx], mode="drop")
        outs.append(buf)
    # per-item position in original order
    pos = jnp.zeros((b,), jnp.int32).at[sort_idx].set(pos_s)
    dropped = jnp.sum((pos_s >= cap).astype(jnp.int32))
    return outs, pos, dropped


# ---------------------------------------------------------------------------
# distributed update / query (call under shard_map; wrappers below)
# ---------------------------------------------------------------------------


def _update_local(state, src, dst, w, scfg: ShardedConfig):
    """Per-shard body: route then apply. ``state`` leading dim is 1."""
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    n, cap = scfg.num_shards, scfg.bucket_capacity(src.shape[0])
    (bsrc, bdst, bw), _, dropped = _build_buckets(
        [src, dst, w], owner_of(src, n), n, cap)
    rsrc = jax.lax.all_to_all(bsrc, scfg.axis, 0, 0, tiled=True)
    rdst = jax.lax.all_to_all(bdst, scfg.axis, 0, 0, tiled=True)
    rw = jax.lax.all_to_all(bw, scfg.axis, 0, 0, tiled=True)
    rsrc, rdst, rw = (x.reshape(-1) for x in (rsrc, rdst, rw))
    state = mc.update_batch(state, rsrc, rdst, weights=rw,
                            mask=rsrc != EMPTY, cfg=scfg.base)
    state = state._replace(dropped_probes=state.dropped_probes + dropped)
    return jax.tree_util.tree_map(lambda x: x[None], state)


def _query_local(state, src, threshold, max_items, scfg: ShardedConfig):
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    n, cap = scfg.num_shards, scfg.bucket_capacity(src.shape[0])
    (bsrc,), pos, _ = _build_buckets([src], owner_of(src, n), n, cap)
    rsrc = jax.lax.all_to_all(bsrc, scfg.axis, 0, 0, tiled=True)
    d, p, need = mc.query_threshold(
        state, rsrc.reshape(-1), threshold, cfg=scfg.base, max_items=max_items)
    d = d.reshape(n, cap, max_items)
    p = p.reshape(n, cap, max_items)
    need = need.reshape(n, cap)
    # route answers back to the requesting shard
    d = jax.lax.all_to_all(d, scfg.axis, 0, 0, tiled=True)
    p = jax.lax.all_to_all(p, scfg.axis, 0, 0, tiled=True)
    need = jax.lax.all_to_all(need, scfg.axis, 0, 0, tiled=True)
    # un-permute: item i sits at [owner[i], pos[i]]
    own = owner_of(src, n)
    ok = pos < cap
    gi = jnp.clip(pos, 0, cap - 1)
    di = d[own, gi]
    pi = p[own, gi]
    ni = need[own, gi]
    di = jnp.where(ok[:, None], di, EMPTY)
    pi = jnp.where(ok[:, None], pi, 0.0)
    ni = jnp.where(ok, ni, 0)
    return di, pi, ni


# ---------------------------------------------------------------------------
# public pjit-able wrappers
# ---------------------------------------------------------------------------


def make_update_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh):
    """Returns jitted ``(state, src[B], dst[B], w[B]) -> state`` with batch
    data-sharded over the shard axis and state node-sharded."""
    a = scfg.axis
    state_spec = jax.tree_util.tree_map(lambda _: P(a), mc.init(scfg.base))

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(state_spec, P(a), P(a), P(a)), out_specs=state_spec)
    def fn(state, src, dst, w):
        return _update_local(state, src, dst, w, scfg)

    return jax.jit(fn)


def make_query_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh,
                  threshold: float, max_items: int):
    a = scfg.axis
    state_spec = jax.tree_util.tree_map(lambda _: P(a), mc.init(scfg.base))

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(state_spec, P(a)), out_specs=(P(a), P(a), P(a)))
    def fn(state, src):
        return _query_local(state, src, threshold, max_items, scfg)

    return jax.jit(fn)
