"""Mesh-sharded MCPrioQ: node-space partitioning with all_to_all routing.

The paper scales by lock-free concurrency on one cache-coherent host.  On a
TPU pod the equivalent scale-out axis is *node-space sharding*: every shard
owns a slice of the graph under the two-level ownership map (hash ->
virtual bucket -> shard, :class:`repro.sharding.ownership.Ownership`;
DESIGN.md §10), a global update batch is routed to owner shards with a
fixed-capacity ``all_to_all`` (the same dispatch shape as MoE
expert-parallel routing), and each shard applies its local update.  Queries
route the same way and the answers are routed back.  The bucket indirection
is what makes the chain *elastic*: reassigning a bucket (rebalancing) or
re-deriving the table at M shards (reshard-on-restore, ``persist/``) moves
keys without touching the routing machinery.

Every per-shard body dispatches the kernel layer directly (DESIGN.md §9):
``_update_local`` runs the pre-aggregated ``ops.slab_update`` pipeline via
:func:`repro.core.mcprioq.update_batch_impl`, ``_query_local`` the fused
``ops.ht_find`` probe + ``ops.cdf_query_fused`` walk via
:func:`repro.core.mcprioq.query_impl`, and ``_maintain_local`` the rolling
``ops.decay_sort`` block decay via :func:`repro.core.mcprioq.decay_impl` —
each shard keeps its own ``decay_cursor``, so maintenance stays O(block) per
call on every shard independently.  The impl bodies carry no jit boundary of
their own: the kernels inline straight into the shard_map program.

Fixed per-destination bucket capacity keeps shapes static (overflowed items
are dropped and counted in ``route_dropped`` / the query drop output, like
the paper's "approximately correct" reads — the observability counter makes
the approximation measurable).

Cross-shard reads: :func:`make_topn_fn` answers the paper's headline query
*globally* — each shard emits its local top-n (per-row priority windows +
one ``lax.top_k``), the answers are all_gathered and k-way merged by
probability (``ops.topn_merge``), returning globally descending n items.
A shard can contribute at most n items to a global top-n, so truncating each
local answer to n is exact relative to each shard's priority order; the
``dropped`` output counts live edges a shard could not expose to the merge
(the fixed-capacity drop model's observability).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mcprioq as mc
from repro.core.hashtable import EMPTY
from repro.kernels import ops
from repro.sharding.ownership import Ownership


@dataclasses.dataclass(frozen=True)
class ShardedConfig:
    base: mc.MCConfig
    num_shards: int
    axis: str = "shard"
    bucket_factor: float = 2.0  # capacity = factor * fair share
    # two-level hash -> virtual bucket -> shard map (DESIGN.md §10); None =
    # the default assignment, which reproduces the legacy static hash for
    # power-of-two shard counts
    ownership: Optional[Ownership] = None

    def bucket_capacity(self, local_batch: int) -> int:
        fair = max(1, local_batch // self.num_shards)
        # never 0: zero-width buckets can route nothing (and break gathers)
        return max(1, int(self.bucket_factor * fair))

    def resolved_ownership(self) -> Ownership:
        own = self.ownership or Ownership(num_shards=self.num_shards)
        if own.num_shards != self.num_shards:
            raise ValueError(
                f"ownership maps {own.num_shards} shards but config has "
                f"{self.num_shards}")
        return own


def owner_of(src: jax.Array, num_shards: int) -> jax.Array:
    """Owner shard of a node id under the *default* two-level map (kept as
    the module-level convenience; routed configs use
    ``ShardedConfig.resolved_ownership().owner_of``)."""
    return Ownership(num_shards=num_shards).owner_of(src)


def init_sharded(cfg: ShardedConfig, mesh: jax.sharding.Mesh) -> mc.MCState:
    """Global state: every array gains a leading ``num_shards`` dim, sharded
    over ``cfg.axis``. Inside shard_map each shard sees its own MCState."""
    one = mc.init(cfg.base)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_shards,) + x.shape), one)
    sharding = jax.sharding.NamedSharding(mesh, P(cfg.axis))
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sharding), stacked)


def _state_spec(scfg: ShardedConfig):
    return jax.tree_util.tree_map(lambda _: P(scfg.axis), mc.init(scfg.base))


# ---------------------------------------------------------------------------
# bucket building (per-shard local work)
# ---------------------------------------------------------------------------


def _build_buckets(vals_list, owner: jax.Array, num_shards: int, cap: int,
                   active: jax.Array = None):
    """Scatter items into [num_shards, cap] send buckets grouped by owner.

    Returns (buckets..., pos, dropped) where ``pos[i]`` is the in-bucket slot
    of item i (>= cap means dropped). Deterministic: stable sort by owner.
    Inactive items (``active`` False — batch padding) are routed to a
    nonexistent shard: they consume no bucket capacity, never displace real
    items, and are excluded from the drop count (their ``pos`` is garbage;
    callers must mask on ``active``).
    """
    b = owner.shape[0]
    if active is not None:
        owner = jnp.where(active, owner, num_shards)
    sort_idx = jnp.argsort(owner, stable=True)
    owner_s = owner[sort_idx]
    starts = jnp.searchsorted(owner_s, jnp.arange(num_shards, dtype=owner.dtype))
    pos_s = (jnp.arange(b, dtype=jnp.int32)
             - starts[jnp.minimum(owner_s, num_shards - 1)])
    outs = []
    for v in vals_list:
        buf = jnp.full((num_shards, cap) + v.shape[1:], EMPTY, v.dtype)
        # out-of-capacity positions (and inactive items) fall off via "drop"
        buf = buf.at[owner_s, pos_s].set(v[sort_idx], mode="drop")
        outs.append(buf)
    # per-item position in original order
    pos = jnp.zeros((b,), jnp.int32).at[sort_idx].set(pos_s)
    real = owner_s < num_shards
    dropped = jnp.sum(((pos_s >= cap) & real).astype(jnp.int32))
    return outs, pos, dropped


def predict_route_overflow(scfg: ShardedConfig, src) -> "np.ndarray":
    """Host-side mirror of :func:`_build_buckets`'s capacity drop decision.

    ``src`` must already be padded to a multiple of ``num_shards`` (the
    engine's ``_pad`` contract): the sharded batch splits into
    ``num_shards`` contiguous sender slices of length ``B/num_shards``,
    and each slice independently drops the items ranked ``>= cap`` within
    their owner group (stable order).  Returns a bool mask, True exactly
    where the device update/query path would drop the item — the overflow
    retry tier masks those items out *before* dispatch and resubmits them
    next step, so ``route_dropped`` stays 0 while the tier is on.

    Must stay bit-faithful to ``_build_buckets`` (same stable sort, same
    searchsorted starts, same ``bucket_capacity``); the fault-matrix test
    asserts prediction == device behaviour over random skewed batches.
    """
    import numpy as np  # host-only helper; keep the module's jnp surface

    src = np.asarray(src)
    n = scfg.num_shards
    if src.size % n:
        raise ValueError(f"batch of {src.size} not padded to a multiple "
                         f"of num_shards={n}")
    local = src.size // n
    cap = scfg.bucket_capacity(local)
    owner = np.asarray(scfg.resolved_ownership().owner_of(
        jnp.asarray(src, jnp.int32)))
    active = src >= 0
    owner = np.where(active, owner, n)
    out = np.zeros(src.size, dtype=bool)
    for s in range(n):
        sl = slice(s * local, (s + 1) * local)
        own_s = owner[sl]
        sort_idx = np.argsort(own_s, kind="stable")
        owner_sorted = own_s[sort_idx]
        starts = np.searchsorted(owner_sorted, np.arange(n))
        pos_s = (np.arange(local)
                 - starts[np.minimum(owner_sorted, n - 1)])
        drop_sorted = (pos_s >= cap) & (owner_sorted < n)
        drop = np.zeros(local, dtype=bool)
        drop[sort_idx] = drop_sorted
        out[sl] = drop
    return out


def _src_of_row(state: mc.MCState, num_rows: int) -> jax.Array:
    """Reverse map row -> src node id, rebuilt from the src hash table by one
    scatter (invalid table lanes fall off via an out-of-range index)."""
    tab = state.src_table
    valid = (tab.keys >= 0) & (tab.vals >= 0)
    idx = jnp.where(valid, tab.vals, num_rows)
    return jnp.full((num_rows,), EMPTY, jnp.int32).at[idx].set(
        tab.keys, mode="drop")


# ---------------------------------------------------------------------------
# per-shard bodies (call under shard_map; wrappers below)
# ---------------------------------------------------------------------------


def _update_local(state, src, dst, w, scfg: ShardedConfig):
    """Per-shard body: route then apply the kernel-routed update pipeline
    (pre-aggregation + ``ops.slab_update`` + bounded slow path +
    ``ops.oddeven_sort`` via ``update_batch_impl``).  ``state`` leading dim
    is 1; bucket-overflow drops land in ``route_dropped``."""
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    n, cap = scfg.num_shards, scfg.bucket_capacity(src.shape[0])
    (bsrc, bdst, bw), _, dropped = _build_buckets(
        [src, dst, w], scfg.resolved_ownership().owner_of(src), n, cap,
        active=src >= 0)
    rsrc = jax.lax.all_to_all(bsrc, scfg.axis, 0, 0, tiled=True)
    rdst = jax.lax.all_to_all(bdst, scfg.axis, 0, 0, tiled=True)
    rw = jax.lax.all_to_all(bw, scfg.axis, 0, 0, tiled=True)
    rsrc, rdst, rw = (x.reshape(-1) for x in (rsrc, rdst, rw))
    state = mc.update_batch_impl(state, rsrc, rdst, weights=rw,
                                 mask=rsrc != EMPTY, cfg=scfg.base)
    state = state._replace(route_dropped=state.route_dropped + dropped)
    return jax.tree_util.tree_map(lambda x: x[None], state)


def _query_local(state, src, threshold, max_items, scfg: ShardedConfig):
    """Per-shard body: route queries to owners, answer through the fused
    kernel read path (``query_impl``), route answers back.  Returns
    ``(dsts, probs, n_needed, dropped[1])`` — ``dropped`` counts queries
    this shard could not route (bucket overflow; answers are EMPTY/0)."""
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    n, cap = scfg.num_shards, scfg.bucket_capacity(src.shape[0])
    act = src >= 0
    (bsrc,), pos, dropped = _build_buckets(
        [src], scfg.resolved_ownership().owner_of(src), n, cap, active=act)
    rsrc = jax.lax.all_to_all(bsrc, scfg.axis, 0, 0, tiled=True)
    d, p, need = mc.query_impl(
        state, rsrc.reshape(-1), threshold, scfg.base, max_items)
    d = d.reshape(n, cap, max_items)
    p = p.reshape(n, cap, max_items)
    need = need.reshape(n, cap)
    # route answers back to the requesting shard
    d = jax.lax.all_to_all(d, scfg.axis, 0, 0, tiled=True)
    p = jax.lax.all_to_all(p, scfg.axis, 0, 0, tiled=True)
    need = jax.lax.all_to_all(need, scfg.axis, 0, 0, tiled=True)
    # un-permute: item i sits at [owner[i], pos[i]]
    own = scfg.resolved_ownership().owner_of(src)
    ok = (pos < cap) & (pos >= 0) & act
    gi = jnp.clip(pos, 0, cap - 1)
    di = d[own, gi]
    pi = p[own, gi]
    ni = need[own, gi]
    di = jnp.where(ok[:, None], di, EMPTY)
    pi = jnp.where(ok[:, None], pi, 0.0)
    ni = jnp.where(ok, ni, 0)
    return di, pi, ni, dropped[None]


def _maintain_local(state, scfg: ShardedConfig, total_threshold: int):
    """Per-shard §II.C maintenance: rolling ``ops.decay_sort`` block decay
    behind the row-total trigger.  Each shard carries its own
    ``decay_cursor``, so per-call cost is O(decay_block_rows) everywhere."""
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    state = mc.maybe_decay_impl(state, cfg=scfg.base,
                                total_threshold=total_threshold)
    return jax.tree_util.tree_map(lambda x: x[None], state)


def _decay_local(state, scfg: ShardedConfig):
    """Per-shard unconditional decay step (one rolling block per shard)."""
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    state = mc.decay_impl(state, cfg=scfg.base)
    return jax.tree_util.tree_map(lambda x: x[None], state)


def _topn_local(state, n: int, scfg: ShardedConfig):
    """Per-shard body of the global top-n read (DESIGN.md §9).

    Local answer: each row exposes its ``min(n, C)``-item priority window
    (one order gather), a single ``lax.top_k`` over the flattened windows
    picks the shard's n best edges, and the row -> src reverse map labels
    them.  Cross-shard: all_gather the S local answers and k-way merge by
    probability (``ops.topn_merge``).  ``dropped`` counts live edges not
    exposed to the merge — exactness is bounded by the approximate order,
    not by the truncation (a shard contributes at most n items globally).
    """
    cfg = scfg.base
    state = jax.tree_util.tree_map(lambda x: x[0], state)
    slabs = state.slabs
    k = min(n, cfg.capacity)
    ord_k = slabs.order[:, :k]                           # [N, k] heads
    cnt_k = jnp.take_along_axis(slabs.cnt, ord_k, axis=1)
    dst_k = jnp.take_along_axis(slabs.dst, ord_k, axis=1)
    totf = jnp.maximum(slabs.tot, 1).astype(jnp.float32)
    prob_k = jnp.where(cnt_k > 0,
                       cnt_k.astype(jnp.float32) / totf[:, None], 0.0)
    src_of_row = _src_of_row(state, cfg.num_rows)        # [N]
    top_p, top_i = jax.lax.top_k(prob_k.reshape(-1), n)
    live_top = top_p > 0
    top_dst = jnp.where(live_top, dst_k.reshape(-1)[top_i], EMPTY)
    top_src = jnp.where(live_top, src_of_row[top_i // k], EMPTY)
    live = jnp.sum((slabs.cnt > 0).astype(jnp.int32))
    dropped = live - jnp.sum(live_top.astype(jnp.int32))
    ps = jax.lax.all_gather(top_p, scfg.axis)            # [S, n] each
    ds = jax.lax.all_gather(top_dst, scfg.axis)
    ss = jax.lax.all_gather(top_src, scfg.axis)
    m_src, m_dst, m_p = ops.topn_merge(ps, ds, ss, n=n, impl=cfg.impl)
    return m_src, m_dst, m_p, jax.lax.psum(dropped, scfg.axis)


# ---------------------------------------------------------------------------
# public pjit-able wrappers
# ---------------------------------------------------------------------------


def make_update_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh):
    """Returns jitted ``(state, src[B], dst[B], w[B]) -> state`` with batch
    data-sharded over the shard axis and state node-sharded."""
    a = scfg.axis
    state_spec = _state_spec(scfg)

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(state_spec, P(a), P(a), P(a)), out_specs=state_spec)
    def fn(state, src, dst, w):
        return _update_local(state, src, dst, w, scfg)

    return jax.jit(fn)


def make_query_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh,
                  threshold: float, max_items: int):
    """Returns jitted ``(state, src[B]) -> (dsts[B, max_items],
    probs[B, max_items], n_needed[B], dropped[num_shards])``; ``dropped``
    counts queries lost to bucket overflow, per requesting shard."""
    a = scfg.axis
    state_spec = _state_spec(scfg)

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(state_spec, P(a)), out_specs=(P(a), P(a), P(a), P(a)))
    def fn(state, src):
        return _query_local(state, src, threshold, max_items, scfg)

    return jax.jit(fn)


def make_maintain_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh,
                     total_threshold: int):
    """Returns jitted ``state -> state`` running the per-shard rolling
    maintenance step (decay one block on every shard whose row totals
    crossed ``total_threshold``)."""
    state_spec = _state_spec(scfg)

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(state_spec,), out_specs=state_spec)
    def fn(state):
        return _maintain_local(state, scfg, total_threshold)

    return jax.jit(fn)


def make_decay_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh):
    """Returns jitted ``state -> state``: one unconditional decay step per
    shard (rolling block when ``decay_block_rows`` is set)."""
    state_spec = _state_spec(scfg)

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(state_spec,), out_specs=state_spec)
    def fn(state):
        return _decay_local(state, scfg)

    return jax.jit(fn)


def make_topn_fn(scfg: ShardedConfig, mesh: jax.sharding.Mesh, n: int):
    """Returns jitted ``state -> (srcs[n], dsts[n], probs[n], dropped)``:
    the globally descending top-n edges of the whole sharded chain, plus the
    count of live edges the shards could not expose to the merge.  Outputs
    are replicated (every shard computes the same merge)."""
    state_spec = _state_spec(scfg)

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(state_spec,), out_specs=(P(), P(), P(), P()))
    def fn(state):
        return _topn_local(state, n, scfg)

    return jax.jit(fn)
