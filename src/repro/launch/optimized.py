import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Final optimized sweep: per-cell best variants from the §Perf hillclimb.

Baseline (paper-faithful sharding) and optimized runs are recorded
SEPARATELY (results/dryrun vs results/dryrun_opt) so the reproduction and
the beyond-paper gains are both visible (brief requirement).

Variant policy (derived in EXPERIMENTS.md §Perf):
  * train/prefill, dense-family archs  -> fsdp2d  (no TP activation traffic)
  * train/prefill, MoE archs           -> moe_ep  (EP all_to_all dispatch)
  * decode, every arch with KV caches  -> sp_attn (+ moe_ep for MoE)
  * ssm decode (no attention)          -> baseline already optimal

    PYTHONPATH=src python -m repro.launch.optimized [--multi-pod] \
        --out results/dryrun_opt
"""

import argparse
import json
import traceback

from repro.configs import get_config
from repro.launch import cells as cells_mod
from repro.launch.dryrun import lower_cell

MOE = ("moonshot-v1-16b-a3b", "deepseek-moe-16b")


def best_variant(arch: str, shape: str) -> str:
    """Measured-best variant per cell class (EXPERIMENTS §Perf).

    Negative results are honored: fsdp2d only helps when the global batch
    covers every chip (train_4k: 256 seqs == 256 chips; prefill's batch 32
    cannot, and fsdp2d regressed 10-90x there); sp_attn only helps when the
    cache is seq-sharded (kv_heads % 16 != 0) and the batch splits the data
    axis; EP MoE pays off for train/prefill token volumes, not single-token
    decode.
    """
    cfg = get_config(arch)
    kind = "train" if shape == "train_4k" else (
        "prefill" if shape == "prefill_32k" else "decode")
    cell = cells_mod.cell_of(arch, shape)
    parts = []
    if arch in MOE:
        if kind in ("train", "prefill"):
            parts.append("moe_ep")
    elif kind == "train":
        parts.append("fsdp2d")
    if (kind == "decode" and cfg.pattern != ("ssm",)
            and cfg.num_kv_heads % 16 != 0
            and cell is not None and cell.batch % 16 == 0):
        parts.append("sp_attn")
    return ",".join(parts) if parts else "baseline"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun_opt")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch, shape, cell in cells_mod.all_cells():
        variant = best_variant(arch, shape)
        try:
            res = lower_cell(arch, shape, args.multi_pod, variant=variant)
        except Exception as e:
            res = {"arch": arch, "shape": shape, "status": "FAILED",
                   "variant": variant, "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
            n_fail += 1
        res["variant"] = variant
        print(json.dumps({k: v for k, v in res.items() if k != "trace"}),
              flush=True)
        tag = "multipod" if args.multi_pod else "singlepod"
        fname = f"{arch}__{shape}__{tag}.json".replace("/", "_")
        with open(os.path.join(args.out, fname), "w") as f:
            json.dump(res, f, indent=1)
    print(f"\noptimized sweep done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
