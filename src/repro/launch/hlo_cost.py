"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 88 layers contributes its body a single time, undercounting
FLOPs/bytes/collective traffic by the trip count.  This module re-derives the
three roofline inputs from ``compiled.as_text()`` with loop multipliers:

  * parse every computation into (instructions, shapes, ops);
  * recover each while loop's trip count from its condition computation
    (the canonical counted-loop pattern: ``compare(iter, constant(N))``);
  * propagate multipliers from ENTRY through while bodies / fusions / calls;
  * FLOPs  = 2 * prod(result dims) * prod(contracting dims) per ``dot``
             (the MFU convention: matmul flops; elementwise ignored);
  * bytes  = operand + result bytes of top-level (post-fusion) instructions —
             a buffer-traffic model of HBM;
  * collective bytes per class with the ring model (roofline.py).

Validated against ``cost_analysis()`` on unrolled references in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_REF = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Fusion-aware HBM model.  The CPU backend fuses far less than TPU (its
# `fusion` ops wrap 2-3 elementwise ops each), so counting every op or even
# every CPU-fusion boundary overstates HBM traffic by 10-100x vs a real TPU
# executable.  The model counts the buffers a TPU program genuinely moves:
# matmul operands/results (XLA:TPU materialises dot inputs/outputs in HBM
# unless a hand-written kernel keeps them in VMEM), collectives, loop-state
# copies, layout changes, and slicing/update regions.  Elementwise / norm /
# softmax chains are treated as free epilogues of the adjacent heavy op —
# a modest undercount for standalone VPU passes, documented in EXPERIMENTS.
_BYTES_FULL = {  # operands + result
    "dot", "convolution", "custom-call", "copy", "transpose",
    "concatenate", "sort", "select-and-scatter", "triangular-solve",
    "cholesky",
}
_BYTES_RESULT_ONLY = {"dynamic-slice", "slice", "gather"}
_BYTES_INPLACE = {"dynamic-update-slice", "scatter"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all array shapes in a type string."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    tail: str  # attributes after the operand list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HEADER.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, type_str, op, operand_str, tail = mi.groups()
        # operand names (refs like %foo); attrs in `tail`
        operands = _NAME_REF.findall(operand_str)
        ins = Instr(name, type_str, op, operands, tail)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    coll_by_class: Dict[str, float]
    loops: List[Tuple[str, int]]


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in _dims_of(ins.type_str):
        out_elems *= d
    # contracting dims from lhs
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.tail)
    if not m or not ins.operands:
        return 2.0 * out_elems  # degenerate
    lhs = comp.by_name.get(ins.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    lhs_dims = _dims_of(lhs.type_str)
    k = 1
    for i in m.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def _hbm_bytes(ins: Instr, comp: Computation, base: str) -> float:
    """Fusion-aware HBM traffic of one top-level instruction (see the op-set
    comment above)."""

    def operand_bytes(idxs=None):
        tot = 0
        ops = ins.operands if idxs is None else [
            ins.operands[i] for i in idxs if i < len(ins.operands)]
        for o in ops:
            src = comp.by_name.get(o)
            if src is not None:
                tot += _shape_elems_bytes(src.type_str)[1]
        return tot

    _, rb = _shape_elems_bytes(ins.type_str)
    if base in COLLECTIVES:
        return rb + operand_bytes()
    if ins.op in _BYTES_FULL:
        return rb + operand_bytes()
    if ins.op in _BYTES_RESULT_ONLY:
        return float(rb)
    if ins.op in _BYTES_INPLACE:
        # read + write of the updated region only (operand 1 = update)
        return 2.0 * operand_bytes([1])
    return 0.0


def _collective_moved(ins: Instr, comp: Computation) -> float:
    _, result_b = _shape_elems_bytes(ins.type_str)
    op_b = 0
    for o in ins.operands:
        src = comp.by_name.get(o)
        if src is not None:
            op_b += _shape_elems_bytes(src.type_str)[1]
    base = ins.op.replace("-start", "").replace("-done", "")
    if base == "all-gather":
        return float(result_b)
    if base == "all-reduce":
        return 2.0 * op_b
    return float(op_b)


def analyze(text: str) -> ModuleCost:
    comps = parse_module(text)

    # resolve constant literals line-by-line (the instr regex drops them)
    const_vals: Dict[Tuple[str, str], int] = {}
    cur_comp = None
    for raw in text.splitlines():
        s = raw.strip()
        m = _COMP_HEADER.match(s)
        if m and s.endswith("{"):
            cur_comp = m.group(1)
            continue
        cm = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)",
                      s)
        if cm and cur_comp:
            const_vals[(cur_comp, cm.group(1))] = int(cm.group(2))

    def cond_trip(cond_name: str) -> int:
        vals = [v for (c, _), v in const_vals.items() if c == cond_name]
        return max(vals) if vals else 1

    entry = None
    for name, c in comps.items():
        if "main" in name or name.startswith("main"):
            entry = name
    if entry is None:  # last computation is ENTRY by convention
        entry = list(comps)[-1]

    # which computations are fusion bodies (skip their byte accounting)
    fusion_bodies = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.tail)
                if m:
                    fusion_bodies.add(m.group(1))

    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    # BFS through the call graph accumulating multipliers
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        cmul = mult[cname]
        for ins in comp.instrs:
            body = re.search(r"body=%?([\w.\-]+)", ins.tail)
            cond = re.search(r"condition=%?([\w.\-]+)", ins.tail)
            if ins.op == "while" and body and cond:
                trips = cond_trip(cond.group(1))
                for target, factor in ((body.group(1), trips),
                                       (cond.group(1), trips + 1)):
                    mult[target] = mult.get(target, 0.0) + cmul * factor
                    if target not in seen:
                        seen.add(target)
                        order.append(target)
            else:
                for attr in ("calls", "to_apply", "true_computation",
                             "false_computation"):
                    m = re.search(rf"{attr}=%?([\w.\-]+)", ins.tail)
                    if m:
                        t = m.group(1)
                        mult[t] = mult.get(t, 0.0) + cmul
                        if t not in seen:
                            seen.add(t)
                            order.append(t)

    flops = 0.0
    byts = 0.0
    coll: Dict[str, float] = {}
    loops: List[Tuple[str, int]] = []
    for cname, comp in comps.items():
        cmul = mult.get(cname, 0.0)
        if cmul == 0.0:
            continue
        count_bytes = cname not in fusion_bodies
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += cmul * _dot_flops(ins, comp)
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                moved = cmul * _collective_moved(ins, comp)
                coll[base] = coll.get(base, 0.0) + moved
            if count_bytes:
                byts += cmul * _hbm_bytes(ins, comp, base)
            if ins.op == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.tail)
                if cond:
                    loops.append((cname, cond_trip(cond.group(1))))

    return ModuleCost(flops=flops, bytes_accessed=byts,
                      collective_bytes=sum(coll.values()),
                      coll_by_class=coll, loops=loops)
