"""Production meshes (TPU v5e target): 16x16 single pod, 2x16x16 multi-pod.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return compat.make_mesh((data, model_axis), ("data", "model"))
