import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first (before any jax-importing import): jax
locks the device count at first init, and only the dry-run wants 512
placeholder devices.

Per cell this script:
  1. builds ShapeDtypeStruct inputs (launch/cells.py — no allocation),
  2. jit-lowers train_step / prefill / serve_step with in/out shardings from
     the name-based rules (sharding/specs.py),
  3. ``.lower().compile()`` — any sharding mismatch / unsupported collective
     / compile-time OOM fails the cell (a bug in our system, per the brief),
  4. records memory_analysis / cost_analysis / collective bytes + the
     three roofline terms to a JSON file for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import cells as cells_mod
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.sharding.specs import batch_axes, partition_specs
from repro.train.train_step import TrainConfig, abstract_state, make_train_step


def _batch_shardings(specs, mesh):
    """Batch inputs: shard dim0 over the BATCH axes where divisible."""
    ax = 1
    for a in batch_axes(mesh):
        ax *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]

    def one(s):
        if s.shape and s.shape[0] % ax == 0:
            return NamedSharding(mesh, P(batch_axes(mesh)))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, specs)


def _cache_shardings(caches, mesh, cfg):
    """KV caches: batch dim over BATCH axes; seq dim of K/V over model when
    kv_heads cannot shard (GQA kv<16); kv-head dim over model when it can."""
    ax_names = batch_axes(mesh)
    ax = 1
    for a in ax_names:
        ax *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    model_ax = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

    def one(path, s):
        last = path[-1]
        # dict entries carry .key; NamedTuple fields (KVCache.k etc) carry
        # .name — missing the latter silently loses the cache sharding
        name = str(getattr(last, "name", None) or getattr(last, "key", ""))
        dims = [None] * len(s.shape)
        # find the batch dim: caches under 'stack' carry a leading period dim
        # UNLESS they are the per-layer list variant (sp_decode_attn), whose
        # path contains a sequence index
        in_list = any(type(p).__name__ == "SequenceKey" for p in path)
        stacked = (not in_list) and any(
            str(getattr(p, "key", "")) == "stack" for p in path)
        b_dim = 1 if stacked else 0
        if len(s.shape) > b_dim and s.shape[b_dim] % ax == 0:
            dims[b_dim] = ax_names
        if name in ("k", "v", "mem_k", "mem_v") and len(s.shape) >= b_dim + 4:
            kvh = s.shape[b_dim + 2]
            seq = s.shape[b_dim + 1]
            if kvh % model_ax == 0:
                dims[b_dim + 2] = "model"
            elif seq % model_ax == 0:
                dims[b_dim + 1] = "model"
        elif name == "positions" and len(s.shape) >= b_dim + 2:
            seq = s.shape[b_dim + 1]
            # positions must shard like the K/V seq dim when that is sharded
            kv_sharded_on_seq = True  # mirrors the k/v rule below
            if cfg.num_kv_heads % model_ax == 0:
                kv_sharded_on_seq = False
            if kv_sharded_on_seq and seq % model_ax == 0:
                dims[b_dim + 1] = "model"
        elif name in ("state", "conv_buf", "h"):
            # recurrent states: shard inner dim over model when divisible
            inner = s.shape[-1]
            if inner % model_ax == 0:
                dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    out = jax.tree_util.tree_map_with_path(one, caches)
    _verify_cache_shardings(caches, out, mesh, cfg)
    return out


def _verify_cache_shardings(caches, shardings, mesh, cfg) -> None:
    """Structural check: every large cache leaf must actually be sharded.

    Guards against the class of bug found in §Perf iter 1a (pytree-path API
    mismatch silently dropping every KV-cache sharding): any leaf bigger
    than 64 MB/device-equivalent whose spec came out fully replicated is a
    rule failure, not a preference.
    """
    n_dev = mesh.devices.size
    leaves = jax.tree_util.tree_leaves_with_path(caches)
    specs = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    for (path, leaf), sh in zip(leaves, specs):
        nbytes = 1
        for d in leaf.shape:
            nbytes *= d
        nbytes *= jnp.dtype(leaf.dtype).itemsize
        replicated = all(e is None for e in sh.spec)
        if replicated and nbytes / n_dev > 64 * 1024 * 1024:
            name = "/".join(str(getattr(p, "name", None)
                                or getattr(p, "key", p)) for p in path)
            raise AssertionError(
                f"cache leaf {name} ({nbytes/1e9:.1f} GB) has a fully "
                f"replicated sharding — rule failure (see §Perf iter 1a)")


# §Perf hillclimb variants: named config overrides applied on top of the
# paper-faithful baseline (comma-separable, e.g. --variant fsdp2d,remat_dots)
VARIANTS = {
    "baseline": {},
    "sp_attn": {"sp_decode_attn": True},
    "moe_gather": {"moe_combine": "gather"},
    "moe_ep": {"moe_impl": "ep"},
    "fsdp2d": {"shard_strategy": "fsdp2d"},
    "remat_dots": {"remat": "dots"},
    "remat_none": {"remat": "none"},
}
# train-config variants (grad-accumulation microbatches)
TRAIN_VARIANTS = {"mb2": 2, "mb4": 4, "mb8": 8}


def lower_cell(arch: str, shape: str, multi_pod: bool,
               donate: bool = True, variant: str = "baseline") -> dict:
    import dataclasses

    from repro.sharding import specs as specs_mod

    cfg = get_config(arch)
    overrides = {}
    microbatches = 1
    for v in variant.split(","):
        if v in TRAIN_VARIANTS:
            microbatches = TRAIN_VARIANTS[v]
        else:
            overrides.update(VARIANTS[v])
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = cells_mod.cell_of(arch, shape)
    if cell is None:
        return {"arch": arch, "shape": shape, "status": "skipped",
                "reason": "full-attention arch: 500k dense KV cache "
                          "(sub-quadratic attention required; DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = Model(cfg)
    t0 = time.time()

    with mesh, specs_mod.strategy(cfg.shard_strategy):
        if cell.kind == "train":
            tcfg = TrainConfig(microbatches=microbatches)
            state = abstract_state(model, tcfg)
            state_specs = partition_specs(state, mesh, mode="train")
            state_sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), state_specs)
            step = make_train_step(model, tcfg)
            bspecs = cells_mod.batch_specs(cfg, cell)
            bsh = _batch_shardings(bspecs, mesh)
            fn = jax.jit(step, in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state, bspecs)
        elif cell.kind == "prefill":
            params = model.abstract_params()
            psh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                partition_specs(params, mesh, mode="serve"))
            bspecs = cells_mod.batch_specs(cfg, cell)
            bsh = _batch_shardings(bspecs, mesh)
            fn = jax.jit(lambda p, b: model.prefill(p, b, cell.seq),
                         in_shardings=(psh, bsh))
            lowered = fn.lower(params, bspecs)
        else:  # decode
            params = model.abstract_params()
            psh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                partition_specs(params, mesh, mode="serve"))
            caches, tokens, pos = cells_mod.decode_specs(cfg, cell)
            csh = _cache_shardings(caches, mesh, cfg)
            tsh = _batch_shardings({"t": tokens}, mesh)["t"]
            possh = _batch_shardings({"p": pos}, mesh)["p"]
            fn = jax.jit(model.decode_step,
                         in_shardings=(psh, csh, tsh, possh),
                         out_shardings=(None, csh),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(params, caches, tokens, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = rl.analyse(compiled, cfg, cell, n_dev)
    out = {
        "arch": arch, "shape": shape, "status": "ok", "variant": variant,
        "mesh": list(mesh.devices.shape), "multi_pod": multi_pod,
        "kind": cell.kind, "batch": cell.batch, "seq": cell.seq,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "roofline": roof.to_dict(),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--variant", default="baseline",
                    help=f"comma-sep of {sorted(VARIANTS)}")
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch, shape, _ in cells_mod.all_cells():
            todo.append((arch, shape))
    else:
        todo.append((args.arch, args.shape))

    results = []
    for arch, shape in todo:
        try:
            res = lower_cell(arch, shape, args.multi_pod,
                             variant=args.variant)
        except Exception as e:  # a failing cell is a bug — surface it loudly
            res = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(res)
        print(json.dumps({k: v for k, v in res.items() if k != "trace"}),
              flush=True)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = "multipod" if args.multi_pod else "singlepod"
            if args.variant != "baseline":
                tag = f"{tag}__{args.variant.replace(',', '+')}"
            fname = f"{arch}__{shape}__{tag}.json".replace("/", "_")
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(res, f, indent=1)

    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n{len(results)} cells, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
