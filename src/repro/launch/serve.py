"""Serving driver: batched requests through the Engine with the MCPrioQ
speculative drafter (the paper's structure as a first-class serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 8 --prompt-len 32 --new-tokens 48
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import speculative as spec
from repro.models.model import Model
from repro.serve.engine import Engine, ServeConfig


def run(arch: str, smoke: bool, requests: int, prompt_len: int,
        new_tokens: int, draft_len: int, seed: int = 0):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.encoder_layers or cfg.frontend == "patch":
        raise SystemExit("text-LM serving driver; see examples/ for encdec")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    scfg = ServeConfig(
        max_new_tokens=new_tokens,
        max_cache_len=prompt_len + new_tokens + 8,
        draft_len=draft_len,
        ngram=spec.NGramConfig(order=2),
    )
    engine = Engine(model, params, scfg)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    outs = []
    for r in range(requests):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, prompt_len)).astype(np.int32))}
        out = engine.generate(batch, jax.random.key(r))
        outs.append(out)
    dt = time.time() - t0
    total_tokens = sum(o.size for o in outs)
    plain_calls = requests * (new_tokens - 1)
    print(f"{requests} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    print(f"model calls {engine.stats['model_calls']} "
          f"(plain greedy would use {plain_calls}), "
          f"draft acceptance {engine.acceptance_rate:.2%}")
    return outs, engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--draft-len", type=int, default=4)
    args = ap.parse_args()
    run(args.arch, args.smoke, args.requests, args.prompt_len,
        args.new_tokens, args.draft_len)


if __name__ == "__main__":
    main()
