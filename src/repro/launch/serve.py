"""Serving driver: batched requests through the Engine with the MCPrioQ
speculative drafter (the paper's structure as a first-class serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 8 --prompt-len 32 --new-tokens 48

Shard-parallel chain serving (DESIGN.md §9) — routes synthetic transition
traffic through the :class:`ShardedEngine` instead of the LM loop (off-TPU,
fake the devices first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --num-shards 8 \
      --bucket-factor 2.0 --requests 16 --route-batch 4096

Durable serving (DESIGN.md §10) — snapshot on cadence, write-ahead-log every
batch, and recover (optionally at a different shard count) with --restore:

  ... --num-shards 8 --snapshot-dir /tmp/mc-snap --snapshot-every 8 \
      --wal /tmp/mc-wal
  ... --num-shards 4 --snapshot-dir /tmp/mc-snap --wal /tmp/mc-wal --restore
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import mcprioq as mc
from repro.core import sharded as sh
from repro.core import speculative as spec
from repro.data.synthetic import MarkovGraphSampler
from repro.models.model import Model
from repro.obs import metrics as obs_metrics
from repro.obs.export import MetricsDumper, MetricsServer
from repro.serve.engine import (Engine, ServeConfig, ShardedEngine,
                                ShardedServeConfig)


def run(arch: str, smoke: bool, requests: int, prompt_len: int,
        new_tokens: int, draft_len: int, seed: int = 0,
        decay_threshold: int = 1 << 18, decay_block_rows: int = 1024):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.encoder_layers or cfg.frontend == "patch":
        raise SystemExit("text-LM serving driver; see examples/ for encdec")
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    # rolling decay keeps learner-side maintenance bounded per request
    # (DESIGN.md §6) instead of stalling serving on a full-table sweep
    mc_cfg = mc.MCConfig(num_rows=8192, capacity=64, sort_passes=1,
                         decay_block_rows=decay_block_rows)
    scfg = ServeConfig(
        max_new_tokens=new_tokens,
        max_cache_len=prompt_len + new_tokens + 8,
        draft_len=draft_len,
        ngram=spec.NGramConfig(order=2, mc=mc_cfg,
                               decay_threshold=decay_threshold),
    )
    engine = Engine(model, params, scfg)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    outs = []
    for r in range(requests):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, prompt_len)).astype(np.int32))}
        out = engine.generate(batch, jax.random.key(r))
        outs.append(out)
    dt = time.time() - t0
    total_tokens = sum(o.size for o in outs)
    plain_calls = requests * (new_tokens - 1)
    print(f"{requests} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    print(f"model calls {engine.stats['model_calls']} "
          f"(plain greedy would use {plain_calls}), "
          f"draft acceptance {engine.acceptance_rate:.2%}")
    print(f"maintenance: decay_steps={engine.stats['decay_steps']} "
          f"dh_rebuilds={engine.stats['dh_rebuilds']} "
          f"dh_tombstones={engine.stats['dh_tombstones']}")
    return outs, engine


def run_sharded(num_shards: int, bucket_factor: float, requests: int,
                route_batch: int, topn: int, seed: int = 0,
                decay_threshold: int = 1 << 18, decay_block_rows: int = 1024,
                snapshot_dir: str = "", snapshot_every: int = 0,
                wal_dir: str = "", restore: bool = False,
                route_retry_budget: int = 0, query_retry_budget: int = 0,
                health_strikes: int = 3, failpoints: str = "",
                metrics_port: int = -1, metrics_dump: str = "",
                metrics_every: float = 5.0, incident_dir: str = "",
                metrics_linger: float = 0.0):
    """Shard-parallel chain serving: route synthetic Zipf transition traffic
    through the ShardedEngine (observe + query per request) and report
    throughput plus the routing/overflow counters.  With a snapshot dir the
    engine checkpoints on cadence (and a WAL makes recovery exact);
    ``restore=True`` recovers from the newest complete snapshot first —
    elastically, if it was taken at a different shard count (DESIGN.md §10).
    ``failpoints`` arms injection sites (same spec as ``MCQ_FAILPOINTS``,
    DESIGN.md §12) so the retry/degradation ladder can be driven live.
    ``metrics_port >= 0`` serves Prometheus text at ``/metrics`` (0 picks an
    ephemeral port, printed at startup); ``metrics_dump`` writes JSONL images
    on a ``metrics_every`` cadence (DESIGN.md §13)."""
    if failpoints:
        from repro.faults import arm_from_env
        n = arm_from_env(failpoints)
        print(f"armed {n} failpoint(s): {failpoints}")
    telemetry = metrics_port >= 0 or bool(metrics_dump) or bool(incident_dir)
    if telemetry:
        obs_metrics.arm()
    base = mc.MCConfig(num_rows=4096, capacity=64, sort_passes=1,
                       decay_block_rows=decay_block_rows)
    scfg = sh.ShardedConfig(base=base, num_shards=num_shards,
                            bucket_factor=bucket_factor)
    engine = ShardedEngine(ShardedServeConfig(
        sharded=scfg, decay_threshold=decay_threshold, topn=topn,
        snapshot_dir=snapshot_dir or None, snapshot_every=snapshot_every,
        wal_dir=wal_dir or None,
        route_retry_budget=route_retry_budget,
        query_retry_budget=query_retry_budget,
        health_strikes=health_strikes,
        incident_dir=incident_dir or None))
    server = dumper = None
    if metrics_port >= 0:
        server = MetricsServer(engine.metrics, port=metrics_port).start()
        print(f"metrics: http://127.0.0.1:{server.port}/metrics", flush=True)
    if metrics_dump:
        dumper = MetricsDumper(engine.metrics, metrics_dump,
                               every_s=metrics_every).start()
    if restore:
        info = engine.restore()
        print(f"restored step {info['step']} ({info['mode']}), "
              f"replayed {info['replayed']} WAL batches "
              f"through seq {info['wal_seq']}")
    graph = MarkovGraphSampler(num_nodes=4096, out_degree=32, seed=seed)
    rng = np.random.default_rng(seed)
    # compile outside the timed loop (jit caches persist per shape)
    s, d = graph.sample_transitions(route_batch)
    engine.observe(s, d)
    engine.query(jnp.asarray(rng.integers(0, 4096, 256).astype(np.int32)))
    t0 = time.time()
    for _ in range(requests):
        s, d = graph.sample_transitions(route_batch)
        engine.observe(s, d)
        engine.query(jnp.asarray(
            rng.integers(0, 4096, 256).astype(np.int32)))
    dt = time.time() - t0
    edges = requests * route_batch
    srcs, dsts, probs = engine.topn()
    st = engine.stats_snapshot()
    print(f"{requests} requests, {edges} edges over {num_shards} shards "
          f"in {dt:.1f}s ({edges / dt:.0f} edges/s)")
    print(f"routing: route_dropped={st['route_dropped']} "
          f"query_dropped={st['query_dropped']} "
          f"dropped_rows={st['dropped_rows']} "
          f"deferred_new={st['deferred_new']}")
    print(f"faults: wal_retries={st['wal_retries']} "
          f"apply_retries={st['apply_retries']} "
          f"dispatch_retries={st['dispatch_retries']} "
          f"write_errors={st['write_errors']} "
          f"degraded_answers={st['degraded_answers']} "
          f"route_retried={st['route_retried']}/"
          f"lost={st['route_lost']} "
          f"shards_down={st['shards_down']} "
          f"write_available={engine.write_available}")
    print(f"maintenance: decay_steps={st['decay_steps']} "
          f"n_rows={st['n_rows']} snapshots={st['snapshots']}")
    if snapshot_dir:
        path = engine.checkpoint()
        print(f"final checkpoint -> {path}")
    head = ", ".join(
        f"{int(s_)}->{int(d_)}:{float(p_):.3f}"
        for s_, d_, p_ in zip(np.asarray(srcs)[:5], np.asarray(dsts)[:5],
                              np.asarray(probs)[:5]))
    print(f"global top-{topn} head: {head} "
          f"(unexposed candidates {st['topn_dropped']})")
    if telemetry:
        snap = engine.metrics.snapshot()
        obs = snap["histograms"].get("engine.observe", {})
        qry = snap["histograms"].get("engine.query", {})
        print(f"telemetry: observe p50={obs.get('p50', 0.0):.4f}s "
              f"p99={obs.get('p99', 0.0):.4f}s "
              f"query p50={qry.get('p50', 0.0):.4f}s "
              f"p99={qry.get('p99', 0.0):.4f}s")
    if metrics_linger > 0 and server is not None:
        print(f"lingering {metrics_linger:.0f}s for scrapes...", flush=True)
        time.sleep(metrics_linger)
    if dumper is not None:
        dumper.close()
    if server is not None:
        server.close()
    return engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--decay-threshold", type=int, default=1 << 18,
                    help="row-total threshold that triggers §II.C decay")
    ap.add_argument("--decay-block-rows", type=int, default=1024,
                    help="rolling decay block size; 0 = stop-the-world")
    ap.add_argument("--num-shards", type=int, default=0,
                    help="> 0 serves the node-sharded chain (ShardedEngine) "
                         "instead of the LM loop; needs that many devices "
                         "(fake with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before jax starts)")
    ap.add_argument("--bucket-factor", type=float, default=2.0,
                    help="all_to_all bucket capacity as a multiple of the "
                         "fair per-shard share (overflow drops are counted)")
    ap.add_argument("--route-batch", type=int, default=2048,
                    help="transitions per sharded observe() call")
    ap.add_argument("--topn", type=int, default=16,
                    help="global top-n read size for the sharded path")
    ap.add_argument("--snapshot-dir", default="",
                    help="arm durable serving: checkpoint()/restore() + "
                         "cadence snapshots land here (DESIGN.md §10)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="background snapshot every N observe() calls "
                         "(0 = only the final/escalation checkpoints)")
    ap.add_argument("--wal", default="", dest="wal_dir",
                    help="write-ahead-log directory: every observed batch "
                         "is durably logged before it is applied, so "
                         "--restore replays to the exact pre-crash state")
    ap.add_argument("--restore", action="store_true",
                    help="recover from the newest complete snapshot before "
                         "serving (elastic if the snapshot's shard count "
                         "differs from --num-shards)")
    ap.add_argument("--route-retry-budget", type=int, default=0,
                    help="bounded re-submission budget for skew-dropped "
                         "routed items (0 = count them as route_dropped)")
    ap.add_argument("--query-retry-budget", type=int, default=0,
                    help="in-call re-dispatch rounds for skew-dropped "
                         "query items (0 = count them as query_dropped)")
    ap.add_argument("--health-strikes", type=int, default=3,
                    help="consecutive dispatch failures before a shard is "
                         "marked down (reads degrade, writes defer)")
    ap.add_argument("--failpoints", default="",
                    help="arm fault-injection sites, e.g. "
                         "'wal.append.fsync=raise:28@nth:5'; same spec as "
                         "the MCQ_FAILPOINTS env var (DESIGN.md §12)")
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="serve Prometheus text + JSONL metrics over HTTP "
                         "on this port (0 = pick an ephemeral port, printed "
                         "at startup; -1 = off); arms telemetry")
    ap.add_argument("--metrics-dump", default="",
                    help="write a JSONL metrics image to this path on a "
                         "cadence (atomic replace); arms telemetry")
    ap.add_argument("--metrics-every", type=float, default=5.0,
                    help="seconds between --metrics-dump images")
    ap.add_argument("--incident-dir", default="",
                    help="flight-recorder incident dumps (last spans + "
                         "metric deltas on poison/strike-out/degraded "
                         "reads) land here as JSON; arms telemetry")
    ap.add_argument("--metrics-linger", type=float, default=0.0,
                    help="keep the metrics endpoint up this many seconds "
                         "after the run finishes (for scraping)")
    args = ap.parse_args()
    if args.num_shards > 0:
        run_sharded(args.num_shards, args.bucket_factor, args.requests,
                    args.route_batch, args.topn,
                    decay_threshold=args.decay_threshold,
                    decay_block_rows=args.decay_block_rows,
                    snapshot_dir=args.snapshot_dir,
                    snapshot_every=args.snapshot_every,
                    wal_dir=args.wal_dir, restore=args.restore,
                    route_retry_budget=args.route_retry_budget,
                    query_retry_budget=args.query_retry_budget,
                    health_strikes=args.health_strikes,
                    failpoints=args.failpoints,
                    metrics_port=args.metrics_port,
                    metrics_dump=args.metrics_dump,
                    metrics_every=args.metrics_every,
                    incident_dir=args.incident_dir,
                    metrics_linger=args.metrics_linger)
        return
    run(args.arch, args.smoke, args.requests, args.prompt_len,
        args.new_tokens, args.draft_len,
        decay_threshold=args.decay_threshold,
        decay_block_rows=args.decay_block_rows)


if __name__ == "__main__":
    main()
