"""The assigned (architecture x input-shape) grid: 10 archs x 4 shapes.

Shape semantics (brief):
  * train_4k   : seq 4096,   global_batch 256  -> lowers train_step
  * prefill_32k: seq 32768,  global_batch 32   -> lowers prefill
  * decode_32k : KV len 32768, global_batch 128 -> lowers serve_step (1 token)
  * long_500k  : KV len 524288, global_batch 1  -> serve_step; SSM/hybrid only

Enc-dec (whisper): seq applies to the encoder frame stream; the decoder uses
its native max (448 prefill 256 prompt / decode cache).  VLM: 256 stub patch
embeddings are part of the sequence budget.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.configs.base import ModelConfig
from repro.models.model import Model

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

# archs whose attention is sub-quadratic (long_500k runs only for these)
SUBQUADRATIC = ("mamba2-130m", "recurrentgemma-9b")


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str            # "train" | "prefill" | "decode"
    batch: int
    seq: int

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"


def cell_of(arch: str, shape: str) -> Optional[Cell]:
    """None == skipped cell (with the reason recorded by the caller)."""
    if shape == "train_4k":
        return Cell(arch, shape, "train", 256, 4096)
    if shape == "prefill_32k":
        return Cell(arch, shape, "prefill", 32, 32768)
    if shape == "decode_32k":
        return Cell(arch, shape, "decode", 128, 32768)
    if shape == "long_500k":
        if arch not in SUBQUADRATIC:
            return None  # full attention: 500k dense KV cache is the blocker
        return Cell(arch, shape, "decode", 1, 524288)
    raise ValueError(shape)


def all_cells():
    for arch in sorted(ARCHS):
        for shape in SHAPES:
            yield arch, shape, cell_of(arch, shape)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs per cell (no allocation — the dry-run contract)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: Cell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training / prefill batch ShapeDtypeStructs."""
    b, s = cell.batch, cell.seq
    i32, f32 = jnp.int32, jnp.float32
    if cfg.encoder_layers:                       # whisper: frames + dec tokens
        s_dec = 256 if cell.kind == "prefill" else min(cfg.decoder_max_len, 448)
        out = {"frames": _sds((b, s, cfg.d_model), f32),
               "tokens": _sds((b, s_dec), i32)}
        if cell.kind == "train":
            out["targets"] = _sds((b, s_dec), i32)
        return out
    if cfg.frontend == "patch":                  # vlm: patches are in-budget
        npatch = cfg.frontend_len
        out = {"prefix_embeds": _sds((b, npatch, cfg.d_model), f32),
               "tokens": _sds((b, s - npatch), i32)}
        if cell.kind == "train":
            out["targets"] = _sds((b, s - npatch), i32)
        return out
    out = {"tokens": _sds((b, s), i32)}
    if cell.kind == "train":
        out["targets"] = _sds((b, s), i32)
    return out


def decode_specs(cfg: ModelConfig, cell: Cell):
    """(caches, tokens, pos) ShapeDtypeStructs for serve_step cells."""
    model = Model(cfg)
    b, s = cell.batch, cell.seq
    if cfg.encoder_layers:
        caches = jax.eval_shape(
            lambda: model.init_caches(b, cfg.decoder_max_len, enc_len=s))
    else:
        caches = jax.eval_shape(lambda: model.init_caches(b, s))
    tokens = _sds((b, 1), jnp.int32)
    pos = _sds((b,), jnp.int32)
    return caches, tokens, pos
