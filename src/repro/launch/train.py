"""End-to-end training driver.

Runs real steps on the host devices (CPU here, TPU pod unchanged): sharded
data pipeline, pjit'd train step, checkpoint/restart, straggler watchdog.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import NamedSharding

from repro.checkpoint import ckpt
from repro.configs import get_config, smoke_config
from repro.data.pipeline import ShardedIterator
from repro.data.synthetic import token_stream
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.runtime.fault_tolerance import StepWatchdog, WatchdogConfig
from repro.sharding.specs import partition_specs
from repro.train.train_step import (TrainConfig, abstract_state, init_state,
                                    make_train_step)


def run(arch: str, smoke: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str | None, ckpt_every: int = 50, microbatches: int = 1,
        compress: bool = False, model_axis: int = 1, log_every: int = 10):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    if cfg.encoder_layers or cfg.frontend == "patch":
        raise SystemExit("use the multimodal example drivers for this arch")
    model = Model(cfg)
    mesh = make_host_mesh(model_axis)
    tcfg = TrainConfig(microbatches=microbatches, compress_grads=compress,
                       total_steps=max(steps, 2))

    with mesh:
        shapes = abstract_state(model, tcfg)
        specs = partition_specs(shapes, mesh, mode="train")
        sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)
        step_fn = jax.jit(make_train_step(model, tcfg),
                          in_shardings=(sh, None), out_shardings=(sh, None),
                          donate_argnums=(0,))

        start = 0
        if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
            state, start = ckpt.restore(shapes, ckpt_dir, shardings=sh)
            print(f"restored checkpoint at step {start}")
        else:
            state = init_state(model, jax.random.key(0), tcfg)
            state = jax.device_put(state, sh)

        data = ShardedIterator(
            token_stream(cfg.vocab_size, batch, seq, seed=1), mesh)
        watchdog = StepWatchdog(WatchdogConfig(deadline_s=300.0))
        pending_save = None
        losses = []
        t0 = time.time()
        for i, b in zip(range(start, steps), data):
            ts = time.time()
            state, metrics = step_fn(state, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            watchdog.observe(time.time() - ts)
            if (i + 1) % log_every == 0:
                print(f"step {i+1:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(i-start+1):.2f}s/step)",
                      flush=True)
            if ckpt_dir and (i + 1) % ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save_async(state, ckpt_dir, i + 1)
        if pending_save is not None:
            pending_save.join()
        if ckpt_dir:
            ckpt.save(state, ckpt_dir, steps)
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()
    losses = run(args.arch, args.smoke, args.steps, args.batch, args.seq,
                 args.ckpt_dir, args.ckpt_every, args.microbatches,
                 args.compress_grads, args.model_axis)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
