"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI link bw

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
flops/bytes (verified: a 512-way sharded matmul reports 1/512 of the global
FLOPs), so the brief's "HLO_FLOPs / (chips x peak)" is applied in per-device
form.  Collective bytes are parsed from the compiled HLO text: per op class,
the bytes a device moves over ICI (ring model):
    all-gather:        result_bytes (receives all other shards)
    all-reduce:        2 x operand_bytes (reduce-scatter + all-gather)
    reduce-scatter:    operand_bytes
    all-to-all:        operand_bytes
    collective-permute: operand_bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

# TPU v5e, per chip (brief-specified)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(([^)]*)\)",
    re.M,
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Per-device ICI bytes by op class, from post-SPMD HLO text."""
    per_class: Dict[str, int] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        result_shape, op, operands = m.group(1), m.group(2), m.group(3)
        line = m.group(0)
        if "-done(" in line:
            continue  # the -start op already counted async collectives
        result_b = _shape_bytes(result_shape)
        operand_b = _shape_bytes(operands)
        if op == "all-gather":
            moved = result_b
        elif op == "all-reduce":
            moved = 2 * operand_b
        elif op == "reduce-scatter":
            moved = operand_b
        else:  # all-to-all, collective-permute
            moved = operand_b
        per_class[op] = per_class.get(op, 0) + moved
    return sum(per_class.values()), per_class


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_by_class: Dict[str, int]
    model_flops_per_device: float
    memory_floor: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_per_device / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant-term step time is to the ideal step time
        (ideal = useful model FLOPs at peak).  This is the score per the
        brief: MODEL_FLOPS/(chips*peak) / max(term)."""
        ideal = self.model_flops_per_device / PEAK_FLOPS_BF16
        actual = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / max(actual, 1e-30)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_by_class": self.coll_by_class,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_per_device": self.model_flops_per_device,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_floor_s": self.memory_floor / HBM_BW,
            "memory_vs_floor": (self.hbm_bytes
                                / max(self.memory_floor, 1.0)),
        }


def memory_floor_bytes(cfg, cell, n_devices: int) -> float:
    """Rough intrinsic lower bound on HBM traffic per device per step —
    what an ideal implementation could not avoid reading/writing:

      train  : 28 B/param (fp32 p read+write, grad write, adam m/v r+w)
               + ~6 half-precision residual-stream passes per layer
      prefill: params once (bf16) + KV cache write + 4 stream passes
      decode : params once (bf16) + full KV/state cache read

    Used to report "memory term is Nx its floor" in §Roofline — decode is
    *expected* to be memory-bound; the floor says how efficiently.
    """
    params = cfg.param_count()
    d, L = cfg.d_model, cfg.num_layers
    tokens = cell.batch * (cell.seq if cell.kind != "decode" else 1)
    if cell.kind == "train":
        traffic = 28.0 * params + 6.0 * L * tokens * d * 2.0
    elif cell.kind == "prefill":
        kv_bytes = (2 * cell.seq * cell.batch * cfg.num_kv_heads
                    * cfg.head_dim * 2.0 * L)
        traffic = 2.0 * params + kv_bytes + 4.0 * L * tokens * d * 2.0
    else:
        if cfg.pattern == ("ssm",):
            cache = (cell.batch * cfg.ssm_heads * cfg.ssm_headdim
                     * cfg.ssm_state * 4.0 * L)
        else:
            eff_len = min(cell.seq, cfg.local_window or cell.seq)
            n_attn = sum(1 for k in cfg._all_kinds()
                         if k in ("attn", "local_attn", "dense_mlp", "cross"))
            cache = (2 * eff_len * cell.batch * cfg.num_kv_heads
                     * cfg.head_dim * 2.0 * n_attn)
        traffic = 2.0 * params + cache
    return traffic / n_devices


def model_flops(cfg, cell, n_devices: int) -> float:
    """MODEL_FLOPS convention: 6*N*D train, 2*N*D inference; N = active
    params (MoE counts routed-in experts only)."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.batch * cell.seq
        total = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.batch * cell.seq
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * cell.batch
    return total / n_devices


def analyse(compiled, cfg, cell, n_devices: int) -> Roofline:
    """Trip-count-aware analysis (launch/hlo_cost.py): XLA's cost_analysis
    counts while bodies once, so lax.scan-over-layers would undercount by the
    trip count — hlo_cost multiplies loop bodies out (validated in
    tests/test_hlo_cost.py)."""
    from repro.launch import hlo_cost

    mc = hlo_cost.analyze(compiled.as_text())
    return Roofline(
        flops=mc.flops, hbm_bytes=mc.bytes_accessed,
        coll_bytes=mc.collective_bytes,
        coll_by_class={k: int(v) for k, v in mc.coll_by_class.items()},
        model_flops_per_device=model_flops(cfg, cell, n_devices),
        memory_floor=memory_floor_bytes(cfg, cell, n_devices),
    )
