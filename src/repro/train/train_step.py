"""Training step factory: grads (+ microbatch accumulation, + optional
gradient compression) -> AdamW update.  Pure function, pjit-ready."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.train import compression

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1          # grad accumulation via scan
    compress_grads: bool = False   # int8 + error feedback


class TrainState(NamedTuple):
    params: PyTree
    opt: adamw.AdamWState
    ef: Optional[compression.EFState]


def init_state(model: Model, rng: jax.Array, tcfg: TrainConfig) -> TrainState:
    params = model.init(rng)
    opt = adamw.init(params)
    ef = compression.init(params) if tcfg.compress_grads else None
    return TrainState(params, opt, ef)


def abstract_state(model: Model, tcfg: TrainConfig) -> TrainState:
    return jax.eval_shape(lambda: init_state(
        model, jax.random.key(0), tcfg))


def make_train_step(model: Model, tcfg: TrainConfig
                    ) -> Callable[[TrainState, PyTree], Tuple[TrainState, dict]]:
    """Returns step(state, batch) -> (state', metrics).

    With microbatches > 1, the global batch's leading dim is split and
    accumulated with a lax.scan — memory for activations scales with the
    microbatch, not the global batch.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return grads, loss, metrics

    def step(state: TrainState, batch: PyTree):
        if tcfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % tcfg.microbatches == 0, (b, tcfg.microbatches)
                return x.reshape((tcfg.microbatches, b // tcfg.microbatches)
                                 + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                g, loss, _ = grads_of(state.params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss_sum), _ = jax.lax.scan(acc_fn, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, grads)
            loss = loss_sum / tcfg.microbatches
            metrics = {"loss": loss}
        else:
            grads, loss, metrics = grads_of(state.params, batch)

        ef = state.ef
        if tcfg.compress_grads:
            grads, ef, cmetrics = compression.compress(grads, ef)
            metrics = {**metrics, **cmetrics}

        lr_scale = warmup_cosine(state.opt.step,
                                 warmup_steps=tcfg.warmup_steps,
                                 total_steps=tcfg.total_steps)
        params, opt, ometrics = adamw.update(
            grads, state.opt, state.params, tcfg.optimizer, lr_scale)
        metrics = {**metrics, **ometrics, "loss": loss}
        return TrainState(params, opt, ef), metrics

    return step
