"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantised gradients with an error-feedback accumulator: the
quantisation residual is carried to the next step, which provably preserves
convergence for SGD-family methods (Karimireddy et al., 2019).  On a real pod
this halves/quarters gradient all-reduce bytes (the collective term in
§Roofline for DP-heavy meshes); composed here as a pure grads->grads
transform so it works under any pjit sharding.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


class EFState(NamedTuple):
    residual: PyTree  # same structure as grads, fp32


def init(grads_like: PyTree) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def _quant_dequant_int8(g: jax.Array) -> jax.Array:
    """Blockwise symmetric int8 quantise-dequantise."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(fp), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.float32) * scale
    return dq.reshape(-1)[:n].reshape(g.shape)


def compress(grads: PyTree, state: EFState) -> Tuple[PyTree, EFState, dict]:
    """grads -> (compressed grads, new EF state, metrics)."""

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        cq = _quant_dequant_int8(gf)
        return cq.astype(g.dtype), gf - cq

    out = jax.tree_util.tree_map(one, grads, state.residual)
    treedef = jax.tree_util.tree_structure(grads)
    flat = treedef.flatten_up_to(out)
    cg = treedef.unflatten([t[0] for t in flat])
    res = treedef.unflatten([t[1] for t in flat])
    err = sum(jnp.sum(jnp.square(r)) for r in jax.tree_util.tree_leaves(res))
    return cg, EFState(res), {"ef_residual_sq": err}
